"""Permutation study: FCT distribution across transports and load
balancers under core oversubscription (paper Fig. 1/6/11 interactively).

  PYTHONPATH=src python examples/permutation_study.py [--oversub 4]
"""

import argparse

import numpy as np

from repro.netsim.engine import SimConfig, build, jain_fairness, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim import workloads


def cdf_sketch(fct, width=40):
    """ASCII CDF of flow completion times."""
    f = np.sort(fct)
    lo, hi = f[0], f[-1]
    rows = []
    for q in (0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0):
        v = f[min(int(q * len(f)), len(f) - 1)]
        bar = "#" * int(width * (v - lo) / max(hi - lo, 1))
        rows.append(f"   p{int(q*100):3d} {v:7.0f} |{bar}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--oversub", type=int, default=4, choices=(2, 4, 8))
    ap.add_argument("--size-kib", type=int, default=1024)
    args = ap.parse_args()

    link = LinkConfig()
    per_rack = 16
    tree = FatTreeConfig(racks=4, nodes_per_rack=per_rack,
                         uplinks=per_rack // args.oversub)
    wl = workloads.permutation(tree, size_bytes=args.size_kib * 1024, seed=1)
    pkts = args.size_kib * 1024 // 4096
    ideal = pkts * args.oversub + 26
    print(f"{tree.n_nodes}-node permutation, {args.oversub}:1 oversubscribed, "
          f"{args.size_kib} KiB flows (ideal ~{ideal} ticks)\n")

    for algo, lb in (("smartt", "reps"), ("smartt", "spray"),
                     ("smartt", "ecmp"), ("swift", "reps"),
                     ("eqds", "reps")):
        sim = build(SimConfig(link=link, tree=tree, algo=algo, lb=lb), wl)
        st = sim.run(max_ticks=200000)
        s = summarize(sim, st)
        fct = s["fct_ticks"][np.asarray(st.done)]
        print(f"== {algo}+{lb}: completion {s['fct_max']} "
              f"({s['fct_max']/ideal:.2f}x ideal), jain {jain_fairness(fct):.3f}, "
              f"trims {s['trims']}")
        print(cdf_sketch(fct))
        print()


if __name__ == "__main__":
    main()
