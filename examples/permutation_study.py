"""Permutation study: FCT distribution across transports and load
balancers under core oversubscription (paper Fig. 1/6/11 interactively),
plus a fused tuning Study — {initial window x seeds} in one compile.

  PYTHONPATH=src python examples/permutation_study.py [--oversub 4]
      [--seeds 3]
"""

import argparse

import numpy as np

from repro.netsim import api
from repro.netsim.scenarios import Scenario
from repro.netsim.state import SimConfig
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim import workloads


def cdf_sketch(fct, width=40):
    """ASCII CDF of flow completion times."""
    f = np.sort(fct)
    lo, hi = f[0], f[-1]
    rows = []
    for q in (0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0):
        v = f[min(int(q * len(f)), len(f) - 1)]
        bar = "#" * int(width * (v - lo) / max(hi - lo, 1))
        rows.append(f"   p{int(q*100):3d} {v:7.0f} |{bar}")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--oversub", type=int, default=4, choices=(2, 4, 8))
    ap.add_argument("--size-kib", type=int, default=1024)
    ap.add_argument("--seeds", type=int, default=3,
                    help="decorrelation seeds for the tuning study")
    args = ap.parse_args()

    link = LinkConfig()
    per_rack = 16
    tree = FatTreeConfig(racks=4, nodes_per_rack=per_rack,
                         uplinks=per_rack // args.oversub)
    wl = workloads.permutation(tree, size_bytes=args.size_kib * 1024, seed=1)
    base = Scenario(name=f"perm_{args.oversub}to1",
                    cfg=SimConfig(link=link, tree=tree),
                    wl=wl, max_ticks=200_000)
    pkts = args.size_kib * 1024 // 4096
    ideal = pkts * args.oversub + 26
    print(f"{tree.n_nodes}-node permutation, {args.oversub}:1 "
          f"oversubscribed, {args.size_kib} KiB flows "
          f"(ideal ~{ideal} ticks)\n")

    # one api.run per (algo, lb) — those change Dims, so each is a build
    for algo, lb in (("smartt", "reps"), ("smartt", "spray"),
                     ("smartt", "ecmp"), ("swift", "reps"),
                     ("eqds", "reps")):
        r = api.run(base, algo=algo, lb=lb)
        print(f"== {algo}+{lb}: completion {r.completion} "
              f"({r.completion / ideal:.2f}x ideal), jain {r.jain:.3f}, "
              f"trims {r.trims}")
        print(cdf_sketch(r.fct_done))
        print()

    # the tuning grid x seed batch, fused: every lane one compiled step
    points = [{"start_cwnd_mult": a} for a in (0.5, 1.0, 1.25)]
    seeds = range(args.seeds)
    res = api.study(base, points=points, seeds=seeds).run()
    print(f"tuning study: {len(points)} points x {res.n_seeds} seeds "
          f"= {len(res)} lanes in one compile ({res.wall_s:.1f}s)")
    print(f"{'start_cwnd_mult':>16s} {'completion (mean/max over seeds)':>34s}"
          f" {'jain (min)':>11s}")
    for pi, pt in enumerate(points):
        lanes = res.by_point(pi)
        comp = [r.completion for r in lanes]
        print(f"{pt['start_cwnd_mult']:16.2f} "
              f"{np.mean(comp):17.0f}/{max(comp):<16d} "
              f"{min(r.jain for r in lanes):11.3f}")
    best = res.best("completion")
    print(f"\nbest lane: {best.name} -> completion {best.completion} "
          f"({best.completion / ideal:.2f}x ideal)")


if __name__ == "__main__":
    main()
