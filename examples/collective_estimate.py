"""Transport-aware collective estimation: replay a training step's
collective traffic through the SMaRTT netsim and compare transports.

This is the integration the paper motivates — AI training traffic (DP
all-reduce, MoE alltoall) carried by the datacenter transport.  The
efficiency factors here refine the roofline's collective term
(EXPERIMENTS.md Sec. Roofline).

  PYTHONPATH=src python examples/collective_estimate.py
"""

from repro.collectives.bridge import estimate

CASES = [
    # (collective, bytes each device contributes) — representative of the
    # jamba-398b cross-pod gradient exchange and a dbrx EP dispatch
    ("all-reduce", 8 << 20),
    ("all-to-all", 4 << 20),
]

print(f"{'collective':12s} {'transport':12s} {'eff':>6s} {'straggle':>9s} "
      f"{'trims':>6s} {'fair':>6s}")
for kind, nbytes in CASES:
    for algo in ("smartt", "swift", "eqds"):
        e = estimate(kind, nbytes, algo=algo, nodes=32, oversub=4)
        print(f"{kind:12s} {algo:12s} {e.efficiency:6.2f} "
              f"{e.straggler_spread:9.3f} {e.trims:6d} {e.fairness:6.3f}")

print("\nefficiency = ideal-bottleneck-time / achieved completion; the "
      "roofline collective term divides by this factor per transport.")
