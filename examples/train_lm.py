"""End-to-end training driver: train a ~100M-class qwen3-family model on
the synthetic pipeline with checkpoint/restart.

Default invocation trains a CPU-sized model for a few hundred steps; pass
--d-model/--layers/--steps to scale up (the same code path drives the
production configs through repro.launch).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --steps 300   # resumes at 200
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b")
    cfg = dataclasses.replace(
        cfg, name="qwen3-mini", d_model=args.d_model, n_layers=args.layers,
        n_heads=max(args.d_model // 32, 1), n_kv_heads=max(args.d_model // 64, 1),
        head_dim=32, d_ff=args.d_model * 3, vocab=4096,
        q_chunk=64, k_chunk=64)
    n = cfg.param_count()
    print(f"model {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step")

    tcfg = TrainConfig(
        adam=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        microbatches=args.microbatches)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, structure=32)
    lcfg = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50, log_every=10)
    params, opt, losses = train(cfg, tcfg, lcfg, dcfg)
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps (checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
