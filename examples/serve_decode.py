"""Batched serving demo: prefill + greedy decode with the cache-carrying
serve path (the same decode_step the dry-run lowers at 32k/500k contexts).

  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import generate

cfg = get_config("qwen3-0.6b", reduced=True)
params = lm.init_params(cfg, jax.random.key(0))

B, S_PROMPT, NEW = 4, 24, 16
prompts = jax.random.randint(jax.random.key(1), (B, S_PROMPT), 0, cfg.vocab,
                             jnp.int32)

t0 = time.time()
out = generate(params, cfg, prompts, max_new=NEW, max_len=S_PROMPT + NEW + 1)
out.block_until_ready()
t1 = time.time()
out2 = generate(params, cfg, prompts, max_new=NEW, max_len=S_PROMPT + NEW + 1)
out2.block_until_ready()
t2 = time.time()

print(f"arch: {cfg.name} | batch {B}, prompt {S_PROMPT}, {NEW} new tokens")
print(f"compile+run: {t1-t0:.2f}s; steady-state: {t2-t1:.3f}s "
      f"({B*NEW/(t2-t1):.0f} tok/s on 1 CPU core)")
print("generated token ids (first request):", out[0].tolist())
assert out.shape == (B, NEW)
