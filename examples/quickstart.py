"""Quickstart: simulate an 8:1 incast under SMaRTT and Swift, print the
congestion-control story in 30 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.netsim.engine import SimConfig, build, jain_fairness, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig, ticks_to_us
from repro.netsim import workloads

link = LinkConfig()                                   # 100 Gb/s, 4 KiB MTU
tree = FatTreeConfig(racks=4, nodes_per_rack=8, uplinks=8)   # non-blocking
wl = workloads.incast(tree, degree=8, size_bytes=512 * 1024, seed=0)
ideal = 8 * (512 * 1024 // 4096) + 26

print(f"8:1 incast of 512 KiB flows onto node 0 "
      f"({tree.n_nodes} nodes, ideal {ideal} ticks)")
print(f"{'algo':12s} {'FCT max':>9s} {'vs ideal':>9s} {'fairness':>9s} "
      f"{'trims':>6s} {'completion':>12s}")
for algo in ("smartt", "swift", "mprdma", "eqds"):
    sim = build(SimConfig(link=link, tree=tree, algo=algo, lb="reps"), wl)
    st = sim.run(max_ticks=60000)
    s = summarize(sim, st)
    fct = s["fct_ticks"][np.asarray(st.done)]
    print(f"{algo:12s} {s['fct_max']:9d} {s['fct_max']/ideal:9.3f} "
          f"{jain_fairness(fct):9.3f} {s['trims']:6d} "
          f"{ticks_to_us(s['fct_max'], link):9.1f}us")

print("\nSMaRTT's QuickAdapt collapses the initial burst within one "
      "target-RTT;\nsee benchmarks/ for the full paper-figure suite.")
