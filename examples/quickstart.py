"""Quickstart: simulate an 8:1 incast under SMaRTT and its baselines via
the experiment API, print the congestion-control story in 30 seconds.

  PYTHONPATH=src python examples/quickstart.py [--quick]

One call per algorithm: ``api.run(scenario(name, algo=...))`` resolves a
registered scenario (fabric + workload + tick budget), runs it, and
returns a typed ``RunResult`` — FCTs, Jain fairness, slowdowns vs the
uncongested ideal, trim/retransmit counters.
"""

import argparse

from repro.netsim.api import run
from repro.netsim.scenarios import scenario
from repro.netsim.units import ticks_to_us


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller fabric/flows (CI smoke)")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="registered scenario to run instead of the "
                         "default incast (e.g. tiny_3t for a three-tier "
                         "smoke)")
    args = ap.parse_args()

    # registered scenarios are string-addressable; per-call overrides
    # (algo=, lb=, max_ticks=...) fork the frozen base Scenario
    name = args.scenario or ("incast8_16n" if args.quick else "incast8_32n")
    base = scenario(name)
    degree = base.wl.n_flows
    pkts = int(base.wl.size[0]) // base.cfg.link.mtu_bytes

    tree = base.cfg.tree
    print(f"{degree} flows of {int(base.wl.size[0]) // 1024} KiB "
          f"({tree.n_nodes} nodes, {tree.tiers}-tier) — scenario {name!r}")
    print(f"{'algo':12s} {'FCT max':>9s} {'slowdown':>9s} {'fairness':>9s} "
          f"{'trims':>6s} {'completion':>12s}")
    for algo in ("smartt", "swift", "mprdma", "eqds"):
        r = run(base, algo=algo)
        assert r.all_done, f"{algo}: {r.n_done}/{r.n_flows} finished"
        print(f"{algo:12s} {r.completion:9d} {r.slowdown_p99:9.3f} "
              f"{r.jain:9.3f} {r.trims:6d} "
              f"{ticks_to_us(r.completion, base.cfg.link):9.1f}us")

    print(f"\n(ideal uncongested flow: {pkts} packets + 1 RTT; slowdown "
          f"is FCT p99 vs that bound)")
    print("SMaRTT's QuickAdapt collapses the initial burst within one "
          "target-RTT;\nsee benchmarks/ for the full paper-figure suite "
          "and api.study for {point x seed} grids.")


if __name__ == "__main__":
    main()
