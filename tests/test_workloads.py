"""Workload generators: alltoall pair coverage and engine-level window
semantics, permutation derangement properties, and the sparse/heavy-tailed
generators feeding the leap benchmarks."""

import numpy as np
import pytest

from repro.netsim import workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.units import FatTreeConfig, LinkConfig

SMALL = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=4)
LINK = LinkConfig()


# ---------------------------------------------------------------- alltoall


def test_alltoall_covers_all_pairs_once():
    n = 6
    wl = workloads.alltoall(SMALL, size_bytes=4 * 4096, window=3, nodes=n)
    pairs = set(zip(wl.src.tolist(), wl.dst.tolist()))
    assert len(pairs) == wl.n_flows == n * (n - 1)
    assert pairs == {(s, d) for s in range(n) for d in range(n) if s != d}
    # per-source order is the 0..n-2 schedule the window gate keys on
    for s in range(n):
        assert sorted(wl.order[wl.src == s].tolist()) == list(range(n - 1))
    assert wl.window == 3


def test_alltoall_window_limits_concurrency():
    """Engine-level window semantics: with window=w, at most w flows of a
    source are in progress (delivered some but not all bytes) at any tick,
    and a flow's successors only start as predecessors finish; yet all
    pairs are eventually issued and complete."""
    n, w, pkts = 6, 2, 4
    size = pkts * 4096
    wl = workloads.alltoall(SMALL, size_bytes=size, window=w, nodes=n)
    sim = build(SimConfig(link=LINK, tree=SMALL), wl)
    nsrc0 = n - 1                           # flows 0..n-2 belong to source 0
    ticks = 4000
    _, ys = sim.run_trace(ticks, trace_flows=nsrc0)
    g = np.asarray(ys["goodput"])           # [ticks, n-1], source 0's flows
    assert g[-1].min() == size              # all of source 0's pairs issued

    in_progress = (g > 0) & (g < size)
    assert in_progress.sum(axis=1).max() <= w

    # order-w flow must not deliver before some predecessor finished
    first_byte = np.argmax(g > 0, axis=0)          # first tick with data
    done_tick = np.argmax(g >= size, axis=0)
    assert first_byte[w] > min(done_tick[:w])

    # full run completes every pair
    st = sim.run(max_ticks=200000)
    assert bool(np.asarray(st.done).all())
    np.testing.assert_array_equal(np.asarray(st.goodput), wl.size)


def test_alltoall_window_one_serializes_each_source():
    """window=1 degenerates to one flow at a time per source: completion
    times are strictly ordered by the per-source schedule."""
    n = 5
    wl = workloads.alltoall(SMALL, size_bytes=2 * 4096, window=1, nodes=n)
    sim = build(SimConfig(link=LINK, tree=SMALL), wl)
    st = sim.run(max_ticks=200000)
    assert bool(np.asarray(st.done).all())
    fct = np.asarray(st.fct) + wl.t_start
    for s in range(n):
        mask = wl.src == s
        by_order = fct[mask][np.argsort(wl.order[mask], kind="stable")]
        assert np.all(np.diff(by_order) > 0), (s, by_order)


# ------------------------------------------------------------- permutation


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_permutation_is_derangement(seed):
    wl = workloads.permutation(SMALL, size_bytes=4 * 4096, seed=seed,
                               cross_rack=False)
    assert sorted(wl.dst.tolist()) == list(range(SMALL.n_nodes))
    assert np.all(wl.dst != wl.src)


@pytest.mark.parametrize("seed", [0, 5, 9])
def test_cross_rack_permutation_crosses_the_core(seed):
    m = SMALL.nodes_per_rack
    wl = workloads.permutation(SMALL, size_bytes=4 * 4096, seed=seed,
                               cross_rack=True)
    assert np.all(wl.dst // m != wl.src // m)
    assert sorted(wl.dst.tolist()) == list(range(SMALL.n_nodes))


def test_multi_permutation_stacks_independent_rounds():
    wl = workloads.permutation(SMALL, size_bytes=4 * 4096, seed=1, n_perms=3)
    n = SMALL.n_nodes
    assert wl.n_flows == 3 * n
    for p in range(3):
        sl = slice(p * n, (p + 1) * n)
        assert np.all(wl.dst[sl] != wl.src[sl])
        assert np.all(wl.order[sl] == p)


# ------------------------------------------------ sparse / heavy-tailed


def test_heavy_tailed_shape_and_sparsity():
    wl = workloads.heavy_tailed(SMALL, 64, size_base=16 * 1024,
                                size_cap=512 * 1024, gap_mean=500.0, seed=0)
    assert np.all(wl.src != wl.dst)
    assert np.all((wl.src >= 0) & (wl.src < SMALL.n_nodes))
    assert np.all((wl.size >= 1) & (wl.size <= 512 * 1024))
    assert wl.size.max() > 4 * wl.size.min()       # the tail is heavy
    assert wl.t_start[0] == 0
    assert np.all(np.diff(wl.t_start) >= 0)        # arrivals in time order
    # sparse: mean inter-arrival near the requested gap (law of large nums)
    mean_gap = float(wl.t_start[-1]) / (wl.n_flows - 1)
    assert 250.0 < mean_gap < 1000.0


def test_heavy_tailed_seed_reproducible():
    a = workloads.heavy_tailed(SMALL, 16, seed=7)
    b = workloads.heavy_tailed(SMALL, 16, seed=7)
    c = workloads.heavy_tailed(SMALL, 16, seed=8)
    np.testing.assert_array_equal(a.size, b.size)
    np.testing.assert_array_equal(a.t_start, b.t_start)
    assert not np.array_equal(a.size, c.size)


def test_staggered_large_disjoint_and_spaced():
    wl = workloads.staggered_large(SMALL, 4, 64 * 4096, gap_ticks=1000,
                                   seed=0)
    assert len(set(wl.src.tolist())) == 4          # distinct senders
    assert np.all(wl.src != wl.dst)
    m = SMALL.nodes_per_rack
    assert np.all(wl.dst // m != wl.src // m)      # cross-rack transfers
    np.testing.assert_array_equal(wl.t_start, 1000 * np.arange(4))
    with pytest.raises(ValueError):
        workloads.staggered_large(SMALL, SMALL.n_nodes, 4096, 10)
