"""Workload generators: alltoall pair coverage and engine-level window
semantics, permutation derangement properties, and the sparse/heavy-tailed
generators feeding the leap benchmarks."""

import numpy as np
import pytest

from repro.netsim import workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.units import FatTreeConfig, LinkConfig

SMALL = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=4)
LINK = LinkConfig()


# ---------------------------------------------------------------- alltoall


def test_alltoall_covers_all_pairs_once():
    n = 6
    wl = workloads.alltoall(SMALL, size_bytes=4 * 4096, window=3, nodes=n)
    pairs = set(zip(wl.src.tolist(), wl.dst.tolist()))
    assert len(pairs) == wl.n_flows == n * (n - 1)
    assert pairs == {(s, d) for s in range(n) for d in range(n) if s != d}
    # per-source order is the 0..n-2 schedule the window gate keys on
    for s in range(n):
        assert sorted(wl.order[wl.src == s].tolist()) == list(range(n - 1))
    assert wl.window == 3


def test_alltoall_window_limits_concurrency():
    """Engine-level window semantics: with window=w, at most w flows of a
    source are in progress (delivered some but not all bytes) at any tick,
    and a flow's successors only start as predecessors finish; yet all
    pairs are eventually issued and complete."""
    n, w, pkts = 6, 2, 4
    size = pkts * 4096
    wl = workloads.alltoall(SMALL, size_bytes=size, window=w, nodes=n)
    sim = build(SimConfig(link=LINK, tree=SMALL), wl)
    nsrc0 = n - 1                           # flows 0..n-2 belong to source 0
    ticks = 4000
    _, ys = sim.run_trace(ticks, trace_flows=nsrc0)
    g = np.asarray(ys["goodput"])           # [ticks, n-1], source 0's flows
    assert g[-1].min() == size              # all of source 0's pairs issued

    in_progress = (g > 0) & (g < size)
    assert in_progress.sum(axis=1).max() <= w

    # order-w flow must not deliver before some predecessor finished
    first_byte = np.argmax(g > 0, axis=0)          # first tick with data
    done_tick = np.argmax(g >= size, axis=0)
    assert first_byte[w] > min(done_tick[:w])

    # full run completes every pair
    st = sim.run(max_ticks=200000)
    assert bool(np.asarray(st.done).all())
    np.testing.assert_array_equal(np.asarray(st.goodput), wl.size)


def test_alltoall_window_one_serializes_each_source():
    """window=1 degenerates to one flow at a time per source: completion
    times are strictly ordered by the per-source schedule."""
    n = 5
    wl = workloads.alltoall(SMALL, size_bytes=2 * 4096, window=1, nodes=n)
    sim = build(SimConfig(link=LINK, tree=SMALL), wl)
    st = sim.run(max_ticks=200000)
    assert bool(np.asarray(st.done).all())
    fct = np.asarray(st.fct) + wl.t_start
    for s in range(n):
        mask = wl.src == s
        by_order = fct[mask][np.argsort(wl.order[mask], kind="stable")]
        assert np.all(np.diff(by_order) > 0), (s, by_order)


# ------------------------------------------------------------- permutation


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_permutation_is_derangement(seed):
    wl = workloads.permutation(SMALL, size_bytes=4 * 4096, seed=seed,
                               cross_rack=False)
    assert sorted(wl.dst.tolist()) == list(range(SMALL.n_nodes))
    assert np.all(wl.dst != wl.src)


@pytest.mark.parametrize("seed", [0, 5, 9])
def test_cross_rack_permutation_crosses_the_core(seed):
    m = SMALL.nodes_per_rack
    wl = workloads.permutation(SMALL, size_bytes=4 * 4096, seed=seed,
                               cross_rack=True)
    assert np.all(wl.dst // m != wl.src // m)
    assert sorted(wl.dst.tolist()) == list(range(SMALL.n_nodes))


def test_multi_permutation_stacks_independent_rounds():
    wl = workloads.permutation(SMALL, size_bytes=4 * 4096, seed=1, n_perms=3)
    n = SMALL.n_nodes
    assert wl.n_flows == 3 * n
    for p in range(3):
        sl = slice(p * n, (p + 1) * n)
        assert np.all(wl.dst[sl] != wl.src[sl])
        assert np.all(wl.order[sl] == p)


# ------------------------------------------------ sparse / heavy-tailed


def test_heavy_tailed_shape_and_sparsity():
    wl = workloads.heavy_tailed(SMALL, 64, size_base=16 * 1024,
                                size_cap=512 * 1024, gap_mean=500.0, seed=0)
    assert np.all(wl.src != wl.dst)
    assert np.all((wl.src >= 0) & (wl.src < SMALL.n_nodes))
    assert np.all((wl.size >= 1) & (wl.size <= 512 * 1024))
    assert wl.size.max() > 4 * wl.size.min()       # the tail is heavy
    assert wl.t_start[0] == 0
    assert np.all(np.diff(wl.t_start) >= 0)        # arrivals in time order
    # sparse: mean inter-arrival near the requested gap (law of large nums)
    mean_gap = float(wl.t_start[-1]) / (wl.n_flows - 1)
    assert 250.0 < mean_gap < 1000.0


def test_heavy_tailed_seed_reproducible():
    a = workloads.heavy_tailed(SMALL, 16, seed=7)
    b = workloads.heavy_tailed(SMALL, 16, seed=7)
    c = workloads.heavy_tailed(SMALL, 16, seed=8)
    np.testing.assert_array_equal(a.size, b.size)
    np.testing.assert_array_equal(a.t_start, b.t_start)
    assert not np.array_equal(a.size, c.size)


# -------------------------------------------------------------- validate


def _table(**overrides):
    base = dict(
        name="t", src=np.array([0, 1, 2], np.int32),
        dst=np.array([4, 5, 6], np.int32),
        size=np.array([4096, 8192, 4096], np.int32),
        t_start=np.array([0, 10, 20], np.int32),
        order=np.zeros(3, np.int32))
    base.update(overrides)
    return workloads.Workload(**base)


def test_validate_accepts_good_tables_and_chains():
    wl = _table()
    assert wl.validate(n_nodes=SMALL.n_nodes) is wl
    # every generator in this module produces a valid table
    for gen in (workloads.incast(SMALL, degree=4, size_bytes=4096),
                workloads.permutation(SMALL, size_bytes=4096),
                workloads.alltoall(SMALL, size_bytes=4096, window=2, nodes=4),
                workloads.heavy_tailed(SMALL, 8),
                workloads.staggered_large(SMALL, 3, 4096, 100)):
        gen.validate(n_nodes=SMALL.n_nodes)


def test_validate_rejects_self_talk_with_flow_index():
    wl = _table(dst=np.array([4, 1, 6], np.int32))       # flow 1: src == dst
    with pytest.raises(ValueError, match=r"\[1\].*src == dst"):
        wl.validate()


def test_validate_rejects_bad_sizes_and_starts():
    with pytest.raises(ValueError, match="non-positive size"):
        _table(size=np.array([4096, 0, 4096], np.int32)).validate()
    with pytest.raises(ValueError, match="negative t_start"):
        _table(t_start=np.array([0, -5, 20], np.int32)).validate()


def test_validate_rejects_out_of_range_nodes():
    with pytest.raises(ValueError, match="different topology"):
        _table(dst=np.array([4, 5, 99], np.int32)).validate(n_nodes=8)
    with pytest.raises(ValueError, match="different topology"):
        _table(src=np.array([-1, 1, 2], np.int32)).validate()


def test_validate_rejects_misaligned_and_empty_tables():
    with pytest.raises(ValueError, match="must align"):
        _table(size=np.array([4096, 4096], np.int32)).validate()
    with pytest.raises(ValueError, match="empty flow table"):
        _table(src=np.zeros(0, np.int32), dst=np.zeros(0, np.int32),
               size=np.zeros(0, np.int32), t_start=np.zeros(0, np.int32),
               order=np.zeros(0, np.int32)).validate()


def test_validate_rejects_windowed_start_order_mismatch():
    """With an active eligibility window, a later-ordered flow that starts
    earlier than its predecessor would sit blocked past its start tick —
    reject with the offending sender/flows named."""
    wl = _table(src=np.array([0, 0, 0], np.int32),
                dst=np.array([4, 5, 6], np.int32),
                t_start=np.array([0, 20, 10], np.int32),
                order=np.array([0, 1, 2], np.int32), window=2)
    with pytest.raises(ValueError, match="windowed sender 0"):
        wl.validate()
    # same table without windowing is fine (start order is free)
    _table(src=np.array([0, 0, 0], np.int32),
           t_start=np.array([0, 20, 10], np.int32),
           order=np.array([0, 1, 2], np.int32)).validate()
    # a decrease among a sender's first `window` flows is fine — those
    # can never accumulate `window` unfinished predecessors
    _table(src=np.array([0, 0, 0], np.int32),
           t_start=np.array([20, 10, 30], np.int32),
           order=np.array([0, 1, 2], np.int32), window=2).validate()
    # a sender the window cannot gate (<= window flows) may start in any
    # order, even while another sender's flow count activates windowing
    workloads.Workload(
        name="t", src=np.array([0, 0, 0, 1, 1], np.int32),
        dst=np.array([4, 5, 6, 7, 4], np.int32),
        size=np.full(5, 4096, np.int32),
        t_start=np.array([0, 10, 20, 30, 5], np.int32),
        order=np.array([0, 1, 2, 0, 1], np.int32), window=2).validate()


def test_engine_rejects_invalid_workload_via_derive():
    wl = _table(dst=np.array([0, 5, 6], np.int32))       # flow 0: src == dst
    with pytest.raises(ValueError, match="src == dst"):
        build(SimConfig(link=LINK, tree=SMALL), wl)


def test_staggered_large_disjoint_and_spaced():
    wl = workloads.staggered_large(SMALL, 4, 64 * 4096, gap_ticks=1000,
                                   seed=0)
    assert len(set(wl.src.tolist())) == 4          # distinct senders
    assert np.all(wl.src != wl.dst)
    m = SMALL.nodes_per_rack
    assert np.all(wl.dst // m != wl.src // m)      # cross-rack transfers
    np.testing.assert_array_equal(wl.t_start, 1000 * np.arange(4))
    with pytest.raises(ValueError):
        workloads.staggered_large(SMALL, SMALL.n_nodes, 4096, 10)
