"""Dependency-driven collectives (DESIGN.md Sec. 11): the oracle-backed
test layer.

Three independent lines of evidence pin the activation predicate
(``sender.activated``) to the workload's chunk DAG:

* a ~50-line host-side numpy reference (``oracle_rounds``) computes the
  dependency-release partial order of a random DAG with Kahn peeling;
  the engine's observed activation ticks must be a linearization of it,
  and the engine must never *emit* a flow before its release tick
  (checked on >= 20 seeded random DAGs, plus a hypothesis sweep when the
  test extra is installed);
* ring allreduce on an ideal uncongested fabric completes in exactly the
  analytic ``2(N-1) * (chunk_pkts - 1 + fwd) + ret`` ticks — the
  closed-form step count of the bucket algorithm;
* dep-free workloads are bit-for-bit unchanged: an explicit empty
  dependency table traces to the same graph as no table at all, and
  every pre-existing registered scenario reproduces the final-state
  digest recorded in ``tests/data/scenario_digests.json`` before the
  dependency machinery existed.

Validation error paths (cycles, range, thresholds) and the CCT metric
plumbing (``api.RunResult.cct`` -> ledger row) are covered here too.
"""

import dataclasses
import functools
import json
import pathlib
import platform
import sys

import jax
import numpy as np
import pytest

from repro.netsim import api, cache, collectives, scenarios, state, workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim.workloads import Workload

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st_h
    HAVE_HYPOTHESIS = True
except ImportError:              # local envs without the test extra
    HAVE_HYPOTHESIS = False

LINK = LinkConfig()
TREE4 = FatTreeConfig(racks=2, nodes_per_rack=2, uplinks=2)       # 4 nodes
MTU = LINK.mtu_bytes

# --------------------------------------------------------------------------
# random DAG workloads with frozen Dims (one compile for the whole sweep)
# --------------------------------------------------------------------------

_F, _D = 12, 2     # flows / max parents per flow — fixed so Dims are fixed


def _dag_wl(seed: int) -> Workload:
    """A random dependency DAG over a *fixed* traffic pattern.

    src/dst follow a balanced deterministic schedule (3 flows per sender,
    3 per receiver on the 4-node tree) so ``Dims`` — FMAX, FRMAX, W —
    are identical across seeds and all DAGs share one compiled step;
    only sizes, start ticks, and the dependency table randomize.
    Parents always have smaller flow ids, so the table is a DAG by
    construction."""
    rng = np.random.default_rng(seed)
    n = TREE4.n_nodes
    f_ids = np.arange(_F)
    src = (f_ids % n).astype(np.int32)
    dst = ((src + 1 + (f_ids // n) % (n - 1)) % n).astype(np.int32)
    size = (rng.integers(1, 4, _F) * MTU).astype(np.int32)
    t_start = rng.integers(0, 40, _F).astype(np.int32)
    dep_par = np.full((_F, _D), -1, np.int32)
    dep_thr = np.zeros((_F, _D), np.int32)
    for f in range(1, _F):
        for j in range(rng.integers(0, _D + 1)):
            p = int(rng.integers(0, f))
            if p in dep_par[f]:
                continue
            dep_par[f, j] = p
            dep_thr[f, j] = int(rng.integers(1, size[p] + 1))
    order = np.zeros(_F, np.int32)
    cnt: dict[int, int] = {}
    for f in range(_F):
        s = int(src[f])
        order[f] = cnt.get(s, 0)
        cnt[s] = order[f] + 1
    return Workload(name=f"dag{seed}", src=src, dst=dst, size=size,
                    t_start=t_start, order=order,
                    dep_par=dep_par, dep_thr=dep_thr)


@functools.lru_cache(maxsize=1)
def _dag_rig():
    """One compiled (step, trace) shared by every random-DAG case."""
    cfg = SimConfig(link=LINK, tree=TREE4)
    sim = build(cfg, _dag_wl(0))

    @functools.partial(jax.jit, static_argnums=2)
    def trace(consts, st0, ticks):
        def body(st, _):
            st2 = sim.step_fn(consts, st)
            return st2, (st2.goodput, st2.next_seq)
        return jax.lax.scan(body, st0, None, length=ticks)

    return cfg, sim, trace


def _run_dag(wl: Workload, ticks: int = 400):
    """(goodput[ticks, F], next_seq[ticks, F], final state) for one DAG,
    through the shared compiled step.  Index k = state after tick k."""
    cfg, sim, trace = _dag_rig()
    _, _, dims, consts = state.derive(cfg, wl)
    assert dims == sim.dims, "fixed traffic pattern must freeze Dims"
    fin, (gp, nseq) = trace(consts, state.init_state(dims, consts), ticks)
    return np.asarray(gp), np.asarray(nseq), jax.device_get(fin)


def oracle_rounds(dep_par: np.ndarray) -> np.ndarray:
    """Host-side numpy reference for the dependency-release partial
    order: round[f] = Kahn peel depth — 0 for dep-free flows, else
    1 + max over parents.  -1 marks flows stuck on (or behind) a cycle.
    The engine must activate flows in an order consistent with this:
    a flow's activation tick strictly after every parent's."""
    F, _ = dep_par.shape
    used = dep_par >= 0
    indeg = used.sum(axis=1)
    children = [[] for _ in range(F)]
    for f, j in zip(*np.nonzero(used)):
        children[int(dep_par[f, j])].append(int(f))
    rounds = np.where(indeg == 0, 0, -1)
    frontier = list(np.flatnonzero(indeg == 0))
    while frontier:
        p = frontier.pop()
        for c in children[p]:
            indeg[c] -= 1
            if indeg[c] == 0:
                rounds[c] = 1 + max(rounds[q] for q in dep_par[c] if q >= 0)
                frontier.append(c)
    return rounds


def _check_dag_property(seed: int):
    """The oracle property for one random DAG.

    * engine activation ticks (first tick every parent's goodput crossed
      its threshold, floored at t_start) are a linearization of the
      oracle partial order: strictly increasing along every edge;
    * the engine never emits a packet of a flow before that tick
      (``next_seq`` is independent evidence — it only moves in phase 5
      when ``sender.activated`` admitted the flow);
    * every flow still finishes (dependency gating never deadlocks a
      valid DAG)."""
    wl = _dag_wl(seed)
    wl.validate(n_nodes=TREE4.n_nodes)
    gp, nseq, fin = _run_dag(wl)
    assert bool(fin.done.all()), f"seed {seed}: DAG did not drain"

    ticks = gp.shape[0]
    rounds = oracle_rounds(wl.dep_par)
    assert (rounds >= 0).all()

    # activation tick: gp[k] is goodput after tick k; arrivals (phase 2)
    # precede sends (phase 5), so a threshold crossed during tick k
    # releases the child within tick k
    act = np.asarray(wl.t_start, np.int64).copy()
    for f in range(_F):
        for j in range(_D):
            p, thr = int(wl.dep_par[f, j]), int(wl.dep_thr[f, j])
            if p < 0:
                continue
            crossed = np.flatnonzero(gp[:, p] >= thr)
            assert crossed.size, f"seed {seed}: parent {p} never delivered"
            act[f] = max(act[f], int(crossed[0]))

    for f in range(_F):
        for p in wl.dep_par[f]:
            if p >= 0:
                assert act[f] > act[p], (
                    f"seed {seed}: flow {f} activated at {act[f]}, not "
                    f"after its parent {p} at {act[p]} — violates the "
                    f"oracle partial order (rounds {rounds[f]} > {rounds[p]})")

    # emission evidence: first next_seq movement is at or after activation
    first_emit = np.where((nseq >= 1).any(axis=0),
                          (nseq >= 1).argmax(axis=0), ticks)
    assert (first_emit < ticks).all(), f"seed {seed}: flow never emitted"
    early = first_emit < act
    assert not early.any(), (
        f"seed {seed}: flows {np.flatnonzero(early).tolist()} emitted "
        f"before their dependency release ticks")
    # dep-free flows start the moment the clock allows
    roots = (np.asarray(wl.dep_par) < 0).all(axis=1)
    assert (first_emit[roots] >= wl.t_start[roots]).all()


def test_oracle_partial_order_random_dags():
    """>= 20 seeded random DAGs against the numpy oracle (one compile)."""
    for seed in range(20):
        _check_dag_property(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(seed=st_h.integers(min_value=0, max_value=10_000))
    def test_oracle_partial_order_hypothesis(seed):
        _check_dag_property(seed)


# --------------------------------------------------------------------------
# analytic ring-allreduce CCT on an ideal fabric
# --------------------------------------------------------------------------


def test_ring_allreduce_cct_analytic():
    """On an uncongested 1:1 fabric with every ring edge in the same
    latency class (participants strided one per rack), the bucket
    algorithm's dependency chain serializes perfectly: each of the
    2(N-1) steps takes exactly (chunk_pkts - 1) serialization ticks plus
    the one-way delivery latency, and the recorded CCT lands the ACK
    return on top — no congestion term, no slack."""
    tree = scenarios.TREE_FLAT                       # 4 racks, 1:1
    n, chunk_pkts = 4, 3
    wl = collectives.ring_allreduce(tree, chunk_bytes=chunk_pkts * MTU,
                                    nodes=n, spread=True)
    sim = build(SimConfig(link=LINK, tree=tree), wl)
    st = jax.device_get(sim.run(max_ticks=8000, seed=0))
    assert bool(st.done.all())

    brtt = np.unique(np.asarray(sim.consts.cc.brtt))
    assert brtt.size == 1, "all ring edges must share one latency class"
    ret = int(np.asarray(sim.consts.ret))
    fwd = float(brtt[0]) - ret                       # one-way send->deliver
    steps = 2 * (n - 1)
    analytic = steps * (chunk_pkts - 1 + fwd) + ret

    finish = np.asarray(st.fct, np.int64) + np.asarray(sim.consts.t_start)
    cct = int(finish.max() - np.asarray(sim.consts.t_start).min())
    assert cct == analytic


# --------------------------------------------------------------------------
# generators: structure + registered scenarios
# --------------------------------------------------------------------------


def test_generator_structures():
    n = TREE4.n_nodes
    ring = collectives.ring_allreduce(TREE4, chunk_bytes=MTU, nodes=n)
    assert ring.n_flows == 2 * (n - 1) * n and ring.n_deps == 1
    ag = collectives.all_gather(TREE4, chunk_bytes=MTU, nodes=n)
    assert ag.n_flows == (n - 1) * n
    tr = collectives.tree_allreduce(TREE4, msg_bytes=MTU, nodes=n)
    assert tr.n_flows == 2 * (n - 1)
    pl = collectives.pipeline(TREE4, stage_bytes=MTU, stages=3,
                              microbatches=5)
    assert pl.n_flows == 2 * 5 and pl.n_deps == 1
    for wl in (ring, ag, tr, pl):
        wl.validate(n_nodes=n)                       # DAG checks pass
        assert wl.coll_id is not None and (wl.coll_id == 0).all()
    # strided participants stay inside the fabric and unique
    big = scenarios.TREE_128_3T
    spread = collectives.all_gather(big, chunk_bytes=MTU, nodes=64,
                                    spread=True)
    nodes = np.unique(np.concatenate([spread.src, spread.dst]))
    assert nodes.size == 64 and nodes.max() < big.n_nodes
    with pytest.raises(ValueError, match="2 <= nodes"):
        collectives.ring_allreduce(TREE4, chunk_bytes=MTU, nodes=1)
    with pytest.raises(ValueError, match="stages >= 2"):
        collectives.pipeline(TREE4, stage_bytes=MTU, stages=1,
                             microbatches=1)


def test_registered_collective_scenarios_build():
    """Every registered collective scenario derives (validate + shape
    math) without building the full step."""
    for name in ("tiny_allreduce_ring", "tiny_allgather", "tiny_pipeline",
                 "allreduce_ring_128n_3t", "allreduce_tree_128n_3t",
                 "allgather_64n_3t", "pipeline_32n"):
        sc = scenarios.scenario(name)
        _, _, dims, consts = state.derive(sc.cfg, sc.wl)
        assert dims.D >= 1
        assert consts.dep_par.shape == (dims.NF, dims.D)
        # lowering: -1 slots became the NF sentinel with threshold 0
        free = np.asarray(sc.wl.dep_par) < 0
        assert (np.asarray(consts.dep_par)[free] == dims.NF).all()
        assert (np.asarray(consts.dep_thr)[free] == 0).all()


# --------------------------------------------------------------------------
# CCT metric plumbing
# --------------------------------------------------------------------------


def test_cct_metric_and_row():
    r = api.run("tiny_allgather")
    assert r.all_done
    fin = r.fct.astype(np.int64) + r.t_start
    assert r.cct_by_coll == {0: int(fin.max() - r.t_start.min())}
    assert r.cct == r.cct_by_coll[0] > 0
    row = r.row()
    assert row["cct"] == r.cct and row["n_collectives"] == 1
    # unfinished collective reports the -1 sentinel, never a partial time
    r_cut = api.run("tiny_allgather", max_ticks=3)
    assert not r_cut.all_done and r_cut.cct == -1
    assert r_cut.row()["cct"] == -1
    # flow-list workloads keep their rows key-identical to before
    r_plain = api.run("tiny_perm4")
    assert r_plain.coll_id is None
    assert r_plain.cct == -1 and r_plain.cct_by_coll == {}
    assert "cct" not in r_plain.row()


# --------------------------------------------------------------------------
# dep-free bit-parity: empty table == no table, and the pre-PR digests
# --------------------------------------------------------------------------


def _state_digest(st) -> str:
    return cache.state_digest(jax.device_get(st))


def test_empty_dep_table_bitwise_identical():
    """An explicit [F, 0] dependency table lowers to D == 0 — the traced
    graph, and therefore the whole trajectory, is bitwise the legacy
    t_start-only one."""
    base = workloads.permutation(TREE4, size_bytes=8 * MTU, seed=1)
    withtab = dataclasses.replace(
        base, dep_par=np.zeros((base.n_flows, 0), np.int32),
        dep_thr=np.zeros((base.n_flows, 0), np.int32))
    cfg = SimConfig(link=LINK, tree=TREE4)
    digs = []
    for wl in (base, withtab):
        sim = build(cfg, wl)
        assert sim.dims.D == 0
        digs.append(_state_digest(sim.run(max_ticks=3000, seed=0)))
    assert digs[0] == digs[1]


_FIXTURE = pathlib.Path(__file__).parent / "data" / "scenario_digests.json"


@pytest.mark.slow
def test_dep_free_scenarios_digest_parity():
    """Every scenario registered before the dependency machinery existed
    reproduces the final-state digest captured on pre-PR main (same
    budgets, seed 0).  Guards the D == 0 path end to end: any bit the
    new admission predicate, Consts layout, or horizon changed for a
    dep-free workload shows up here.  Digests are platform/jax-version
    pinned; on other environments the fixture is skipped (the structural
    ``test_empty_dep_table_bitwise_identical`` still runs)."""
    doc = json.loads(_FIXTURE.read_text())
    env = f"{sys.platform}-{platform.machine()}"
    if doc["env"]["jax"] != jax.__version__ or \
            doc["env"]["platform"] != env:
        pytest.skip(f"digest fixture recorded on jax "
                    f"{doc['env']['jax']}/{doc['env']['platform']}, "
                    f"running {jax.__version__}/{env}")
    mismatches = []
    for name, want in sorted(doc["digests"].items()):
        sc = scenarios.scenario(name)
        assert sc.wl.n_deps == 0, f"{name} predates the dep table"
        sim = sc.build()
        got = _state_digest(sim.run(max_ticks=doc["budgets"][name],
                                    seed=doc["seed"]))
        if got != want:
            mismatches.append(name)
    assert not mismatches, (
        f"dep-free scenarios drifted from pre-dependency main: "
        f"{mismatches}")


# --------------------------------------------------------------------------
# validation error paths
# --------------------------------------------------------------------------


def _wl(dep_par=None, dep_thr=None, coll_id=None, **over):
    base = dict(
        name="t", src=np.array([0, 1, 2], np.int32),
        dst=np.array([1, 2, 0], np.int32),
        size=np.full(3, 4 * MTU, np.int32),
        t_start=np.zeros(3, np.int32), order=np.zeros(3, np.int32),
        dep_par=dep_par, dep_thr=dep_thr, coll_id=coll_id)
    base.update(over)
    return Workload(**base)


def _deps(*rows):
    par = np.array([[p for p, _ in r] for r in rows], np.int32)
    thr = np.array([[t for _, t in r] for r in rows], np.int32)
    return dict(dep_par=par, dep_thr=thr)


def test_validate_dep_partner_missing():
    with pytest.raises(ValueError, match="given together"):
        _wl(dep_par=np.zeros((3, 1), np.int32)).validate(n_nodes=4)


def test_validate_dep_shape_mismatch():
    with pytest.raises(ValueError, match="aligned"):
        _wl(dep_par=np.full((3, 2), -1, np.int32),
            dep_thr=np.zeros((3, 1), np.int32)).validate(n_nodes=4)
    with pytest.raises(ValueError, match="aligned"):
        _wl(dep_par=np.full((2, 1), -1, np.int32),
            dep_thr=np.zeros((2, 1), np.int32)).validate(n_nodes=4)


def test_validate_dep_parent_out_of_range():
    with pytest.raises(ValueError, match=r"flows \[1\].*outside \[0, 3\)"):
        _wl(**_deps([(-1, 0)], [(3, 1)], [(-1, 0)])).validate(n_nodes=4)


def test_validate_dep_self_dependency():
    with pytest.raises(ValueError, match=r"flows \[2\] depend on themselves"):
        _wl(**_deps([(-1, 0)], [(-1, 0)], [(2, 1)])).validate(n_nodes=4)


def test_validate_dep_threshold_bounds():
    # above the parent's size
    with pytest.raises(ValueError, match=r"\[1, parent size\]"):
        _wl(**_deps([(-1, 0)], [(0, 5 * MTU)], [(-1, 0)])).validate(n_nodes=4)
    # zero threshold on a real slot
    with pytest.raises(ValueError, match=r"\[1, parent size\]"):
        _wl(**_deps([(-1, 0)], [(0, 0)], [(-1, 0)])).validate(n_nodes=4)


def test_validate_dep_cycle():
    with pytest.raises(ValueError, match="dependency cycle"):
        _wl(**_deps([(2, 1)], [(0, 1)], [(1, 1)])).validate(n_nodes=4)
    # a 2-cycle hanging off a valid root names the stuck flows
    with pytest.raises(ValueError, match=r"flows \[1, 2\]"):
        _wl(**_deps([(-1, 0)], [(2, 1)], [(1, 1)])).validate(n_nodes=4)


def test_validate_coll_id():
    with pytest.raises(ValueError, match="coll_id must be 1-D"):
        _wl(coll_id=np.zeros((3, 1), np.int32)).validate(n_nodes=4)
    with pytest.raises(ValueError, match="coll_id < -1"):
        _wl(coll_id=np.array([0, -2, 0], np.int32)).validate(n_nodes=4)


def test_valid_dep_table_passes():
    wl = _wl(**_deps([(-1, 0)], [(0, MTU)], [(1, 4 * MTU)]),
             coll_id=np.array([0, 0, -1], np.int32))
    assert wl.validate(n_nodes=4) is wl
    assert wl.n_deps == 1
