"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
sweeping shapes and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.smartt import smartt_update
from repro.core.types import CCEvent, init_cc_state, make_cc_params
from repro.kernels.cc_update.ops import smartt_update_pallas
from repro.kernels.flash_attn.ops import gqa_flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.red_mark.kernel import red_mark
from repro.kernels.red_mark.ref import red_mark_ref
from repro.kernels.ssd_scan.ops import ssd, ssd_jnp
from repro.kernels.ssd_scan.ref import ssd_ref


# ------------------------------ cc_update ------------------------------


def _random_cc(F, seed):
    rng = np.random.default_rng(seed)
    brtt = np.where(rng.random(F) < 0.5, 26.0, 20.0).astype(np.float32)
    p = make_cc_params(mtu=4096.0, bdp=26 * 4096.0, brtt=brtt)
    s = init_cc_state(F, p)
    s = s._replace(
        cwnd=jnp.asarray(rng.uniform(4096, 133120, F), jnp.float32),
        acked=jnp.asarray(rng.uniform(0, 1e5, F), jnp.float32),
        qa_end=jnp.asarray(rng.choice([0.0, 10.0, 50.0], F), jnp.float32),
        trigger_qa=jnp.asarray(rng.random(F) < 0.3),
        bytes_to_ignore=jnp.asarray(rng.uniform(0, 5e4, F), jnp.float32),
        bytes_ignored=jnp.asarray(rng.uniform(0, 5e4, F), jnp.float32),
        fi_count=jnp.asarray(rng.uniform(0, 2e5, F), jnp.float32),
        fi_active=jnp.asarray(rng.random(F) < 0.2),
        avg_wtd=jnp.asarray(rng.uniform(0, 1, F), jnp.float32),
        ack_count=jnp.asarray(rng.integers(0, 100, F), jnp.int32))
    ev = CCEvent(
        has_ack=jnp.asarray(rng.random(F) < 0.7),
        ack_bytes=jnp.full((F,), 4096.0, jnp.float32),
        ecn=jnp.asarray(rng.random(F) < 0.4),
        rtt=jnp.asarray(rng.uniform(20, 80, F), jnp.float32),
        ack_entropy=jnp.zeros((F,), jnp.int32),
        n_trims=jnp.asarray(rng.integers(0, 3, F), jnp.int32),
        trim_bytes=jnp.asarray(rng.integers(0, 3, F) * 4096.0, jnp.float32),
        n_timeouts=jnp.asarray(rng.integers(0, 2, F), jnp.int32),
        to_bytes=jnp.asarray(rng.integers(0, 2, F) * 4096.0, jnp.float32),
        unacked=jnp.asarray(rng.uniform(0, 1e5, F), jnp.float32),
        credit_grant=jnp.zeros((F,), jnp.float32))
    return p, s, ev


@pytest.mark.parametrize("F", [1, 7, 128, 1000])
def test_cc_update_kernel_matches_oracle(F):
    p, s, ev = _random_cc(F, F)
    ref = smartt_update(p, s, ev, 42.0)
    out = smartt_update_pallas(p, s, ev, 42.0)
    for name in ("cwnd", "acked", "qa_end", "trigger_qa", "bytes_to_ignore",
                 "bytes_ignored", "fi_count", "fi_active", "avg_wtd",
                 "ack_count"):
        np.testing.assert_allclose(
            np.asarray(getattr(ref, name), np.float32),
            np.asarray(getattr(out, name), np.float32),
            rtol=1e-6, atol=1e-3, err_msg=f"F={F} field={name}")


# ------------------------------ red_mark ------------------------------


@pytest.mark.parametrize("Q", [5, 130, 1024])
@pytest.mark.parametrize("tick", [0, 17, 65535])
def test_red_mark_matches_oracle(Q, tick):
    rng = np.random.default_rng(Q + tick)
    qs = jnp.asarray(rng.integers(0, 27, Q), jnp.int32)
    ar = jnp.asarray(rng.integers(0, 6, Q), jnp.int32)
    got = red_mark(qs, ar, 26, 5.2, 20.8, tick, 0xECD)
    want = red_mark_ref(qs, ar, jnp.int32(26), jnp.float32(5.2),
                        jnp.float32(20.8), jnp.int32(tick), jnp.int32(0xECD))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_red_mark_probability_is_red_shaped():
    """Marking frequency rises ~linearly between kmin and kmax."""
    Q = 4096
    for q, lo, hi in ((4, 0.0, 0.01), (13, 0.4, 0.6), (25, 0.99, 1.01)):
        qs = jnp.full((Q,), q, jnp.int32)
        mark, _, _ = red_mark(qs, jnp.zeros((Q,), jnp.int32),
                              26, 5.2, 20.8, 3, 0xECD)
        frac = float(jnp.mean(mark.astype(jnp.float32)))
        assert lo <= frac <= hi, (q, frac)


# ------------------------------ flash_attn ------------------------------


@pytest.mark.parametrize("case", [
    (1, 2, 2, 128, 128, 64, True, 0, jnp.float32),
    (2, 4, 2, 256, 256, 32, True, 0, jnp.float32),
    (1, 2, 1, 128, 256, 64, True, 0, jnp.float32),
    (1, 2, 2, 128, 128, 64, True, 64, jnp.float32),
    (1, 2, 2, 64, 64, 16, False, 0, jnp.float32),
    (1, 2, 2, 128, 128, 64, True, 0, jnp.bfloat16),
])
def test_flash_attention_matches_oracle(case):
    b, hq, hkv, sq, sk, d, causal, win, dt = case
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dt)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dt)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dt)
    out = gqa_flash_attention(q, k, v, causal=causal, window=win)
    kr = jnp.repeat(k, hq // hkv, axis=1)
    vr = jnp.repeat(v, hq // hkv, axis=1)
    ref = attention_ref(q, kr, vr, causal=causal, window=win)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ------------------------------ ssd_scan ------------------------------


@pytest.mark.parametrize("case", [(2, 64, 16, 32, 16), (1, 128, 64, 128, 32),
                                  (3, 96, 8, 16, 48)])
def test_ssd_kernel_and_jnp_match_sequential(case):
    BH, L, P, N, chunk = case
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((BH, L, P)) * 0.5, jnp.float32)
    loga = jnp.asarray(-np.abs(rng.standard_normal((BH, L))) * 0.3, jnp.float32)
    B = jnp.asarray(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((BH, L, N)) * 0.3, jnp.float32)
    ref = ssd_ref(x, loga, B, C)
    np.testing.assert_allclose(np.asarray(ssd(x, loga, B, C, chunk=chunk)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ssd_jnp(x, loga, B, C, chunk=chunk)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
