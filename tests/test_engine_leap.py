"""Event-horizon time leaping (DESIGN.md Sec. 6.3): leap-on trajectories
must be bit-for-bit identical to leap-off across the *full* state pytree
(`now`, metrics counters, RTT histograms included) — the leap skips only
ticks that are state no-ops, it never approximates.  Covered regimes:
dense incast/permutation/alltoall on both CC backends, credit-based
grants, timeout recovery without trimming, faulted links, the sparse
heavy-tailed scenario the perf benchmark leans on, and the batched /
sweep run loops with their min-over-batch leap."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis import trace_guard
from repro.netsim import collectives, workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.sweep import build_sweep
from repro.netsim.units import FatTreeConfig, LinkConfig

TREE = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)
OVERSUB = FatTreeConfig(racks=2, nodes_per_rack=8, uplinks=2)   # 4:1
TREE3 = FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2,
                      pods=2, core_uplinks=1)                   # core 2:1
LINK = LinkConfig()


def _run(tree, wl, leap, max_ticks=30000, **kw):
    sim = build(SimConfig(link=LINK, tree=tree, leap=leap, **kw), wl)
    st = sim.run(max_ticks=max_ticks)
    st.now.block_until_ready()
    return sim, st


def _assert_state_equal(st_a, st_b):
    la, lb = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_leap_equal(tree, wl, max_ticks=30000, **kw):
    _, st_off = _run(tree, wl, leap=False, max_ticks=max_ticks, **kw)
    _, st_on = _run(tree, wl, leap=True, max_ticks=max_ticks, **kw)
    _assert_state_equal(st_off, st_on)
    return st_on


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_leap_bit_for_bit_incast(backend):
    wl = workloads.incast(TREE, degree=3, size_bytes=16 * 4096, seed=0)
    _assert_leap_equal(TREE, wl, cc_backend=backend)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_leap_bit_for_bit_oversubscribed_permutation(backend):
    """Trims, retransmissions, RED marking — the congested regime where a
    wrong horizon would skip a deliverable event."""
    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=1)
    st = _assert_leap_equal(OVERSUB, wl, cc_backend=backend)
    assert int(st.m.n_trim) > 0


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_leap_bit_for_bit_windowed_alltoall(backend):
    wl = workloads.alltoall(TREE, size_bytes=8 * 4096, window=2, nodes=6)
    _assert_leap_equal(TREE, wl, max_ticks=60000, cc_backend=backend)


def test_leap_bit_for_bit_pallas_fabric_transport():
    """Leap parity with the fabric enqueue-rank/arbitration and transport
    ring-drain kernels on the pallas backend: the leap's no-op-tick
    contract has to hold through the kernels' padded tiles too (a padded
    lane that wrote anything would break bitwise equality here)."""
    wl = workloads.permutation(OVERSUB, size_bytes=32 * 4096, seed=1)
    st = _assert_leap_equal(OVERSUB, wl, fabric_backend="pallas",
                            transport_backend="pallas")
    assert int(st.m.n_trim) > 0


def test_leap_bit_for_bit_sparse_heavy_tailed():
    """The perf target: spread-out arrivals with heavy-tailed sizes keep
    the fabric quiescent most of the span — exactly where the leap engine
    must skip thousands of ticks and still land on every event."""
    wl = workloads.heavy_tailed(TREE, 10, size_base=2 * 4096,
                                size_cap=64 * 4096, gap_mean=1200.0, seed=2)
    st = _assert_leap_equal(TREE, wl, max_ticks=40000)
    assert int(st.now) > 5000          # the span really is sparse


def test_leap_bit_for_bit_three_tier_sparse():
    """Three-tier fabric: the longer (cross-core) wire/control rings and
    the extra routed tiers must leave the horizon reductions exact."""
    wl = workloads.heavy_tailed(TREE3, 10, size_base=2 * 4096,
                                size_cap=64 * 4096, gap_mean=1200.0, seed=11)
    st = _assert_leap_equal(TREE3, wl, max_ticks=40000)
    assert int(st.now) > 5000          # the span really is sparse


def test_leap_bit_for_bit_three_tier_core_fault():
    """A dead core uplink forces blackhole -> RTO cycles across the T2
    plane; the timeout horizon must land the leap on every expiry."""
    wl = workloads.permutation(TREE3, size_bytes=64 * 4096, seed=3)
    st = _assert_leap_equal(TREE3, wl, faults=(("t1_up", 0, 0, 0),),
                            fault_start=0, max_ticks=40000)
    assert int(st.m.n_black) > 0 and int(st.m.n_to) > 0


def test_leap_lands_on_timeouts():
    """Without trimming, recovery is timeout-driven: the leap must land
    exactly on each RTO expiry (first tick strictly beyond send + rto)."""
    wl = workloads.incast(OVERSUB, degree=6, size_bytes=32 * 4096, seed=3)
    st = _assert_leap_equal(OVERSUB, wl, trimming=False)
    assert int(st.m.n_to) > 0          # timeouts actually fired


def test_leap_with_dead_link_timeout_cycles():
    """A blackholed uplink forces RTO -> retransmit cycles with long
    quiescent waits in between — the timeout-dominated leap regime."""
    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=4)
    st = _assert_leap_equal(OVERSUB, wl, faults=((0, 1, 0),),
                            fault_start=100)
    assert int(st.m.n_black) > 0 and int(st.m.n_to) > 0


def test_leap_with_degraded_link_service_periods():
    """A half-rate link services its queue every other tick; the horizon
    treats any occupied port as eventful, so the leap must stay exact."""
    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=5)
    _assert_leap_equal(OVERSUB, wl, faults=((0, 1, 2),), fault_start=0)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_leap_bit_for_bit_fault_schedule_multi_transition(backend):
    """A FaultSchedule with four transitions (fail -> degrade -> repair,
    plus an independent late kill) under a nonzero fault_start: the
    fault-transition clamp in ``fabric.horizon`` must stop every leap at
    each state change, on both CC backends (ISSUE 8 acceptance: >= 3
    transitions, leap-on == leap-off bitwise)."""
    from repro.netsim.faults import FaultEvent, FaultSchedule
    sched = FaultSchedule(events=(
        FaultEvent(t=0, kind="t1_up", i=0, j=0, period=0),
        FaultEvent(t=400, kind="t1_up", i=0, j=0, period=3),
        FaultEvent(t=900, kind="t1_up", i=0, j=0, period=1),
        FaultEvent(t=1200, kind="t2_down", i=0, j=1, period=0)))
    wl = workloads.permutation(TREE3, size_bytes=64 * 4096, seed=3)
    st = _assert_leap_equal(TREE3, wl, faults=sched, fault_start=60,
                            max_ticks=40000, cc_backend=backend)
    assert int(st.m.n_black) > 0


def test_leap_bit_for_bit_flapping_uplink():
    """A flapping uplink alternates dead/healthy on a fixed cycle; the
    clamp must stop leaps at every phase boundary inside the window and
    ignore the flap entirely outside it."""
    from repro.netsim.faults import Flap, FaultSchedule
    sched = FaultSchedule(flaps=(
        Flap(kind="t0_up", i=0, j=1, up=40, cycle=90, t=50, t_end=1000),))
    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=4)
    st = _assert_leap_equal(OVERSUB, wl, faults=sched, fault_start=30)
    assert int(st.m.n_black) > 0


def test_leap_bit_for_bit_recovery_transport():
    """RTO backoff + REPS timeout eviction under a fail-then-repair
    schedule: the timeout horizon reads the *backed-off* per-flow RTO, so
    the leap must land exactly on every delayed retry."""
    from repro.netsim.faults import FaultEvent, FaultSchedule
    sched = FaultSchedule(events=(
        FaultEvent(t=100, kind="t0_up", i=0, j=0, period=0),
        FaultEvent(t=100, kind="t0_up", i=0, j=1, period=0),
        FaultEvent(t=2500, kind="t0_up", i=0, j=0, period=1),
        FaultEvent(t=2500, kind="t0_up", i=0, j=1, period=1)))
    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=6)
    st = _assert_leap_equal(OVERSUB, wl, faults=sched,
                            rto_backoff_max=3, evict_on_timeout=True)
    # backoff itself ends at 0 (the post-repair ACKs reset it); the
    # timeout count proves the delayed retries actually happened
    assert int(st.m.n_to) > 0


def test_leap_bit_for_bit_eqds_grants():
    """Credit-based algorithms add the grant-demand and credit-ring
    horizons; sparse starts make the receiver pacing the only clock."""
    wl = workloads.heavy_tailed(TREE, 8, size_base=4 * 4096,
                                size_cap=32 * 4096, gap_mean=800.0, seed=6)
    _assert_leap_equal(TREE, wl, algo="eqds", max_ticks=40000)
    _assert_leap_equal(TREE, wl, algo="eqds_smartt", max_ticks=40000)


@pytest.mark.parametrize("algo", ["swift", "mprdma", "ecn_only",
                                  "delay_only"])
def test_leap_bit_for_bit_baseline_algorithms(algo):
    """Dims.leap's contract — the CC choice mutates no state on event-free
    ticks — is per-algorithm: every non-paced baseline the figure suite
    runs leap-on must stay bitwise equal, so a future time-dependent term
    added to one of them fails here instead of silently skewing figures."""
    wl = workloads.heavy_tailed(TREE, 6, size_base=2 * 4096,
                                size_cap=32 * 4096, gap_mean=600.0, seed=8)
    _assert_leap_equal(TREE, wl, algo=algo, max_ticks=20000)


@pytest.mark.parametrize("lb", ["spray", "ecmp"])
def test_leap_bit_for_bit_other_load_balancers(lb):
    """Same contract for the LB hooks that keep leaping enabled (PLB is
    excluded statically; REPS is covered by every other test here)."""
    wl = workloads.heavy_tailed(TREE, 6, size_base=2 * 4096,
                                size_cap=32 * 4096, gap_mean=600.0, seed=9)
    _assert_leap_equal(TREE, wl, lb=lb, max_ticks=20000)


def test_leap_forced_off_for_paced_and_plb():
    """Rate pacing accrues budget every tick and PLB rolls its round clock
    on wall time — event-free ticks are not no-ops there, so the leap must
    be statically disabled no matter the knob."""
    wl = workloads.incast(TREE, degree=3, size_bytes=16 * 4096, seed=0)
    assert not build(SimConfig(link=LINK, tree=TREE, algo="bbr",
                               leap=True), wl).dims.leap
    assert not build(SimConfig(link=LINK, tree=TREE, lb="plb",
                               leap=True), wl).dims.leap
    assert build(SimConfig(link=LINK, tree=TREE, leap=True), wl).dims.leap


def test_leap_run_batch_per_lane_horizons():
    """Batched lanes leap independently (each by its own horizon, frozen
    once done — api._run_lanes); every lane must match its leap-off
    twin bit-for-bit."""
    wl = workloads.heavy_tailed(OVERSUB, 8, size_base=4 * 4096,
                                size_cap=64 * 4096, gap_mean=900.0, seed=7)
    sim_on = build(SimConfig(link=LINK, tree=OVERSUB, leap=True), wl)
    sim_off = build(SimConfig(link=LINK, tree=OVERSUB, leap=False), wl)
    st_on = sim_on.run_batch(np.arange(4), max_ticks=40000)
    st_off = sim_off.run_batch(np.arange(4), max_ticks=40000)
    _assert_state_equal(st_off, st_on)


def test_run_batch_builds_one_init_and_broadcasts():
    """Satellite contract: run_batch derives a single init state and
    broadcasts it over the batch, scattering only the per-seed salt."""
    wl = workloads.incast(TREE, degree=3, size_bytes=16 * 4096, seed=0)
    sim = build(SimConfig(link=LINK, tree=TREE), wl)
    with trace_guard("state.init", expect=1):
        st = sim.run_batch(np.arange(5), max_ticks=30000)
        st.now.block_until_ready()
    np.testing.assert_array_equal(np.asarray(st.salt), np.arange(5))


def test_leap_sweep_per_point_horizons():
    """The sweep leap evaluates each grid point's horizon under its own
    swept Consts (different RTOs / start windows!) and each lane jumps by
    its own distance (api._run_lanes)."""
    wl = workloads.incast(TREE, degree=4, size_bytes=32 * 4096, seed=1)
    points = [{"start_cwnd_mult": a, "rto_mult": r}
              for a, r in ((0.5, 3.0), (1.25, 5.0))]
    st_on = build_sweep(SimConfig(link=LINK, tree=TREE, leap=True),
                        wl, points).run(max_ticks=30000)
    st_off = build_sweep(SimConfig(link=LINK, tree=TREE, leap=False),
                         wl, points).run(max_ticks=30000)
    _assert_state_equal(st_off, st_on)


def test_leap_bit_for_bit_dependency_gated_ring_allreduce():
    """Dependency-gated activation (DESIGN.md Sec. 11): the horizon
    shares ``sender.activated`` with admission, and threshold crossings
    ride on deliveries the fabric horizon already bounds — so leap-on
    must stay bitwise equal through a full ring allreduce whose every
    flow past step 0 is released by a parent's chunk landing."""
    wl = collectives.ring_allreduce(TREE3, chunk_bytes=4 * 4096, nodes=8)
    st = _assert_leap_equal(TREE3, wl, max_ticks=40000)
    assert bool(np.asarray(st.done).all())


def test_leap_bit_for_bit_dependency_chain_sparse():
    """A staggered pipeline chain: activation alternates between
    start-clamped waits (t_start far beyond the dependency release) and
    dep-driven releases, with multi-thousand-tick quiescent stretches in
    between — the regime where an unclamped dependency term would let
    the leap overshoot a release tick."""
    pl = collectives.pipeline(TREE, stage_bytes=8 * 4096, stages=4,
                              microbatches=2)
    wl = dataclasses.replace(
        pl, t_start=(3000 * np.arange(pl.n_flows)).astype(np.int32))
    st = _assert_leap_equal(TREE, wl, max_ticks=40000)
    assert bool(np.asarray(st.done).all())
    assert int(st.now) > 5000          # the span really is sparse
