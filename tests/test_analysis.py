"""The static-analysis layer analyzes itself honestly: every jaxpr rule
trips on a known-bad toy program, every lint rule trips on a known-bad
source snippet, and the real catalogue passes with zero unallowlisted
findings (DESIGN.md Sec. 10).
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit, lint, rules, trace_guard
from repro.analysis.trace_guard import counter


def _rules_of(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------------------
# jaxpr rules trip on deliberately bad programs
# --------------------------------------------------------------------------


def test_jx001_f64_leak_trips():
    from jax.experimental import enable_x64
    with enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(np.zeros(4, np.float32))
    found = audit.check_jaxpr(closed, "toy/f64")
    assert "JX001" in _rules_of(found)
    assert any("float64" in f.token for f in found)


def test_jx001_clean_x32_program():
    closed = jax.make_jaxpr(lambda x: x * 2.0)(np.zeros(4, np.float32))
    assert "JX001" not in _rules_of(audit.check_jaxpr(closed, "toy"))


def test_jx002_convert_chain_trips():
    # bool -> int32 -> float32: the middle cast is collapsible
    closed = jax.make_jaxpr(
        lambda x: x.astype(jnp.int32).astype(jnp.float32)
    )(np.zeros(4, bool))
    found = audit.check_jaxpr(closed, "toy/chain")
    assert "JX002" in _rules_of(found)


def test_jx002_lossy_chain_not_flagged():
    # f32 -> i32 -> f32 truncates: semantics, not churn
    closed = jax.make_jaxpr(
        lambda x: x.astype(jnp.int32).astype(jnp.float32)
    )(np.zeros(4, np.float32))
    assert "JX002" not in _rules_of(audit.check_jaxpr(closed, "toy"))


def test_jx003_host_callback_trips():
    def step(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((4,), np.float32),
            x)
    closed = jax.make_jaxpr(step)(np.zeros(4, np.float32))
    found = audit.check_jaxpr(closed, "toy/callback")
    assert "JX003" in _rules_of(found)
    assert any(f.token == "pure_callback" for f in found)


def test_jx004_aliased_donation_trips():
    x = jnp.zeros(8)
    found = audit.check_donation((x, x, jnp.zeros(8)), "toy/donate")
    assert "JX004" in _rules_of(found)
    assert len(found) == 1           # one alias pair, third leaf is fresh


def test_jx004_fresh_buffers_clean():
    assert audit.check_donation(
        (jnp.zeros(8), jnp.zeros(8)), "toy") == []


def test_jx005_scatter_blowup_trips():
    def blowup(x):
        for i in range(6):
            x = jax.lax.dynamic_update_slice(x, jnp.ones(1), (i,))
        return x
    closed = jax.make_jaxpr(blowup)(np.zeros(16, np.float32))
    found = audit.check_jaxpr(closed, "toy/scatter",
                              budgets={"scatter": 3})
    assert "JX005" in _rules_of(found)
    # within budget: clean
    assert audit.check_jaxpr(closed, "toy", budgets={"scatter": 6}) == []


def test_op_stats_counts_and_recurses():
    def fn(x):
        def body(_, s):
            return jax.lax.dynamic_update_slice(s, jnp.ones(1), (0,))
        return jax.lax.fori_loop(0, 4, body, x)
    st = audit.op_stats(jax.make_jaxpr(fn)(np.zeros(8, np.float32)))
    assert st.scatter >= 1           # found inside the loop body jaxpr
    assert st.eqns > 1
    assert st.est_bytes > 0


# --------------------------------------------------------------------------
# JX006 — classification drift detector
# --------------------------------------------------------------------------


def test_jx006_catches_misclassified_static_key(monkeypatch):
    from repro.netsim import api
    # pretend a Dims-changing knob were sweepable: JX006 must object
    monkeypatch.setattr(api, "CFG_KEYS",
                        frozenset(api.CFG_KEYS | {"superstep"}))
    found = audit.classify_config()
    assert any(f.rule == "JX006" and f.token == "superstep" for f in found)


def test_jx006_clean_on_real_classification():
    assert [str(f) for f in audit.classify_config()
            if not f.allowlisted] == []


# --------------------------------------------------------------------------
# lint rules trip on deliberately bad sources
# --------------------------------------------------------------------------


def test_jx101_signature_drift_trips(tmp_path):
    kdir = tmp_path / "toy_kernel"
    kdir.mkdir()
    (kdir / "ref.py").write_text(textwrap.dedent("""\
        def toy_ref(a, b, c):
            return a + b + c
    """))
    (kdir / "kernel.py").write_text(textwrap.dedent("""\
        def toy(a, c, b):
            return a + b + c
    """))
    found = lint.check_kernel_parity(tmp_path)
    assert _rules_of(found) == {"JX101"}


def test_jx101_kwonly_statics_are_parity(tmp_path):
    kdir = tmp_path / "toy_kernel"
    kdir.mkdir()
    (kdir / "ref.py").write_text("def toy_ref(a, b, cap):\n    return a\n")
    (kdir / "kernel.py").write_text(
        "def toy(a, b, *, cap, interpret=True):\n    return a\n")
    assert lint.check_kernel_parity(tmp_path) == []


def test_jx102_unregistered_scenario_trips(tmp_path):
    bench = tmp_path / "BENCH_netsim.json"
    bench.write_text(
        '{"schema": 1, "sections": {"perf": {"rows": '
        '[{"name": "no_such_scenario/jnp/k40", "ticks_per_sec": 1}]}}}')
    found = lint.check_ledger_keys(bench)
    assert _rules_of(found) == {"JX102"}
    assert found[0].token == "no_such_scenario"


def test_jx103_unseeded_random_trips(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""\
        import numpy as np
        def jitter(n):
            return np.random.rand(n)
        def ok(n, seed):
            return np.random.default_rng(seed).random(n)
    """))
    found = lint.check_random(bad)
    assert len(found) == 1
    assert found[0].rule == "JX103"
    assert "np.random.rand" in found[0].token


def test_jx104_traced_truthiness_trips(tmp_path):
    bad = tmp_path / "phase.py"
    bad.write_text(textwrap.dedent("""\
        def control(dims, consts, st):
            if st.now > 5:
                return st
            if dims.trimming:      # static branch: fine
                pass
            return st
    """))
    found = lint.check_truthiness(bad)
    assert len(found) == 1
    assert found[0].rule == "JX104"
    assert "st.now" in found[0].token


def test_jx105_device_math_on_host_path_trips(tmp_path):
    bad = tmp_path / "topo.py"
    bad.write_text(textwrap.dedent("""\
        import jax.numpy as jnp
        import numpy as np
        def build(n):
            return jnp.arange(n)
        def traced_fn(n):
            return jnp.arange(n)
    """))
    found = lint.check_host_purity(bad)
    assert _rules_of(found) == {"JX105"}
    # the traced exemption works
    assert len(lint.check_host_purity(
        bad, traced_functions=("traced_fn",))) == 1


def test_noqa_suppresses_a_lint_finding(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import numpy as np\n"
                   "x = np.random.rand(3)  # noqa: JX103\n"
                   "y = np.random.rand(3)\n")
    found = lint.check_random(bad)
    assert len(found) == 1
    assert found[0].site.endswith(":3")


# --------------------------------------------------------------------------
# allowlist mechanics
# --------------------------------------------------------------------------


def test_allowlist_matches_and_justifies():
    f = rules.finding("JX101", "kernels/cc_update",
                      "cc_update_ref|cc_update", "drift")
    assert f.allowlisted and rules.ALLOWLIST[f.allowed_by]
    f2 = rules.finding("JX101", "kernels/other", "x|y", "drift")
    assert not f2.allowlisted


def test_every_allowlist_entry_has_a_justification():
    for key, why in rules.ALLOWLIST.items():
        assert len(key.split(":", 2)) == 3, key
        assert why.strip(), f"empty justification for {key}"


# --------------------------------------------------------------------------
# trace_guard — the shared trace-counting contract
# --------------------------------------------------------------------------


def test_trace_guard_counts_and_expects():
    c = counter("test.analysis.guard")
    with trace_guard("test.analysis.guard") as g:
        c.hit()
        c.hit()
    assert g.count == 2
    with pytest.raises(AssertionError, match="expected 1"):
        with trace_guard("test.analysis.guard", expect=1):
            c.hit()
            c.hit()


def test_trace_guard_nested_windows_are_independent():
    c = counter("test.analysis.nested")
    with trace_guard("test.analysis.nested") as outer:
        c.hit()
        with trace_guard("test.analysis.nested", expect=1) as inner:
            c.hit()
        assert inner.count == 1
    assert outer.count == 2


# --------------------------------------------------------------------------
# the real repository is clean
# --------------------------------------------------------------------------


def test_lint_repo_self_clean():
    bad = [f for f in lint.lint_repo() if not f.allowlisted]
    assert bad == [], "\n".join(map(str, bad))


def test_audit_small_scenarios_self_clean():
    from repro.netsim.scenarios import scenario
    for name in ("tiny_3t", "tiny_perm4"):
        findings, rows = audit.audit_scenario(scenario(name))
        bad = [f for f in findings if not f.allowlisted]
        assert bad == [], "\n".join(map(str, bad))
        # the ledger rows carry the budgeted op families
        programs = {r["program"] for r in rows}
        assert {"init", "departures", "arrivals", "control", "grants",
                "sends", "metrics", "step", "horizon"} <= programs


@pytest.mark.slow
def test_audit_full_catalogue_self_clean():
    findings, rows = audit.audit_catalogue()
    bad = [f for f in findings if not f.allowlisted]
    assert bad == [], "\n".join(map(str, bad))
    names = {r["name"] for r in rows}
    # the paper-scale scenario records per-phase budget rows
    assert "perm_512n_3t/jnp/arrivals" in names
    assert "perm_512n_3t/pallas/step" in names
