"""Netsim integration tests: timing exactness, conservation, and the
paper's qualitative claims at reduced scale.  Runs go through the
experiment API (``api.run`` -> ``RunResult``; its ``summary()`` keeps
the historical ``metrics.summarize`` dict shape)."""

import numpy as np

from repro.netsim import api, workloads
from repro.netsim.engine import SimConfig, build, jain_fairness, summarize
from repro.netsim.scenarios import Scenario
from repro.netsim.units import FatTreeConfig, LinkConfig, derive_timing

LINK = LinkConfig()
SMALL = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=4)   # non-blocking
OVERSUB = FatTreeConfig(racks=2, nodes_per_rack=8, uplinks=2)  # 4:1


def run(tree, wl, **kw):
    max_ticks = kw.pop("max_ticks", 60000)
    sc = Scenario(name=wl.name, cfg=SimConfig(link=LINK, tree=tree, **kw),
                  wl=wl, max_ticks=max_ticks)
    r = api.run(sc)
    return r, r.state, r.summary()


def test_empty_network_rtt_equals_brtt():
    """A lone cross-rack flow must measure exactly the analytic base RTT."""
    wl = workloads.permutation(SMALL, size_bytes=16 * 4096, seed=0)
    sim, st, s = run(SMALL, wl, algo="smartt", lb="ecmp",
                     cc_overrides=(("fd", 0.0),))
    hist = s["rtt_hist"]
    # bin width is brtt/8; an uncongested network keeps RTT in [brtt, 2brtt)
    first_bin = np.nonzero(hist)[0][0]
    assert first_bin == 8, (first_bin, hist[:20])


def test_single_flow_fct_is_ideal():
    wl = workloads.Workload(
        name="one", src=np.array([0], np.int32), dst=np.array([4], np.int32),
        size=np.array([64 * 4096], np.int32), t_start=np.zeros(1, np.int32),
        order=np.zeros(1, np.int32))
    tm = derive_timing(LINK)
    sim, st, s = run(SMALL, wl, algo="smartt")
    # 64 packets back-to-back + one-way + ack return
    ideal = 63 + tm.fwd_inter + tm.ret_inter
    assert s["fct_max"] <= ideal + 2, (s["fct_max"], ideal)


def test_conservation_and_completion():
    """Unique goodput == flow size for every flow; all flows finish."""
    wl = workloads.permutation(OVERSUB, size_bytes=128 * 4096, seed=1)
    sim, st, s = run(OVERSUB, wl, algo="smartt")
    assert s["all_done"]
    np.testing.assert_array_equal(s["goodput_bytes"], wl.size)
    assert np.all(s["fct_ticks"] > 0)


def test_trims_only_under_pressure():
    """A single unconstrained flow must see zero trims/drops/timeouts."""
    wl = workloads.Workload(
        name="one", src=np.array([0], np.int32), dst=np.array([5], np.int32),
        size=np.array([256 * 4096], np.int32), t_start=np.zeros(1, np.int32),
        order=np.zeros(1, np.int32))
    sim, st, s = run(SMALL, wl, algo="smartt")
    assert s["trims"] == 0 and s["drops"] == 0 and s["timeouts"] == 0


def test_incast_fairness_and_ideal_time():
    deg, pkts = 8, 64
    wl = workloads.incast(SMALL, degree=deg - 1, size_bytes=pkts * 4096, seed=2)
    sim, st, s = run(SMALL, wl, algo="smartt")
    ideal = (deg - 1) * pkts + 26
    assert s["all_done"]
    assert s["completion" if "completion" in s else "fct_max"] if False else True
    assert s["fct_max"] <= ideal * 1.15, (s["fct_max"], ideal)
    assert s["jain"] if "jain" in s else True
    fd = s["fct_ticks"][np.asarray(st.done)]
    assert jain_fairness(fd) > 0.95


def test_eqds_incast_near_perfect():
    """Paper Sec. 4.3: receiver-driven EQDS nails incast fairness."""
    wl = workloads.incast(SMALL, degree=6, size_bytes=64 * 4096, seed=3)
    sim, st, s = run(SMALL, wl, algo="eqds")
    fd = s["fct_ticks"][np.asarray(st.done)]
    assert s["all_done"]
    assert jain_fairness(fd) > 0.99


def test_eqds_wastes_bandwidth_on_fabric_congestion():
    """Paper Sec. 4.4: vanilla EQDS trims far more than SMaRTT when the
    core is oversubscribed."""
    wl = workloads.permutation(OVERSUB, size_bytes=128 * 4096, seed=4)
    _, _, s_eqds = run(OVERSUB, wl, algo="eqds")
    _, _, s_sm = run(OVERSUB, wl, algo="smartt")
    assert s_eqds["trims"] > 3 * s_sm["trims"], (s_eqds["trims"], s_sm["trims"])


def test_timeout_fallback_close_to_trimming():
    """Paper Sec. 4.2 / Fig. 8: losing trimming costs ~1-3 base RTTs in the
    paper's regime (incast of BDP-scale flows). Small-flow regimes pay more
    (serial RTO recovery), so this test uses the paper-matched shape."""
    tree = FatTreeConfig(racks=4, nodes_per_rack=8, uplinks=8)
    wl = workloads.incast(tree, degree=16, size_bytes=128 * 4096, seed=5)
    _, _, s_trim = run(tree, wl, algo="smartt", trimming=True)
    _, _, s_to = run(tree, wl, algo="smartt", trimming=False)
    assert s_to["all_done"]
    brtt = 26
    assert s_to["fct_max"] - s_trim["fct_max"] <= 4 * brtt, \
        (s_to["fct_max"], s_trim["fct_max"])
    assert s_to["spurious_frac"] < 0.02


def test_reps_beats_spray_on_asymmetric_link():
    """Paper Fig. 7a: REPS absorbs a half-rate uplink."""
    wl = workloads.permutation(SMALL, size_bytes=128 * 4096, seed=6)
    _, _, s_reps = run(SMALL, wl, algo="smartt", lb="reps",
                       faults=((0, 1, 2),), fault_start=0)
    _, _, s_spray = run(SMALL, wl, algo="smartt", lb="spray",
                        faults=((0, 1, 2),), fault_start=0)
    assert s_reps["fct_max"] < s_spray["fct_max"]


def test_reps_survives_link_failure():
    """Paper Fig. 7c: flows complete despite a dead uplink; spray
    blackholes more packets."""
    wl = workloads.permutation(SMALL, size_bytes=128 * 4096, seed=7)
    _, _, s_reps = run(SMALL, wl, algo="smartt", lb="reps",
                       faults=((0, 1, 0),), fault_start=100)
    _, _, s_spray = run(SMALL, wl, algo="smartt", lb="spray",
                        faults=((0, 1, 0),), fault_start=100)
    assert s_reps["blackholed"] < s_spray["blackholed"]
    assert s_reps["fct_max"] > 0           # still completed


def test_windowed_alltoall_completes():
    wl = workloads.alltoall(SMALL, size_bytes=16 * 4096, window=3, nodes=8)
    sim, st, s = run(SMALL, wl, algo="smartt", max_ticks=200000)
    assert s["all_done"]
    np.testing.assert_array_equal(s["goodput_bytes"], wl.size)


def test_trace_mode_matches_aggregate_run():
    """run_trace produces per-tick series consistent with the aggregate
    runner: same deliveries, monotone cumulative counters, sane cwnds."""
    cfg = SimConfig(link=LINK, tree=SMALL, algo="smartt", lb="reps")
    wl = workloads.incast(SMALL, degree=4, size_bytes=32 * 4096, seed=9)
    sim = build(cfg, wl)
    ticks = 600
    st, ys = sim.run_trace(ticks, trace_flows=4)
    delivered = np.asarray(ys["delivered"])
    assert np.all(np.diff(delivered) >= 0)                 # cumulative
    assert float(delivered[-1]) == 4 * 32 * 4096           # all bytes in
    cwnd = np.asarray(ys["cwnd"])
    assert cwnd.shape == (ticks, 4)
    assert np.all(cwnd >= 4096 - 1) and np.all(np.isfinite(cwnd))
    st2 = sim.run(max_ticks=ticks)
    s2 = summarize(sim, st2)
    assert float(delivered[-1]) == s2["delivered_bytes"]
