"""Experiment API (DESIGN.md Sec. 7): the declarative Scenario/Study
entry point must lower a {point x seed} grid onto ONE compiled step while
keeping every lane bit-for-bit equal to its standalone execution — and
the legacy entry points (``engine.build(...).run``, ``build_sweep``) must
stay exact wrappers over the same machinery."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis import trace_guard
from repro.netsim import api, engine, scenarios, workloads
from repro.netsim.api import apply_point
from repro.netsim.scenarios import Scenario, scenario
from repro.netsim.state import SimConfig
from repro.netsim.sweep import build_sweep
from repro.netsim.units import FatTreeConfig, LinkConfig

TREE = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)
LINK = LinkConfig()

POINTS = ({}, {"start_cwnd_mult": 0.5}, {"rto_mult": 5.0},
          {"start_cwnd_mult": 0.75, "react_every": 4})
SEEDS = (0, 1, 2, 3)
MAX_TICKS = 30_000


def _scenario(leap=True, **cfg_kw) -> Scenario:
    wl = workloads.incast(TREE, degree=4, size_bytes=32 * 4096, seed=1)
    return Scenario(name="t_incast4",
                    cfg=SimConfig(link=LINK, tree=TREE, leap=leap, **cfg_kw),
                    wl=wl, max_ticks=MAX_TICKS)


def _assert_state_equal(st_a, st_b):
    la, lb = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _lane(states, i):
    return jax.tree.map(lambda x: x[i], states)


# --------------------------------------------------------------------------
# acceptance: one compile, per-lane bitwise equivalence (leap on and off)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("leap", [True, False])
def test_study_one_compile_and_lanes_match_standalone(leap):
    """A >=4-point x >=4-seed Study compiles exactly one step, and every
    lane's final state equals the standalone ``Sim.run`` of that
    (point, seed) across the FULL SimState pytree — ``now``, metrics
    counters, and RTT histograms included."""
    sc = _scenario(leap=leap)
    st_obj = api.study(sc, points=POINTS, seeds=SEEDS)
    assert st_obj.n_lanes == len(POINTS) * len(SEEDS)

    with trace_guard("engine.step", expect=1):
        res = st_obj.run()

    for pi, pt in enumerate(POINTS):
        cfg_i = apply_point(sc.cfg, pt)
        sim_i = engine.build(cfg_i, sc.wl)
        assert sim_i.dims.leap == leap
        for si, seed in enumerate(SEEDS):
            st_i = sim_i.run(max_ticks=MAX_TICKS, seed=seed)
            _assert_state_equal(st_i,
                                _lane(res.states, pi * len(SEEDS) + si))
            # the typed lane result reflects the same run
            r = res.lane(pi, si)
            assert r.seed == seed and dict(r.point) == pt
            assert r.ticks == int(st_i.now)
            np.testing.assert_array_equal(r.fct, np.asarray(st_i.fct))


def test_study_lanes_match_standalone_three_tier():
    """Same per-lane bitwise contract on a three-tier scenario: the lane
    loop's per-lane horizons/exits must stay exact with core-path routing
    and the longer cross-core rings."""
    sc = scenario("tiny_3t")
    points = ({}, {"start_cwnd_mult": 0.5})
    seeds = (0, 3)
    res = api.study(sc, points=points, seeds=seeds).run()
    for pi, pt in enumerate(points):
        sim_i = engine.build(apply_point(sc.cfg, pt), sc.wl)
        assert sim_i.dims.tiers == 3
        for si, seed in enumerate(seeds):
            st_i = sim_i.run(max_ticks=sc.max_ticks, seed=seed)
            _assert_state_equal(st_i,
                                _lane(res.states, pi * len(seeds) + si))


def test_build_sweep_lanes_match_study():
    """Compatibility wrapper: ``build_sweep`` runs the same lane loop, so
    its [P] states are bit-identical to the seed-0 lanes of a Study over
    the same points (and therefore to standalone builds)."""
    sc = _scenario()
    states_sweep = build_sweep(sc.cfg, sc.wl, list(POINTS)).run(
        max_ticks=MAX_TICKS)
    res = api.study(sc, points=POINTS, seeds=(0, 1)).run()
    for pi in range(len(POINTS)):
        _assert_state_equal(_lane(states_sweep, pi),
                            _lane(res.states, pi * 2))


def test_run_batch_matches_study_seed_lanes():
    """Compatibility wrapper: ``Sim.run_batch`` is the seeds-only Study —
    bit-identical states, including per-lane ``now``."""
    sc = _scenario()
    sim = engine.build(sc.cfg, sc.wl)
    stb = sim.run_batch(np.asarray(SEEDS), max_ticks=MAX_TICKS)
    res = api.study(sc, seeds=SEEDS).run()
    _assert_state_equal(stb, res.states)
    for si, seed in enumerate(SEEDS):
        st_i = sim.run(max_ticks=MAX_TICKS, seed=seed)
        _assert_state_equal(st_i, _lane(stb, si))


def test_study_single_init_trace():
    """The [P*S] lane batch comes from ONE vmapped init_state trace."""
    st_obj = api.study(_scenario(), points=POINTS, seeds=SEEDS)
    with trace_guard("state.init", expect=1):
        states = st_obj.init()
    np.testing.assert_array_equal(
        np.asarray(states.salt), np.tile(SEEDS, len(POINTS)))


# --------------------------------------------------------------------------
# planner validation
# --------------------------------------------------------------------------


def test_study_rejects_dims_changing_and_unknown_keys():
    sc = _scenario()
    with pytest.raises(KeyError, match="changes Dims"):
        api.study(sc, points=[{"superstep": 4}])
    with pytest.raises(KeyError, match="changes Dims"):
        api.study(sc, points=[{"trimming": 0.0}])
    with pytest.raises(KeyError, match="unsweepable"):
        api.study(sc, points=[{"quantum_entanglement": 1.0}])
    with pytest.raises(ValueError, match="empty sweep"):
        api.study(sc, points=[])
    with pytest.raises(ValueError, match="empty seeds"):
        api.study(sc, seeds=[])


def test_study_validates_workload_up_front():
    """A bad flow table fails at plan time with an actionable message,
    not deep inside tracing."""
    bad = workloads.Workload(
        name="bad", src=np.array([0, 1], np.int32),
        dst=np.array([0, 2], np.int32),          # flow 0: src == dst
        size=np.array([4096, 4096], np.int32),
        t_start=np.zeros(2, np.int32), order=np.zeros(2, np.int32))
    sc = dataclasses.replace(_scenario(), wl=bad)
    with pytest.raises(ValueError, match="src == dst"):
        api.study(sc)
    with pytest.raises(ValueError, match="src == dst"):
        api.run(sc)


# --------------------------------------------------------------------------
# scenario registry
# --------------------------------------------------------------------------


def test_scenario_registry_resolves_and_overrides():
    names = scenarios.names()
    assert {"incast8_32n", "perm64", "sparse_heavy_32n",
            "tiny_incast3"} <= set(names)
    sc = scenario("tiny_incast3", algo="swift", max_ticks=12_345)
    assert sc.cfg.algo == "swift" and sc.max_ticks == 12_345
    assert sc.name == "tiny_incast3"
    # aliases resolve to the same catalogue entry
    assert scenario("perm_64n").name == "perm64"
    with pytest.raises(KeyError, match="tiny_incast3"):
        scenario("no_such_scenario")


def test_api_accepts_scenario_names():
    r = api.run("tiny_incast3")
    assert r.scenario == "tiny_incast3" and r.all_done
    res = api.study("tiny_incast3",
                    points=[{"start_cwnd_mult": a} for a in (0.5, 1.0)],
                    seeds=(0, 1)).run()
    assert len(res) == 4 and all(rr.all_done for rr in res)


# --------------------------------------------------------------------------
# typed results
# --------------------------------------------------------------------------


def test_run_result_derived_fields():
    r = api.run("tiny_incast3")
    assert r.all_done and r.n_done == r.n_flows
    assert r.completion == int(r.fct_done.max())
    assert 0.0 < r.jain <= 1.0
    assert r.fct_min <= r.fct_mean <= r.fct_p99 <= r.completion
    # slowdown vs the uncongested ideal: >= ~1 for every finished flow
    assert np.nanmin(r.slowdown) > 0.9
    assert r.slowdown_p99 >= r.slowdown_mean > 0
    s = r.summary()
    assert s["fct_max"] == r.completion and s["trims"] == r.trims


def test_study_result_rows_are_point_major_and_tidy():
    points = [{"start_cwnd_mult": a} for a in (0.5, 1.0, 1.25)]
    seeds = (0, 7)
    res = api.study("tiny_incast3", points=points, seeds=seeds).run()
    rows = res.rows()
    assert len(rows) == len(points) * len(seeds)
    for pi, pt in enumerate(points):
        for si, seed in enumerate(seeds):
            row = rows[pi * len(seeds) + si]
            assert row["point"] == pt and row["seed"] == seed
            assert row["scenario"] == "tiny_incast3"
            assert {"name", "completion", "jain", "slowdown_p99",
                    "trims", "ticks"} <= set(row)
    # lane() indexes the same grid
    assert res.lane(2, 1).seed == 7
    assert dict(res.lane(2, 1).point) == points[2]
    best = res.best("completion")
    assert best.completion == min(r.completion for r in res)


# --------------------------------------------------------------------------
# best() tie-handling (regression: unfinished lanes must rank strictly
# last, whatever their partial metric looks like)
# --------------------------------------------------------------------------


def _synthetic_result(fct, done, seed):
    """A hand-built RunResult with exactly the finished-flow structure
    the test wants (the derived metrics — completion, slowdown — follow
    from fct/done)."""
    nf = len(fct)
    z = np.zeros(nf, np.int32)
    return api.RunResult(
        scenario="syn", algo="smartt", lb="reps", point=(), seed=seed,
        max_ticks=100, ticks=100, mtu=4096, brtt=10,
        fct=np.asarray(fct, np.int32), goodput=z,
        done=np.asarray(done, bool),
        size=np.full(nf, 4096, np.int32), t_start=z,
        flow_brtt=np.full(nf, 10.0, np.float32),
        trims=0, drops=0, blackholed=0, timeouts=0, retx=0, acks=0,
        spurious_retx=0, delivered_pkts=0, delivered_bytes=0.0,
        rtt_hist=np.zeros(8, np.int32), q_mean=0.0, q_max=0)


def _synthetic_study(results):
    return api.StudyResult(scenario="syn", points=((),) * len(results),
                           seeds=(0,), results=tuple(results),
                           states=None, wall_s=0.0)


def test_best_unfinished_lanes_rank_strictly_last():
    """An unfinished lane whose partial metric looks perfect — e.g. one
    early flow finished at tick 0, so ``completion == 0`` — must never
    beat a finished lane, for any metric; sentinel values (-1, NaN) rank
    last within each group; exact ties resolve to the lowest lane."""
    unfinished_looks_great = _synthetic_result([0, -1], [True, False],
                                               seed=0)
    assert not unfinished_looks_great.all_done
    assert unfinished_looks_great.completion == 0     # the trap value
    finished_slow = _synthetic_result([50, 70], [True, True], seed=1)
    res = _synthetic_study([unfinished_looks_great, finished_slow])
    assert res.best("completion") is finished_slow
    assert res.best("fct_mean") is finished_slow
    # slowdown of the unfinished lane is a -1 sentinel -> ranks last even
    # against a large finished value
    assert res.best("slowdown_p99") is finished_slow

    # nothing finished at all: fall back to the metric among unfinished
    # lanes (the -1 sentinel maps to inf, so real progress wins)
    part = _synthetic_result([5, -1], [True, False], seed=0)
    none_ = _synthetic_result([-1, -1], [False, False], seed=1)
    assert _synthetic_study([none_, part]).best("completion") is part

    # exact tie between finished lanes: stable, lowest lane index
    twin_a = _synthetic_result([9, 9], [True, True], seed=0)
    twin_b = _synthetic_result([9, 9], [True, True], seed=1)
    assert _synthetic_study([twin_a, twin_b]).best("completion") is twin_a
