"""Dynamic fault schedules and failure-recovery transport (ISSUE 8).

Covers the FaultSchedule compilation contract end to end:

* validation — every malformed schedule entry (out-of-range port
  coordinates, negative times/periods, degenerate flap windows) raises an
  actionable error naming the offending entry, mirroring
  ``Workload.validate``;
* lowering — legacy ``faults=((r, a, period), ...)`` tuples and their
  explicit one-event ``FaultSchedule`` form produce bit-identical final
  state pytrees (the acceptance digest);
* recovery knobs — ``rto_backoff_max`` / ``evict_on_timeout`` are exact
  no-ops on runs that never fire a timeout, and on the registered
  fail-then-repair three-tier scenario the recovery configuration
  completes every flow while the no-recovery configuration strands at
  least one (the ISSUE 8 acceptance case);
* recovery metrics — ``fault_ticks`` / ``delivered_fault_frac`` /
  ``ttr_max`` / ``dip_depth`` flow through ``RunResult.row()`` exactly
  when a schedule is present.
"""

import jax
import numpy as np
import pytest

from repro.netsim import api, faults, workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.faults import FaultEvent, FaultSchedule, Flap
from repro.netsim.state import derive
from repro.netsim.units import FatTreeConfig, LinkConfig

LINK = LinkConfig()
TREE2 = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)        # 4:1
TREE3 = FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2,
                      pods=2, core_uplinks=1)                      # core 2:1


def _derive(tree, wl, **cfg_kw):
    return derive(SimConfig(link=LINK, tree=tree, **cfg_kw), wl)


def _final_state(tree, wl, max_ticks=30000, **cfg_kw):
    sim = build(SimConfig(link=LINK, tree=tree, **cfg_kw), wl)
    st = sim.run(max_ticks=max_ticks)
    st.now.block_until_ready()
    return st


def _assert_pytree_equal(st_a, st_b):
    la, lb = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# validation: actionable errors naming the offending entry
# --------------------------------------------------------------------------

@pytest.mark.parametrize("tree,bad,msg", [
    # out-of-range coordinates per kind, two- and three-tier
    (TREE2, (("t0_up", 9, 0, 0),), r"faults\[0\].*i \(rack\)=9"),
    (TREE2, (("t0_up", 0, 5, 0),), r"faults\[0\].*j \(uplink\)=5"),
    (TREE3, (("t1_up", 99, 0, 0),), r"faults\[0\].*i \(t1 switch\)=99"),
    (TREE3, (("t2_down", 0, 7, 2),), r"faults\[0\].*j \(pod\)=7"),
    (TREE3, (("t1_down", 0, 3, 0),), r"faults\[0\].*j \(rack-in-pod\)=3"),
    (TREE2, (("t1_up", 0, 0, 0),), r"faults\[0\].*three-tier"),
    (TREE2, (("warp_core", 0, 0, 0),), r"faults\[0\].*unknown fault kind"),
    (TREE2, ((0, 9, 0),), r"faults\[0\].*j \(uplink\)=9"),   # legacy 3-tuple
], ids=["rack", "uplink", "t1", "pod", "t1down", "two-tier", "kind",
        "legacy"])
def test_validate_out_of_range_names_entry(tree, bad, msg):
    wl = workloads.permutation(tree, size_bytes=4096, seed=0)
    with pytest.raises(ValueError, match=msg):
        _derive(tree, wl, faults=bad)


@pytest.mark.parametrize("cfg_kw,msg", [
    (dict(faults=((0, 0, 2),), fault_start=-5), r"fault_start=-5"),
    (dict(faults=FaultSchedule(events=(
        FaultEvent(t=-1, kind="t0_up", i=0),))), r"t must be >= 0"),
    (dict(faults=FaultSchedule(events=(
        FaultEvent(t=0, kind="t0_up", i=0, period=-2),))),
     r"period must be >= 0"),
    (dict(faults=FaultSchedule(flaps=(
        Flap(kind="t0_up", i=0, up=3, cycle=2),))),
     r"0 < up < cycle"),
    (dict(faults=FaultSchedule(flaps=(
        Flap(kind="t0_up", i=0, up=1, cycle=4, t=10, t_end=10),))),
     r"0 <= t < t_end"),
    (dict(faults=((0, 0),)), r"not understood"),
    (dict(rto_backoff_max=-1), r"rto_backoff_max"),
    (dict(goodput_bin=-8), r"goodput_bin"),
], ids=["fault_start", "event_t", "event_period", "flap_up", "flap_win",
        "tuple_shape", "backoff", "goodput_bin"])
def test_validate_schedule_shape_errors(cfg_kw, msg):
    wl = workloads.permutation(TREE2, size_bytes=4096, seed=0)
    with pytest.raises(ValueError, match=msg):
        _derive(TREE2, wl, **cfg_kw)


def test_validate_duplicate_flap_per_port():
    wl = workloads.permutation(TREE2, size_bytes=4096, seed=0)
    flaps = (Flap(kind="t0_up", i=0, j=0, up=2, cycle=4),
             Flap(kind="t0_up", i=0, j=0, up=3, cycle=6))
    with pytest.raises(ValueError, match=r"at most one flap per port"):
        _derive(TREE2, wl, faults=FaultSchedule(flaps=flaps))


def test_switch_kind_expands_to_all_owned_ports():
    """kind='switch' marks every queue the switch owns dead at once."""
    wl = workloads.permutation(TREE3, size_bytes=4096, seed=0)
    cfg = SimConfig(link=LINK, tree=TREE3)
    topo, _, _, _ = derive(cfg, wl)
    sw = int(TREE3.racks)          # first T1 switch id = racks + 0
    sched = FaultSchedule(events=(
        FaultEvent(t=0, kind="switch", i=sw, period=0),))
    cf = faults.compile_tables(sched, topo, 0)
    per = faults.np_port_period(cf, 0, 100)
    dead = set(np.where(per == 0)[0])
    owned = set(np.where(np.asarray(topo.sw_of_q) == sw)[0])
    assert dead == owned and owned, (dead, owned)
    with pytest.raises(ValueError, match=r"switch=999 out of range"):
        faults.compile_tables(FaultSchedule(events=(
            FaultEvent(t=0, kind="switch", i=999),)), topo, 0)


# --------------------------------------------------------------------------
# lowering: legacy tuples == explicit one-event schedules, bit for bit
# --------------------------------------------------------------------------

def test_legacy_tuple_lowers_to_one_event_schedule_bitwise():
    """The acceptance digest: a legacy ``(r, a, period)`` tuple with a
    nonzero ``fault_start`` and the explicit one-event FaultSchedule must
    produce bit-identical *full final-state pytrees*."""
    wl = workloads.permutation(TREE2, size_bytes=48 * 4096, seed=1)
    legacy = _final_state(TREE2, wl, faults=((0, 1, 2),), fault_start=120)
    sched = FaultSchedule(events=(
        FaultEvent(t=0, kind="t0_up", i=0, j=1, period=2),))
    explicit = _final_state(TREE2, wl, faults=sched, fault_start=120)
    _assert_pytree_equal(legacy, explicit)


def test_legacy_4tuple_lowers_bitwise_three_tier():
    wl = workloads.permutation(TREE3, size_bytes=32 * 4096, seed=2)
    legacy = _final_state(TREE3, wl,
                          faults=(("t1_up", 0, 0, 0), ("t2_down", 0, 1, 2)),
                          fault_start=50)
    sched = FaultSchedule(events=(
        FaultEvent(t=0, kind="t1_up", i=0, j=0, period=0),
        FaultEvent(t=0, kind="t2_down", i=0, j=1, period=2)))
    explicit = _final_state(TREE3, wl, faults=sched, fault_start=50)
    _assert_pytree_equal(legacy, explicit)


def test_fault_start_sweepable_without_retrace():
    """fault_start stays a Consts scalar: sweeping it must not retrace
    (the compiled tables are relative to it)."""
    from repro.analysis import trace_guard
    wl = workloads.permutation(TREE2, size_bytes=16 * 4096, seed=0)
    from repro.netsim.scenarios import Scenario
    sc = Scenario(name="fs_sweep",
                  cfg=SimConfig(link=LINK, tree=TREE2,
                                faults=((0, 0, 0),), fault_start=0),
                  wl=wl, max_ticks=6000)
    study = api.study(sc, points=[{"fault_start": 100},
                                  {"fault_start": 400}])
    with trace_guard("engine.step", expect=1):   # fault_start sweep retraced?
        res = study.run()
    a, b = res.results
    assert a.ticks > 0 and b.ticks > 0


# --------------------------------------------------------------------------
# recovery knobs: exact no-ops without timeouts; the acceptance contrast
# --------------------------------------------------------------------------

def test_recovery_knobs_are_noop_without_timeouts():
    """On a clean (fault-free, timeout-free) run, backoff + eviction must
    leave every state leaf bitwise unchanged."""
    wl = workloads.permutation(TREE2, size_bytes=16 * 4096, seed=3)
    base = _final_state(TREE2, wl)
    assert int(base.m.n_to) == 0, "meant to be a timeout-free run"
    rec = _final_state(TREE2, wl, rto_backoff_max=4, evict_on_timeout=True)
    _assert_pytree_equal(base, rec)


def test_backoff_spaces_out_retries_on_dead_path():
    """A flow stuck on a dead link fires timeouts at increasing spacing:
    with backoff the timeout count over a fixed window drops."""
    wl = workloads.permutation(TREE2, size_bytes=32 * 4096, seed=1)
    # kill both uplinks of rack 0 permanently: rack-0 senders strand
    sched = FaultSchedule(events=(
        FaultEvent(t=0, kind="t0_up", i=0, j=0, period=0),
        FaultEvent(t=0, kind="t0_up", i=0, j=1, period=0)))
    base = _final_state(TREE2, wl, max_ticks=8000, faults=sched)
    backed = _final_state(TREE2, wl, max_ticks=8000, faults=sched,
                          rto_backoff_max=4)
    assert int(base.m.n_to) > 0
    assert int(backed.m.n_to) < int(base.m.n_to)
    assert int(np.asarray(backed.rto_backoff).max()) == 4


def test_corefail_acceptance_recovery_completes_norecovery_strands():
    """ISSUE 8 acceptance: on the registered fail-then-repair three-tier
    scenario, smartt with RTO backoff + REPS timeout eviction completes
    every flow; the no-recovery configuration strands at least one (the
    repair lands closer to the budget than one forward traversal, so a
    stranded flow cannot sneak in after it)."""
    from repro.netsim.scenarios import scenario
    sc = scenario("corefail_128n_3t")
    no_rec = api.run(sc)
    rec = api.run(sc.with_(name="corefail+recovery",
                           rto_backoff_max=2, evict_on_timeout=True))
    assert rec.all_done, f"recovery config stranded: {rec.n_done}"
    assert not no_rec.all_done, "no-recovery config was meant to strand"
    assert no_rec.n_done < no_rec.n_flows
    # and the recovery config escaped through evicted entropies, visibly
    assert rec.timeouts > 0 and rec.blackholed > 0


# --------------------------------------------------------------------------
# recovery metrics -> RunResult.row()
# --------------------------------------------------------------------------

def test_recovery_metrics_flow_into_row():
    from repro.netsim.scenarios import Scenario
    wl = workloads.permutation(TREE3, size_bytes=48 * 4096, seed=2)
    sched = FaultSchedule(events=(
        FaultEvent(t=20, kind="t1_up", i=0, j=0, period=0),
        FaultEvent(t=600, kind="t1_up", i=0, j=0, period=1)))
    sc = Scenario(name="metrics_probe",
                  cfg=SimConfig(link=LINK, tree=TREE3, faults=sched),
                  wl=wl, max_ticks=20000)
    r = api.run(sc)
    row = r.row()
    for key in ("fault_ticks", "delivered_fault_frac", "ttr_max",
                "dip_depth", "dip_ticks", "blackholed", "timeouts"):
        assert key in row, f"missing {key} in row: {sorted(row)}"
    assert r.ticks > 20, "fault was meant to land mid-run"
    assert row["fault_ticks"] == max(min(600, r.ticks) - 20, 0)
    assert 0.0 <= row["delivered_fault_frac"] <= 1.0
    assert 0.0 <= row["dip_depth"] <= 1.0
    assert r.first_fault == 20
    assert list(r.repair_ticks) == ([600] if r.ticks > 600 else [])
    # goodput histogram integrates to total delivered bytes
    assert r.goodput_hist is not None
    np.testing.assert_allclose(float(np.sum(r.goodput_hist)),
                               r.delivered_bytes, rtol=1e-6)


def test_fault_free_row_keeps_legacy_shape():
    """No schedule -> no recovery-metric keys (ledger rows unchanged)."""
    from repro.netsim.scenarios import Scenario
    wl = workloads.permutation(TREE2, size_bytes=8 * 4096, seed=0)
    sc = Scenario(name="clean",
                  cfg=SimConfig(link=LINK, tree=TREE2),
                  wl=wl, max_ticks=8000)
    row = api.run(sc).row()
    for key in ("fault_ticks", "delivered_fault_frac", "ttr_max"):
        assert key not in row


# --------------------------------------------------------------------------
# host/traced evaluation consistency
# --------------------------------------------------------------------------

def test_np_port_period_matches_traced_evaluation():
    """The host-side metric integrator and the traced fabric gate must
    agree at every tick of a multi-transition + flap schedule."""
    import jax.numpy as jnp
    wl = workloads.permutation(TREE3, size_bytes=4096, seed=0)
    sched = FaultSchedule(
        events=(FaultEvent(t=40, kind="t1_up", i=0, j=0, period=0),
                FaultEvent(t=90, kind="t1_up", i=0, j=0, period=3),
                FaultEvent(t=160, kind="t1_up", i=0, j=0, period=1),
                FaultEvent(t=10, kind="t2_down", i=1, j=1, period=2)),
        flaps=(Flap(kind="t0_up", i=1, j=0, up=7, cycle=11,
                    t=25, t_end=180),))
    cfg = SimConfig(link=LINK, tree=TREE3, faults=sched, fault_start=13)
    topo, _, dims, consts = derive(cfg, wl)
    cf = faults.compile_tables(sched, topo, 13)
    fn = jax.jit(lambda t: faults.port_period(dims, consts, t))
    for t in range(0, 220):
        np.testing.assert_array_equal(
            np.asarray(fn(jnp.asarray(t, jnp.int32))),
            faults.np_port_period(cf, 13, t), err_msg=f"t={t}")


def test_transition_horizon_never_skips_a_change():
    """Over [t, t + transition_horizon(t)) the period vector must be
    constant — the leap-clamp soundness condition."""
    import jax.numpy as jnp
    wl = workloads.permutation(TREE3, size_bytes=4096, seed=0)
    sched = FaultSchedule(
        events=(FaultEvent(t=30, kind="t1_up", i=1, j=0, period=0),
                FaultEvent(t=75, kind="t1_up", i=1, j=0, period=1)),
        flaps=(Flap(kind="t0_up", i=0, j=1, up=4, cycle=9,
                    t=20, t_end=120),))
    cfg = SimConfig(link=LINK, tree=TREE3, faults=sched, fault_start=7)
    topo, _, dims, consts = derive(cfg, wl)
    cf = faults.compile_tables(sched, topo, 7)
    hz = jax.jit(lambda t: faults.transition_horizon(dims, consts, t))
    for t in range(0, 160):
        h = int(hz(jnp.asarray(t, jnp.int32)))
        assert h >= 1
        base = faults.np_port_period(cf, 7, t)
        for dt in range(1, min(h, 40)):
            np.testing.assert_array_equal(
                faults.np_port_period(cf, 7, t + dt), base,
                err_msg=f"period changed inside horizon: t={t} dt={dt}")
