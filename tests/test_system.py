"""End-to-end behaviour tests for the whole system: the paper's headline
result on the netsim, and the training stack's learn+restart loop."""

import numpy as np

from repro.netsim.engine import SimConfig, build, jain_fairness, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim import workloads


def test_headline_smartt_beats_baselines_on_oversubscribed_permutation():
    """Paper Sec. 4.4 headline: on an oversubscribed fat tree SMaRTT
    completes a permutation at least as fast as Swift/MPRDMA while being
    the fairest, and EQDS burns an order of magnitude more trims."""
    link = LinkConfig()
    tree = FatTreeConfig(racks=4, nodes_per_rack=16, uplinks=4)
    wl = workloads.permutation(tree, size_bytes=512 * 1024, seed=1)
    res = {}
    for algo in ("smartt", "swift", "mprdma", "eqds"):
        sim = build(SimConfig(link=link, tree=tree, algo=algo, lb="reps"), wl)
        st = sim.run(max_ticks=60000)
        s = summarize(sim, st)
        fct = s["fct_ticks"][np.asarray(st.done)]
        res[algo] = dict(c=s["fct_max"], j=jain_fairness(fct), t=s["trims"],
                         done=s["all_done"])
    assert all(r["done"] for r in res.values())
    assert res["smartt"]["c"] <= min(res["swift"]["c"], res["mprdma"]["c"])
    assert res["smartt"]["j"] >= max(res["swift"]["j"], res["mprdma"]["j"],
                                     res["eqds"]["j"]) - 1e-9
    assert res["eqds"]["t"] > 3 * res["smartt"]["t"]


def test_batched_runs_are_decorrelated_and_complete():
    link = LinkConfig()
    tree = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)
    wl = workloads.permutation(tree, size_bytes=64 * 4096, seed=2)
    sim = build(SimConfig(link=link, tree=tree, algo="smartt", lb="reps"), wl)
    st = sim.run_batch(np.arange(4), max_ticks=30000)
    assert bool(np.all(np.asarray(st.done)))
    fcts = [int(np.asarray(st.fct)[i].max()) for i in range(4)]
    assert len(set(fcts)) > 1          # per-seed salts decorrelate runs


def test_train_learns_and_restarts(tmp_path):
    """The end-to-end driver: loss falls, a second invocation resumes from
    the checkpoint instead of restarting."""
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import LoopConfig, train
    from repro.train.step import TrainConfig

    cfg = get_config("qwen3-0.6b", reduced=True)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                      structure=16)
    tcfg = TrainConfig(adam=AdamWConfig(lr=2e-2, warmup_steps=5,
                                        total_steps=40), microbatches=2)
    ckpt = str(tmp_path / "ck")
    _, _, losses = train(cfg, tcfg,
                         LoopConfig(steps=25, ckpt_dir=ckpt, ckpt_every=10,
                                    log_every=100),
                         dcfg, log=lambda *_: None)
    assert losses[-1] < losses[0] - 0.5
    _, _, losses2 = train(cfg, tcfg,
                          LoopConfig(steps=30, ckpt_dir=ckpt, ckpt_every=10,
                                     log_every=100),
                          dcfg, log=lambda *_: None)
    assert len(losses2) == 5           # resumed at 25, ran 5 more
