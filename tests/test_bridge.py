"""Collectives bridge + serving engine integration tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives.bridge import estimate, refine_collective_term


def test_allreduce_estimate_sane():
    e = estimate("all-reduce", 256 * 1024, algo="smartt", nodes=32,
                 oversub=4, max_bytes=256 * 1024)
    assert 0.3 <= e.efficiency <= 1.0
    assert e.fairness > 0.8
    assert e.achieved_ticks > 0


def test_transport_changes_the_estimate():
    kw = dict(nodes=32, oversub=4, max_bytes=256 * 1024)
    sm = estimate("all-reduce", 256 * 1024, algo="smartt", **kw)
    eq = estimate("all-reduce", 256 * 1024, algo="eqds", **kw)
    # EQDS completes but wastes fabric bandwidth on trims (paper Sec. 4.4)
    assert eq.trims > 3 * sm.trims


def test_refine_collective_term_scales():
    out = refine_collective_term(1.0, "all-reduce", 256 * 1024,
                                 algo="smartt", nodes=32, oversub=4,
                                 max_bytes=256 * 1024)
    assert out["refined_s"] >= out["ideal_s"]
    assert 0 < out["efficiency"] <= 1.0


def test_generate_shapes_and_determinism():
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import generate

    cfg = get_config("qwen3-0.6b", reduced=True)
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (3, 8), 0, cfg.vocab,
                              jnp.int32)
    a = generate(params, cfg, toks, max_new=5, max_len=16)
    b = generate(params, cfg, toks, max_new=5, max_len=16)
    assert a.shape == (3, 5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.all((np.asarray(a) >= 0) & (np.asarray(a) < cfg.vocab))
