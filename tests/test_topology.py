"""Topology and routing invariants for the tier-generic fat tree.

Three layers of guarantees:

* structural — ``build_topology``'s port blocks partition the queue space,
  every wire feeds a real switch (or a host), and the routing tables stay
  in range, for randomized 2- and 3-tier configs;
* behavioral — the *production* routing functions (``fabric.route_from_
  sender`` / ``route_step``) deliver every (src, dst, entropy) to dst in
  exactly the analytic hop count, never revisit a port, and the ECMP
  entropy hash covers every equal-cost uplink at every tier;
* degenerate — on two-tier trees the table-driven routing must equal the
  historical closed-form routing bit for bit, for the whole scenario
  catalogue's trees.

The randomized suites always run on numpy-seeded draws; hypothesis (a
declared test dependency — CI installs ``.[test]``) additionally drives
the same property through minimized search where available.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.netsim import fabric, hashing, workloads
from repro.netsim.scenarios import (TREE_2TO1, TREE_4TO1, TREE_8TO1,
                                    TREE_16, TREE_FLAT, TREE_TINY)
from repro.netsim.state import SimConfig, derive
from repro.netsim.topology import (KIND_SENDER, KIND_T0_DOWN, KIND_T0_UP,
                                   KIND_T1_DOWN, KIND_T1_UP, KIND_T2_DOWN,
                                   build_topology)
from repro.netsim.units import FatTreeConfig, LinkConfig, path_queues

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # local envs without the test extra
    HAVE_HYPOTHESIS = False

I32 = np.int32

# a spread of 2- and 3-tier shapes (including single-uplink and
# single-pod corners) for the seeded randomized sweeps
RANDOM_TREES = [
    FatTreeConfig(racks=2, nodes_per_rack=2, uplinks=1),
    FatTreeConfig(racks=3, nodes_per_rack=3, uplinks=2),
    FatTreeConfig(racks=4, nodes_per_rack=4, uplinks=3),
    FatTreeConfig(racks=2, nodes_per_rack=2, uplinks=2, pods=1,
                  core_uplinks=1),
    FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2, pods=2,
                  core_uplinks=2),
    FatTreeConfig(racks=6, nodes_per_rack=2, uplinks=3, pods=3,
                  core_uplinks=1),
    FatTreeConfig(racks=8, nodes_per_rack=2, uplinks=2, pods=4,
                  core_uplinks=3),
    FatTreeConfig(racks=9, nodes_per_rack=2, uplinks=1, pods=3,
                  core_uplinks=2),
]


def _all_pairs_workload(tree: FatTreeConfig, rng=None, max_flows=256):
    """Every ordered (src, dst) pair, subsampled when the fabric is big."""
    n = tree.n_nodes
    src, dst = np.meshgrid(np.arange(n, dtype=I32),
                           np.arange(n, dtype=I32), indexing="ij")
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if src.shape[0] > max_flows:
        idx = (rng or np.random.default_rng(0)).choice(
            src.shape[0], size=max_flows, replace=False)
        src, dst = src[idx], dst[idx]
    f = src.shape[0]
    return workloads.Workload(
        name="pairs", src=src, dst=dst,
        size=np.full(f, 4096, I32), t_start=np.zeros(f, I32),
        order=np.zeros(f, I32))


def _derive(tree: FatTreeConfig, wl):
    return derive(SimConfig(link=LinkConfig(), tree=tree), wl)


def _walk_paths(dims, consts, ents):
    """Route every flow for every entropy from the sender NIC to delivery.

    Returns ``hops`` [H+1, NF, E]: the queue id at each step (delivery
    encoded negative, sticky once reached).  H is a hop budget one above
    the longest legal path, so a loop shows up as a non-delivered entry.
    """
    e = jnp.asarray(ents, jnp.int32)[None, :]
    f = jnp.arange(dims.NF, dtype=jnp.int32)[:, None]
    d = consts.dst[:, None]
    q = fabric.route_from_sender(dims, consts, f, e)
    hops = [np.asarray(q)]
    for _ in range(7):           # longest legal path is 5 queues
        nxt = fabric.route_step(dims, consts,
                                jnp.clip(q, 0, dims.NQ - 1), d, e)
        q = jnp.where(q >= 0, nxt, q)
        hops.append(np.asarray(q))
    return np.stack(hops)


def _check_routing(tree: FatTreeConfig, n_ents=32, rng=None):
    """The full behavioral property for one tree (shared by the seeded
    sweep and the hypothesis search)."""
    wl = _all_pairs_workload(tree, rng)
    topo, tm, dims, consts = _derive(tree, wl)
    ents = np.arange(n_ents, dtype=I32)
    hops = _walk_paths(dims, consts, ents)

    # 1. delivery: the final entry is -(dst + 1) for every (flow, entropy)
    want = -(np.asarray(consts.dst)[:, None] + 1)
    np.testing.assert_array_equal(
        hops[-1], np.broadcast_to(want, hops[-1].shape))

    # 2. exact hop count per path class (number of queues traversed)
    h_intra, h_pod, h_inter = path_queues(tree)
    M, Pg = tree.nodes_per_rack, tree.racks_per_pod
    sr, dr = wl.src // M, wl.dst // M
    expect = np.where(sr == dr, h_intra,
                      np.where(sr // Pg == dr // Pg, h_pod, h_inter))
    n_queues = np.sum(hops >= 0, axis=0)
    np.testing.assert_array_equal(
        n_queues, np.broadcast_to(expect[:, None], n_queues.shape))

    # 3. loop-free and in range: queues along a path are distinct valid ids
    valid = hops >= 0
    assert np.all(hops[valid] < dims.NQ)
    s = np.sort(np.where(valid, hops, -np.arange(hops.shape[0])[:, None, None] - 1),
                axis=0)
    assert np.all((s[1:] != s[:-1]) | (s[1:] < 0)), "a path revisited a port"

    # 4. ECMP coverage: over the entropy sweep, every switch with
    # equal-cost up ports sees every one of them chosen — per tier, the
    # sprayed load can reach the whole equal-cost set (paper Sec. 3.6)
    up_cnt = np.asarray(consts.sw_up_cnt)
    salts = np.asarray(consts.sw_salt)
    sweep = np.arange(max(dims.NF * 4, 256), dtype=np.uint32)
    for sw in np.flatnonzero(up_cnt > 0):
        h = np.asarray(hashing.hash2(jnp.asarray(sweep),
                                     jnp.asarray(np.uint32(salts[sw]))))
        chosen = set((h % up_cnt[sw]).tolist())
        assert chosen == set(range(up_cnt[sw])), \
            f"switch {sw}: entropy sweep missed uplinks {set(range(up_cnt[sw])) - chosen}"

    # 5. up-hops land inside the chosen switch's up-port run
    up_base = np.asarray(consts.sw_up_base)
    nbr_q = np.asarray(consts.nbr_q)
    for step in range(hops.shape[0] - 1):
        q, nxt = hops[step], hops[step + 1]
        live = (q >= 0) & (nxt >= 0)
        if not live.any():
            continue
        sw = nbr_q[q[live]]
        down = topo.down_tbl[sw, np.broadcast_to(
            np.asarray(consts.dst)[:, None], q.shape)[live]]
        is_down = nxt[live] == down
        in_up_run = (nxt[live] >= up_base[sw]) & \
            (nxt[live] < up_base[sw] + np.maximum(up_cnt[sw], 1))
        assert np.all(is_down | in_up_run)


# --------------------------------------------------------------------------
# structural invariants
# --------------------------------------------------------------------------


@pytest.mark.parametrize("tree", RANDOM_TREES,
                         ids=[f"t{t.tiers}_{t.n_nodes}n" for t in RANDOM_TREES])
def test_topology_structure(tree):
    topo = build_topology(tree)
    t = topo.tree
    N, NQ, NE = t.n_nodes, topo.n_queues, topo.n_emitters
    three = t.tiers == 3
    # block sizes partition the queue space
    n_t1dn = t.n_t1 * t.racks_per_pod
    assert NQ == (t.racks * t.uplinks + t.n_t1 * t.core_uplinks
                  + t.n_cores * max(t.pods, 0) + n_t1dn + N)
    assert NE == NQ + N
    assert topo.n_switches == t.n_switches
    # the last N queues (and only those) are host-facing
    assert np.all(topo.nbr_sw[NQ - N:NQ] == -1)
    assert np.all(topo.nbr_sw[:NQ - N] >= 0)
    assert np.all(topo.nbr_sw[:NQ - N] < topo.n_switches)
    assert np.all(topo.nbr_sw[NQ:] >= 0)          # senders feed their rack
    # kinds occupy their blocks
    assert np.all(topo.kind[NQ:] == KIND_SENDER)
    assert np.all(topo.kind[NQ - N:NQ] == KIND_T0_DOWN)
    if three:
        assert np.sum(topo.kind == KIND_T1_UP) == t.n_t1 * t.core_uplinks
        assert np.sum(topo.kind == KIND_T2_DOWN) == t.n_cores * t.pods
    else:
        assert not np.any(topo.kind == KIND_T1_UP)
        assert not np.any(topo.kind == KIND_T2_DOWN)
    # subtree intervals: racks tile the hosts; T1 covers its pod; cores all
    P = t.racks
    np.testing.assert_array_equal(topo.sw_lo[:P],
                                  np.arange(P) * t.nodes_per_rack)
    assert np.all(topo.sw_hi - topo.sw_lo > 0)
    assert np.all(topo.sw_hi <= N)
    # every up run lies in the queue space, down tables point at queues
    assert np.all(topo.sw_up_base + topo.sw_up_cnt <= NQ)
    assert np.all((topo.down_tbl >= 0) & (topo.down_tbl < NQ))
    # helper ids agree with the arrays
    assert topo.t0_down(0) == NQ - N
    assert topo.sender(N - 1) == NE - 1
    if three:
        q = topo.t1_up(1, t.core_uplinks - 1)
        assert topo.kind[q] == KIND_T1_UP
        q = topo.t2_down(t.n_cores - 1, t.pods - 1)
        assert topo.kind[q] == KIND_T2_DOWN


@pytest.mark.parametrize("tree", RANDOM_TREES,
                         ids=[f"t{t.tiers}_{t.n_nodes}n" for t in RANDOM_TREES])
def test_run_length_down_routing_equals_dense_table(tree):
    """The closed-form dn_base + d // dn_stride lookup must reproduce the
    dense down_tbl for every node *inside* each switch's subtree (the only
    place routing ever goes down), at every tier."""
    topo = build_topology(tree)
    n = tree.n_nodes
    d = np.arange(n, dtype=I32)
    for sw in range(topo.n_switches):
        run = topo.dn_base[sw] + d // topo.dn_stride[sw]
        inside = (d >= topo.sw_lo[sw]) & (d < topo.sw_hi[sw])
        np.testing.assert_array_equal(run[inside], topo.down_tbl[sw][inside])
        # and the ports it names are real queues of this switch's blocks
        assert np.all((run[inside] >= 0) & (run[inside] < topo.n_queues))


@pytest.mark.parametrize("tree", RANDOM_TREES,
                         ids=[f"t{t.tiers}_{t.n_nodes}n" for t in RANDOM_TREES])
def test_fan_in_tables_invert_nbr_sw(tree):
    """enq_ids/in_tbl/in_pos are a faithful, ascending-ordered compact
    inverse of nbr_sw: enq_ids enumerates exactly the switch-facing
    emitters in id order, every compact index appears in exactly one
    group slot of its feeding switch, in_pos names that slot, and group
    sizes never exceed fan_max."""
    topo = build_topology(tree)
    nsw, dmax = topo.n_switches, topo.fan_max
    eq = len(topo.enq_ids)
    # compact enumeration: exactly the switch-facing emitters, ascending
    np.testing.assert_array_equal(topo.enq_ids,
                                  np.where(topo.nbr_sw >= 0)[0])
    assert topo.in_tbl.shape == (nsw, dmax)
    assert topo.in_pos.shape == (eq,)
    seen = np.zeros(eq, bool)
    for sw in range(nsw):
        row = topo.in_tbl[sw]
        real = row[row < eq]
        # ascending compact indices (== ascending emitter ids), pads
        # (== eq) only at the tail
        assert np.all(np.diff(real) > 0)
        assert np.all(row[len(real):] == eq)
        for k, j in enumerate(real):
            assert topo.nbr_sw[topo.enq_ids[j]] == sw
            assert topo.in_pos[j] == sw * dmax + k
            assert not seen[j]
            seen[j] = True
    assert seen.all()
    assert dmax == max(np.sum(topo.nbr_sw == sw) for sw in range(nsw))


@pytest.mark.parametrize("tree", RANDOM_TREES,
                         ids=[f"t{t.tiers}_{t.n_nodes}n" for t in RANDOM_TREES])
def test_sw_of_q_names_owning_switch(tree):
    """Every queue's owning switch covers it: the queue appears among the
    output ports enumerated for that switch tier, and destinations routed
    *down* through it stay inside the switch's subtree interval."""
    topo = build_topology(tree)
    assert topo.sw_of_q.shape == (topo.n_queues,)
    assert np.all((topo.sw_of_q >= 0) & (topo.sw_of_q < topo.n_switches))
    # the down-run of each switch lands only on queues it owns
    for sw in range(topo.n_switches):
        d = np.arange(topo.sw_lo[sw], topo.sw_hi[sw])
        if len(d) == 0:
            continue
        q = topo.dn_base[sw] + d // topo.dn_stride[sw]
        np.testing.assert_array_equal(topo.sw_of_q[q], sw)


def test_fat_tree_config_validation():
    with pytest.raises(ValueError, match="core_uplinks"):
        FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2, core_uplinks=2)
    with pytest.raises(ValueError, match="core_uplinks >= 1"):
        FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2, pods=2)
    with pytest.raises(ValueError, match="divide evenly"):
        FatTreeConfig(racks=5, nodes_per_rack=2, uplinks=2, pods=2,
                      core_uplinks=1)


# --------------------------------------------------------------------------
# behavioral routing property (seeded sweep + hypothesis search)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("tree", RANDOM_TREES,
                         ids=[f"t{t.tiers}_{t.n_nodes}n" for t in RANDOM_TREES])
def test_routing_reaches_dst_loop_free_with_coverage(tree):
    _check_routing(tree, rng=np.random.default_rng(1))


if HAVE_HYPOTHESIS:
    @st.composite
    def _trees(draw):
        tiers = draw(st.sampled_from((2, 3)))
        m = draw(st.integers(1, 4))
        u1 = draw(st.integers(1, 4))
        if tiers == 2:
            p = draw(st.integers(2, 6))
            return FatTreeConfig(racks=p, nodes_per_rack=m, uplinks=u1)
        pods = draw(st.integers(1, 4))
        pg = draw(st.integers(1, 3))
        u2 = draw(st.integers(1, 3))
        return FatTreeConfig(racks=pods * pg, nodes_per_rack=m, uplinks=u1,
                             pods=pods, core_uplinks=u2)

    @settings(max_examples=15, deadline=None)
    @given(tree=_trees(), seed=st.integers(0, 2**31 - 1))
    def test_routing_property_hypothesis(tree, seed):
        if tree.n_nodes < 2:
            return
        _check_routing(tree, n_ents=16, rng=np.random.default_rng(seed))


# --------------------------------------------------------------------------
# two-tier degenerate case: table-driven == historical closed form
# --------------------------------------------------------------------------


def _closed_form_from_queue(dims, topo, consts, flow):
    """The pre-table routing (verbatim semantics): t0_up -> t1_down[spine,
    drack]; t1_down -> t0_down[dst]; t0_down -> deliver."""
    d = np.asarray(consts.dst)[np.clip(flow, 0, dims.NF - 1)]
    drack = d // dims.M
    PU = dims.P * dims.U
    k, ax = topo.kind[:dims.NQ], topo.aux[:dims.NQ]
    r_up = PU + ax * dims.P + drack
    r_t1 = 2 * PU + d
    r_del = -(d + 1)
    return np.where(k == KIND_T0_UP, r_up,
                    np.where(k == KIND_T1_DOWN, r_t1, r_del))


def _closed_form_from_sender(dims, consts, f, ent):
    sr = np.asarray(consts.src)[f] // dims.M
    d = np.asarray(consts.dst)[f]
    h = np.asarray(hashing.hash2(
        jnp.asarray(ent, jnp.uint32),
        (jnp.asarray(sr, jnp.int32) * 0x9E37 + 0x1234).astype(jnp.uint32))
        % jnp.uint32(dims.U)).astype(I32)
    PU = dims.P * dims.U
    return np.where(d // dims.M == sr, 2 * PU + d, sr * dims.U + h)


@pytest.mark.parametrize(
    "tree", [TREE_TINY, TREE_16, TREE_FLAT, TREE_2TO1, TREE_4TO1, TREE_8TO1],
    ids=["tiny", "16", "flat", "2to1", "4to1", "8to1"])
def test_two_tier_table_routing_equals_closed_form(tree):
    """On every catalogue two-tier tree the new table-driven routing must
    reproduce the historical closed form bit for bit: same first queue for
    every (flow, entropy), same next queue for every (port, head packet)."""
    rng = np.random.default_rng(3)
    wl = _all_pairs_workload(tree, rng)
    topo, tm, dims, consts = _derive(tree, wl)

    ents = np.arange(64, dtype=I32)
    f = np.arange(dims.NF, dtype=I32)[:, None]
    got = np.asarray(fabric.route_from_sender(
        dims, consts, jnp.asarray(f), jnp.asarray(ents)[None, :]))
    want = _closed_form_from_sender(
        dims, consts, np.broadcast_to(f, got.shape),
        np.broadcast_to(ents[None, :], got.shape))
    np.testing.assert_array_equal(got, want)

    # Per-port head flows must be *reachable* there: a packet in a t1_down
    # queue feeding rack r necessarily has its dst under rack r (both the
    # closed form and the tables assume sound upstream routing; on garbage
    # (port, dst) combos they legitimately disagree).
    dsts = np.asarray(consts.dst)
    by_rack = [np.flatnonzero(dsts // dims.M == r) for r in range(dims.P)]
    assert all(len(b) for b in by_rack)
    for _ in range(8):
        flow = rng.integers(0, dims.NF, dims.NQ).astype(I32)
        for q in range(dims.NQ):
            if topo.kind[q] == KIND_T1_DOWN:
                cand = by_rack[topo.rack[q]]
                flow[q] = cand[rng.integers(0, len(cand))]
        ent = rng.integers(0, 256, dims.NQ).astype(I32)
        got_q = np.asarray(fabric.route_from_queue(
            dims, consts, jnp.asarray(flow), jnp.asarray(ent)))
        want_q = _closed_form_from_queue(dims, topo, consts, flow)
        np.testing.assert_array_equal(got_q, want_q)
