"""Superstep execution engine: K>1 runs must be bit-for-bit identical to
K=1 (the per-tick gate makes fused ticks exact, not approximate), across
CC backends, the batched runner, and the config sweep; and the per-seed
salt decorrelation of run_batch must actually change RED marking."""

import jax
import numpy as np
import pytest

from repro.analysis import trace_guard
from repro.netsim import collectives, workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.sweep import build_sweep
from repro.netsim.units import FatTreeConfig, LinkConfig

TREE = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)
OVERSUB = FatTreeConfig(racks=2, nodes_per_rack=8, uplinks=2)   # 4:1
TREE3 = FatTreeConfig(racks=4, nodes_per_rack=4, uplinks=2,
                      pods=2, core_uplinks=1)                   # core 4:1
LINK = LinkConfig()


def _run(tree, wl, superstep, max_ticks=30000, **kw):
    sim = build(SimConfig(link=LINK, tree=tree, superstep=superstep, **kw), wl)
    st = sim.run(max_ticks=max_ticks)
    st.now.block_until_ready()
    return sim, st


def _assert_state_equal(st_a, st_b):
    """Full-pytree bitwise equality — stronger than the acceptance bar
    (fct/goodput/cwnd): every state leaf, metrics counters included."""
    la, lb = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_superstep_bit_for_bit_equals_k1(backend):
    wl = workloads.incast(TREE, degree=3, size_bytes=16 * 4096, seed=0)
    _, st1 = _run(TREE, wl, superstep=1, cc_backend=backend)
    for k in (0, 7):          # 0 = auto (one base RTT); 7 doesn't divide
        _, stk = _run(TREE, wl, superstep=k, cc_backend=backend)
        np.testing.assert_array_equal(np.asarray(st1.fct), np.asarray(stk.fct))
        np.testing.assert_array_equal(np.asarray(st1.goodput),
                                      np.asarray(stk.goodput))
        np.testing.assert_array_equal(np.asarray(st1.cc.cwnd),
                                      np.asarray(stk.cc.cwnd))
        assert int(st1.now) == int(stk.now)
        _assert_state_equal(st1, stk)


def test_superstep_bit_for_bit_pallas_fabric_transport():
    """Fused K>1 vs K=1 with the enqueue-rank/arbitration and ring-drain
    kernels on the pallas backend — the cond-gated superstep body must
    compose with the kernel call graph exactly as with the jnp refs."""
    wl = workloads.incast(TREE, degree=3, size_bytes=8 * 4096, seed=0)
    kw = dict(fabric_backend="pallas", transport_backend="pallas")
    _, st1 = _run(TREE, wl, superstep=1, **kw)
    _, stk = _run(TREE, wl, superstep=0, **kw)
    _assert_state_equal(st1, stk)


def test_superstep_exact_under_congestion_and_trimming():
    """An oversubscribed permutation exercises trims, retransmissions, and
    RED marking; the fused loop must still match K=1 exactly."""
    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=1)
    _, st1 = _run(OVERSUB, wl, superstep=1)
    _, stk = _run(OVERSUB, wl, superstep=0)
    assert int(st1.m.n_trim) > 0          # the scenario actually trims
    _assert_state_equal(st1, stk)


def test_superstep_exact_on_three_tier_core_congestion():
    """Cross-core permutation on an oversubscribed three-tier fabric:
    trims at the T1 uplinks, five-queue paths, longer rings — K>1 must
    still match K=1 over the full pytree."""
    wl = workloads.permutation(TREE3, size_bytes=48 * 4096, seed=6)
    _, st1 = _run(TREE3, wl, superstep=1)
    _, stk = _run(TREE3, wl, superstep=0)
    assert int(st1.m.n_trim) > 0          # the core actually congests
    _assert_state_equal(st1, stk)


def test_run_batch_matches_k1_and_decorrelates_red():
    """run_batch composes with supersteps, and the per-seed salts change
    RED marking outcomes (different mark draws -> different trajectories),
    while seed 0 reproduces the unbatched run exactly."""
    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=2)
    sim1 = build(SimConfig(link=LINK, tree=OVERSUB, superstep=1), wl)
    simk = build(SimConfig(link=LINK, tree=OVERSUB, superstep=0), wl)
    stb = simk.run_batch(np.arange(4), max_ticks=30000)
    st1 = sim1.run(max_ticks=30000)

    # batch element 0 carries salt 0 == the unbatched run
    np.testing.assert_array_equal(np.asarray(st1.fct), np.asarray(stb.fct)[0])
    np.testing.assert_array_equal(np.asarray(st1.goodput),
                                  np.asarray(stb.goodput)[0])

    # decorrelation: the salt feeds the RED mark draw, so marking-driven
    # outcomes (ECN-driven cwnd trajectories -> fct) differ across seeds
    fcts = [tuple(np.asarray(stb.fct)[i]) for i in range(4)]
    assert len(set(fcts)) > 1
    hists = [tuple(np.asarray(stb.m.rtt_hist)[i]) for i in range(4)]
    assert len(set(hists)) > 1


def test_sweep_composes_with_supersteps():
    """The vmapped sweep under a superstep loop stays one-compile and
    matches the per-tick sweep point-for-point."""
    wl = workloads.incast(TREE, degree=4, size_bytes=32 * 4096, seed=1)
    points = [{"start_cwnd_mult": a} for a in (0.5, 1.0, 1.25)]
    cfg1 = SimConfig(link=LINK, tree=TREE, superstep=1)
    cfgk = SimConfig(link=LINK, tree=TREE, superstep=13)

    swk = build_sweep(cfgk, wl, points)
    with trace_guard("engine.step", expect=1):
        states_k = swk.run(max_ticks=30000)
        states_k.now.block_until_ready()

    states_1 = build_sweep(cfg1, wl, points).run(max_ticks=30000)
    np.testing.assert_array_equal(np.asarray(states_1.fct),
                                  np.asarray(states_k.fct))
    np.testing.assert_array_equal(np.asarray(states_1.goodput),
                                  np.asarray(states_k.goodput))
    np.testing.assert_array_equal(np.asarray(states_1.cc.cwnd),
                                  np.asarray(states_k.cc.cwnd))
    assert int(states_1.now[0]) == int(states_k.now[0])


def test_donated_state_is_consumed():
    """The run loops donate their input state: callers must not reuse a
    SimState after passing it to a run loop (DESIGN.md Sec. 6 contract).
    Sim.run builds a fresh init() per call, so back-to-back runs agree."""
    wl = workloads.incast(TREE, degree=3, size_bytes=16 * 4096, seed=3)
    sim, st_a = _run(TREE, wl, superstep=0)
    st_b = sim.run(max_ticks=30000)
    np.testing.assert_array_equal(np.asarray(st_a.fct), np.asarray(st_b.fct))


def test_legacy_baseline_matches_production_trajectory():
    """benchmarks/legacy.py (the perf baseline) must stay a faithful
    *semantic* twin of the production step — only the op structure may
    differ — so ticks/sec comparisons measure the engine, not the load.
    (Compared on simulated outcomes, not the full pytree: the baseline
    intentionally keeps the seed's unconditional trim_seen ledger, which
    the production step gates on credit-based algorithms.)"""
    pytest.importorskip("benchmarks.legacy")
    from benchmarks.legacy import build_legacy
    from benchmarks.perf import _run_k1_ungated

    wl = workloads.permutation(OVERSUB, size_bytes=64 * 4096, seed=4)
    cfg = SimConfig(link=LINK, tree=OVERSUB)
    leg = build_legacy(cfg, wl)
    st_l = _run_k1_ungated(leg.step, leg.init(), 30000)
    _, st_p = _run(OVERSUB, wl, superstep=0)
    np.testing.assert_array_equal(np.asarray(st_l.fct), np.asarray(st_p.fct))
    np.testing.assert_array_equal(np.asarray(st_l.goodput),
                                  np.asarray(st_p.goodput))
    assert int(st_l.now) == int(st_p.now)


def test_superstep_exact_dependency_gated_collectives():
    """K>1 vs K=1 under dependency gating (DESIGN.md Sec. 11): a flow
    released mid-superstep by a parent's chunk landing must activate on
    exactly the same tick inside the fused body."""
    wl = collectives.ring_allreduce(TREE, chunk_bytes=4 * 4096, nodes=8)
    _, st1 = _run(TREE, wl, superstep=1)
    assert bool(np.asarray(st1.done).all())
    for k in (0, 7):          # 0 = auto (one base RTT); 7 doesn't divide
        _, stk = _run(TREE, wl, superstep=k)
        _assert_state_equal(st1, stk)
