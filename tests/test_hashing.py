"""netsim/hashing.py: golden bitwise values (the mixers feed ECMP path
choice and RED mark draws, so any cross-host/library drift silently
changes every trajectory), uniform01 range/mean sanity, and avalanche
behavior of single-bit input flips."""

import numpy as np

from repro.netsim import hashing

INPUTS = np.array([0, 1, 2, 0xDEADBEEF, 0x7FFFFFFF], np.uint32)

# Golden values pinned from the splitmix/murmur3-style constants; these
# must never change without a deliberate (trajectory-breaking) decision.
GOLD_MIX32 = [0x00000000, 0x514E28B7, 0x30F4C306, 0x0DE5C6A9, 0xF9CC0EA8]
GOLD_HASH2 = [0x46D13876, 0x70F7BBF2, 0x8C3E5FDB, 0xBC56A58D, 0xAE93B3F5]
GOLD_HASH3 = [0xCCB1A8F1, 0x8537BDD9, 0x5AE6B032, 0x5BAA5382, 0xD4ABBCFA]


def test_mix32_golden():
    out = np.asarray(hashing.mix32(INPUTS), np.uint32)
    np.testing.assert_array_equal(out, np.array(GOLD_MIX32, np.uint32))


def test_hash2_golden():
    out = np.asarray(hashing.hash2(INPUTS, np.uint32(0x1234)), np.uint32)
    np.testing.assert_array_equal(out, np.array(GOLD_HASH2, np.uint32))


def test_hash3_golden():
    out = np.asarray(hashing.hash3(INPUTS, np.uint32(7), np.uint32(9)),
                     np.uint32)
    np.testing.assert_array_equal(out, np.array(GOLD_HASH3, np.uint32))


def test_hash2_lane_asymmetry():
    """hash2 must not be symmetric in its lanes (a sender/rack salt swap
    would otherwise collide)."""
    a = np.asarray(hashing.hash2(np.uint32(3), np.uint32(17)))
    b = np.asarray(hashing.hash2(np.uint32(17), np.uint32(3)))
    assert int(a) != int(b)


def test_uniform01_range_and_mean():
    u = np.asarray(hashing.uniform01(np.arange(10000, dtype=np.int32),
                                     np.int32(42)))
    assert u.dtype == np.float32
    assert np.all(u >= 0.0) and np.all(u < 1.0)
    assert abs(float(u.mean()) - 0.5) < 0.01
    # distinct salts decorrelate the draw (the engine's per-run `salt`)
    v = np.asarray(hashing.uniform01(np.arange(10000, dtype=np.int32),
                                     np.int32(43)))
    assert not np.array_equal(u, v)


def test_mix32_avalanche():
    """Flipping any single input bit flips ~half the 32 output bits on
    average (murmur3 finalizer property) — this is what makes counter-based
    draws usable as i.i.d. uniforms."""
    x = np.arange(256, dtype=np.uint32)
    h0 = np.asarray(hashing.mix32(x))
    for bit in range(32):
        hb = np.asarray(hashing.mix32(x ^ np.uint32(1 << bit)))
        flipped = np.unpackbits((h0 ^ hb).view(np.uint8)).sum() / x.size
        assert 13.0 < flipped < 19.0, (bit, flipped)
