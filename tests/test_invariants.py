"""Engine-wide packet-conservation invariant.

Every data packet the NICs ever emitted is, at every tick boundary, in
exactly one place: delivered at a receiver, trimmed/dropped at a full
queue, blackholed on a dead link, parked in a port queue, or in flight on
the wire ring.  Emissions are counted from transport state (``next_seq``
counts first sends, ``n_retx`` counts retransmissions), so the ledger

    sum(next_seq) + n_retx ==
        delivered + trimmed + dropped + blackholed + queued + on_wire

closes with no slack term — the soundness contract the delay-ring design
(zero-on-read; valid entry <=> live event) and therefore the event-horizon
leap machinery rest on (DESIGN.md Sec. 6.3).  Checked tick by tick, for
trimming on and off, on two- and three-tier fabrics including faulted
links.
"""

import jax
import numpy as np
import pytest

from repro.netsim import workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.units import FatTreeConfig, LinkConfig

LINK = LinkConfig()
TREE2 = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)        # 4:1
TREE3 = FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2,
                      pods=2, core_uplinks=1)                      # core 2:1


def _conservation_ledger(dims, st):
    sent = int(np.sum(np.asarray(st.next_seq))) + int(st.m.n_retx)
    on_wire = int(np.sum(np.asarray(st.infl)[:, :, 0] == 1))
    queued = int(np.sum(np.asarray(st.q_size)[:dims.NQ]))
    sunk = (int(st.m.delivered_pkts) + int(st.m.n_trim)
            + int(st.m.n_drop) + int(st.m.n_black))
    return sent, sunk + on_wire + queued


def _check_conservation(tree, wl, ticks, **cfg_kw):
    sim = build(SimConfig(link=LINK, tree=tree, **cfg_kw), wl)
    step = jax.jit(sim.step)
    st = sim.init()
    for t in range(ticks):
        st = step(st)
        sent, accounted = _conservation_ledger(sim.dims, st)
        assert sent == accounted, (
            f"tick {t + 1}: {sent} packets sent but {accounted} accounted "
            f"(delivered+trimmed+dropped+blackholed+queued+on-wire)")
    return st


@pytest.mark.parametrize("trimming", [True, False],
                         ids=["trim", "drop"])
def test_conservation_two_tier_oversubscribed(trimming):
    """A 4:1 incast overflows queues: the trim (or drop) path must account
    for every rejected packet, every tick."""
    wl = workloads.incast(TREE2, degree=6, size_bytes=24 * 4096, seed=0)
    st = _check_conservation(TREE2, wl, 500, trimming=trimming)
    lost = int(st.m.n_trim) if trimming else int(st.m.n_drop)
    assert lost > 0, "scenario was meant to overflow queues"


@pytest.mark.parametrize("trimming", [True, False],
                         ids=["trim", "drop"])
def test_conservation_three_tier_core(trimming):
    """Cross-core permutation on an oversubscribed three-tier fabric."""
    wl = workloads.permutation(TREE3, size_bytes=24 * 4096, seed=2)
    st = _check_conservation(TREE3, wl, 500, trimming=trimming)
    assert int(st.m.delivered_pkts) > 0


def test_conservation_with_dead_and_degraded_core_links():
    """Blackholed packets leave the fabric through the n_black counter;
    a half-rate core link only delays, never loses."""
    wl = workloads.permutation(TREE3, size_bytes=64 * 4096, seed=3)
    st = _check_conservation(
        TREE3, wl, 600,
        faults=(("t1_up", 0, 0, 0), ("t2_down", 0, 1, 2)), fault_start=0)
    assert int(st.m.n_black) > 0, "dead core uplink never blackholed"


def test_conservation_under_dynamic_fault_schedule():
    """The ledger must close tick by tick through fail -> degrade ->
    repair transitions and a whole-switch kill (every port the switch
    owns blackholes at once, then all come back) — ISSUE 8 soundness."""
    from repro.netsim.faults import FaultEvent, FaultSchedule
    wl = workloads.permutation(TREE3, size_bytes=64 * 4096, seed=3)
    sched = FaultSchedule(events=(
        FaultEvent(t=50, kind="t1_up", i=0, j=0, period=0),
        FaultEvent(t=200, kind="t1_up", i=0, j=0, period=2),
        FaultEvent(t=350, kind="t1_up", i=0, j=0, period=1),
        FaultEvent(t=120, kind="switch", i=5, period=0),       # a T1 switch
        FaultEvent(t=420, kind="switch", i=5, period=1)))
    st = _check_conservation(TREE3, wl, 600, faults=sched)
    assert int(st.m.n_black) > 0, "schedule never blackholed a packet"


def test_conservation_with_recovery_transport():
    """RTO backoff + REPS eviction change *when* retransmissions happen,
    never how many packets exist — the ledger must stay exact."""
    from repro.netsim.faults import FaultEvent, FaultSchedule
    wl = workloads.permutation(TREE3, size_bytes=64 * 4096, seed=4)
    sched = FaultSchedule(events=(
        FaultEvent(t=30, kind="t1_up", i=1, j=0, period=0),
        FaultEvent(t=450, kind="t1_up", i=1, j=0, period=1)))
    st = _check_conservation(TREE3, wl, 600, faults=sched,
                             rto_backoff_max=3, evict_on_timeout=True)
    assert int(st.m.n_to) > 0, "recovery path never exercised"


@pytest.mark.parametrize("trimming", [True, False],
                         ids=["trim", "drop"])
def test_conservation_pallas_fabric_transport(trimming):
    """The ledger must close identically when the enqueue-rank/arbitration
    and ring-drain kernels run on the pallas backend (interpret mode on
    CPU) — the kernels sit exactly on the enqueue/trim and ACK-drain
    edges the ledger counts."""
    wl = workloads.incast(TREE2, degree=6, size_bytes=16 * 4096, seed=0)
    st = _check_conservation(TREE2, wl, 300, trimming=trimming,
                             fabric_backend="pallas",
                             transport_backend="pallas")
    lost = int(st.m.n_trim) if trimming else int(st.m.n_drop)
    assert lost > 0, "scenario was meant to overflow queues"


def test_conservation_eqds_credit_path():
    """Credit-based EQDS adds grant/credit rings; data-packet conservation
    must be untouched by the control plane."""
    wl = workloads.incast(TREE2, degree=5, size_bytes=16 * 4096, seed=4)
    _check_conservation(TREE2, wl, 400, algo="eqds")


def test_paper_scale_three_tier_bit_parity():
    """The acceptance case at paper scale: on the 512-node three-tier
    permutation, the production engine (superstep auto + leap) and a Study
    lane are both bit-for-bit equal to the plain K=1 leap-off run over the
    full final state pytree."""
    from repro.netsim import api
    from repro.netsim.scenarios import scenario

    sc = scenario("perm_512n_3t")
    base = sc.with_(superstep=1, leap=False).build()
    assert base.dims.tiers == 3 and base.dims.N == 512
    st_ref = base.run(max_ticks=sc.max_ticks)
    st_prod = sc.build().run(max_ticks=sc.max_ticks)  # production defaults
    lane = api.study(sc).run_states()     # 1-point x 1-seed lane batch
    for a, b in zip(jax.tree.leaves(st_ref), jax.tree.leaves(st_prod)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(st_ref), jax.tree.leaves(lane)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[0])


def test_conservation_dependency_gated_collective():
    """Dependency gating (DESIGN.md Sec. 11) only delays emissions — it
    must never invent or lose a packet: the ledger closes tick by tick
    through a ring allreduce whose every post-step-0 flow waits on a
    parent chunk, including across the trim-recovery path of the
    oversubscribed core."""
    from repro.netsim import collectives
    wl = collectives.ring_allreduce(TREE3, chunk_bytes=6 * 4096, nodes=8)
    st = _check_conservation(TREE3, wl, 500)
    assert int(st.m.delivered_pkts) > 0
