import os
import sys

# smoke tests and benches must see ONE device; only the dry-run subprocesses
# set xla_force_host_platform_device_count (and they set it themselves).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
