"""Dry-run machinery tests.

The production-mesh compiles need 512 fake devices, which must be set
before jax initializes — so the actual lower+compile runs in a subprocess
(exactly how the real sweep is invoked).  Spec-rule unit tests run inline.
"""

import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import specs as S
from repro.models import lm
from repro.sharding import Shardings

ROOT = os.path.join(os.path.dirname(__file__), "..")


class FakeMesh:
    axis_names = ("pod", "data", "model")
    axis_sizes = (2, 16, 16)


def _specs_for(arch, fsdp=False):
    cfg = get_config(arch)
    sh = Shardings(FakeMesh())
    sds = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.key(0)))
    return cfg, S.param_specs(cfg, sh, sds, fsdp=fsdp), sds


def _leaf(tree, *path):
    for p in path:
        tree = tree[p]
    return tree


def test_param_specs_tp_rules():
    cfg, specs, sds = _specs_for("qwen3-0.6b")
    g0 = specs["groups"][0]
    assert g0["mixer"]["wq"] == P(None, None, "model")
    assert g0["mixer"]["wo"] == P(None, "model", None)
    assert g0["ffn"]["down"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)


def test_param_specs_divisibility_fallback():
    """qwen2: the fused 14*64=896 projection dim shards (896 % 16 == 0),
    but the 14-way *head* layout cannot — constrain_heads must fall back."""
    cfg, specs, sds = _specs_for("qwen2-0.5b")
    g0 = specs["groups"][0]
    assert g0["mixer"]["wq"] == P(None, None, "model")    # fused dim divides
    assert g0["ffn"]["gate"] == P(None, None, "model")    # 4864 divides
    sh = Shardings(FakeMesh())
    assert sh.maybe("model", cfg.n_heads, "heads") is None     # 14 -> replicate
    assert sh.maybe("model", cfg.n_kv_heads, "kv") is None     # 2  -> replicate
    # minicpm3: 40 heads also fall back; latent ranks shard
    cfg2, specs2, _ = _specs_for("minicpm3-4b")
    assert sh.maybe("model", cfg2.n_heads, "heads") is None
    assert specs2["groups"][0]["mixer"]["wdkv"] == P(None, None, "model")


def test_param_specs_moe_ep_vs_tp():
    _, specs, _ = _specs_for("dbrx-132b", fsdp=True)      # 16 experts -> EP
    g0 = specs["groups"][0]
    assert g0["ffn"]["gate"][1] == "model"
    _, specs, _ = _specs_for("mixtral-8x22b", fsdp=True)  # 8 experts -> TP
    g0 = specs["groups"][0]
    assert g0["ffn"]["gate"][1] != "model"
    assert g0["ffn"]["gate"][3] == "model"


def test_param_specs_fsdp_adds_data_axes():
    _, specs, _ = _specs_for("llama-3.2-vision-90b", fsdp=True)
    g0 = specs["groups"][0]
    assert g0["mixer"]["wq"] == P(None, ("pod", "data"), "model")


def test_jamba_hybrid_specs_cover_all_kinds():
    cfg, specs, sds = _specs_for("jamba-1.5-large-398b", fsdp=True)
    kinds = set()
    for pos, spec in enumerate(specs["groups"]):
        kinds.update(spec["mixer"].keys())
    assert "wz" in kinds and ("wq" in kinds)              # mamba + attn mix


@pytest.mark.slow
def test_dryrun_subprocess_reduced_cells():
    """End-to-end: lower+compile two reduced cells on the 512-device mesh
    in a fresh interpreter (XLA_FLAGS isolation)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    for arch, shape, extra in (
            ("qwen3-0.6b", "train_4k", ["--multi-pod"]),
            ("mamba2-780m", "decode_32k", []),
    ):
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--reduced", *extra],
            env=env, cwd=ROOT, capture_output=True, text=True, timeout=500)
        assert out.returncode == 0, out.stdout + out.stderr


@pytest.mark.slow
def test_compressed_allreduce_subprocess():
    """int8 error-feedback all-reduce on a fake 8-device mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.train.compression import make_compressed_allreduce, BLOCK
mesh = jax.make_mesh((8,), ("data",))
fn, world = make_compressed_allreduce(mesh, "data")
rng = np.random.default_rng(0)
N = 8 * BLOCK * 4
g = jnp.asarray(rng.standard_normal((8, N)), jnp.float32)
err = jnp.zeros((8, N), jnp.float32)
out, err2 = fn(g, err)
want = np.asarray(g).mean(0)
got = np.asarray(out)[0]
rel = np.abs(got - want).max() / np.abs(want).max()
assert rel < 0.02, rel
# error feedback: residual is bounded by the quantization step
assert np.abs(np.asarray(err2)).max() < np.abs(np.asarray(g)).max() / 64
print("compressed allreduce OK", rel)
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=ROOT,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
