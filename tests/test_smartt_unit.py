"""Unit tests for the paper-faithful SMaRTT update rules (Alg. 1-3)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import registry
from repro.core.smartt import smartt_update
from repro.core.types import CCEvent, init_cc_state, make_cc_params

MTU = 4096.0
BDP = 26 * 4096.0


def params(**kw):
    return make_cc_params(mtu=MTU, bdp=BDP, brtt=26.0, **kw)


def event(F=1, **kw):
    base = dict(has_ack=True, ack_bytes=MTU, ecn=False, rtt=26.0,
                ack_entropy=0, n_trims=0, trim_bytes=0.0, n_timeouts=0,
                to_bytes=0.0, unacked=8 * MTU, credit_grant=0.0)
    base.update(kw)
    out = {}
    for k, v in base.items():
        dt = jnp.int32 if k in ("ack_entropy", "n_trims", "n_timeouts") else None
        if isinstance(v, bool) or k in ("has_ack", "ecn"):
            out[k] = jnp.full((F,), bool(v))
        else:
            out[k] = jnp.full((F,), v, dt or jnp.float32)
    return CCEvent(**out)


def mk_state(p, F=1, **kw):
    s = init_cc_state(F, p)
    return s._replace(**{k: jnp.full((F,), v,
                                     s._asdict()[k].dtype) for k, v in kw.items()})


def test_mult_increase_grows_window():
    p = params()
    s = mk_state(p, cwnd=10 * MTU, avg_wtd=0.0, qa_end=1000.0, fi_count=0.0)
    # rtt well below trtt but above FastIncrease's near-base band
    s2 = smartt_update(p, s, event(rtt=32.0), now=1)
    mi = float(p.mi)
    want = min(MTU, (39.0 - 32.0) / 32.0 * MTU / (10 * MTU) * MTU * mi) \
        + MTU / (10 * MTU) * MTU * float(p.fi)        # Eq. 4 + Eq. 3
    assert np.isclose(float(s2.cwnd[0] - s.cwnd[0]), want, rtol=1e-5)


def test_fair_decrease_exact():
    p = params()
    s = mk_state(p, cwnd=10 * MTU, avg_wtd=1.0, qa_end=1000.0)
    s2 = smartt_update(p, s, event(ecn=True, rtt=30.0), now=1)
    want = -(10 * MTU) / BDP * 0.8 * MTU               # Eq. 1
    assert np.isclose(float(s2.cwnd[0] - s.cwnd[0]), want, rtol=1e-5)


def test_mult_decrease_includes_fd_and_caps_at_packet():
    p = params()
    s = mk_state(p, cwnd=10 * MTU, avg_wtd=1.0, qa_end=1000.0)
    rtt = 80.0     # >> trtt=39 -> md term hits the min(p.size) cap
    s2 = smartt_update(p, s, event(ecn=True, rtt=rtt), now=1)
    md_amt = min(MTU, (rtt - 39.0) / rtt * 2.0 * MTU)
    fd_amt = (10 * MTU) / BDP * 0.8 * MTU
    assert np.isclose(float(s2.cwnd[0] - s.cwnd[0]), -(md_amt + fd_amt), rtol=1e-5)


def test_wtd_blocks_decrease_until_threshold():
    p = params()
    s = mk_state(p, cwnd=10 * MTU, avg_wtd=0.0, qa_end=1000.0)
    s2 = smartt_update(p, s, event(ecn=True, rtt=30.0), now=1)
    assert float(s2.cwnd[0]) == float(s.cwnd[0])       # no decrease yet
    assert float(s2.avg_wtd[0]) > 0


def test_quickadapt_sets_window_to_received_bytes():
    p = params()
    s = mk_state(p, cwnd=20 * MTU, qa_end=10.0, trigger_qa=True,
                 acked=5 * MTU, avg_wtd=1.0)
    # ACK at a tick past qa_end: fire. acked first absorbs this ACK (Alg.1 l.4)
    s2 = smartt_update(p, s, event(ecn=True, rtt=100.0, unacked=12 * MTU), now=50)
    want = max(6 * MTU, MTU) * 0.8                     # Alg. 2 l. 7
    assert np.isclose(float(s2.cwnd[0]), want, rtol=1e-5)
    assert not bool(s2.trigger_qa[0])
    assert float(s2.bytes_to_ignore[0]) == 12 * MTU
    assert float(s2.qa_end[0]) == 50 + 39.0


def test_quickadapt_at_most_once_per_trtt():
    p = params()
    s = mk_state(p, cwnd=20 * MTU, qa_end=10.0, trigger_qa=True,
                 acked=5 * MTU)
    s2 = smartt_update(p, s, event(), now=50)
    # re-arm trigger inside the same window: must NOT fire again
    s3 = smartt_update(p, s2._replace(trigger_qa=jnp.array([True])),
                       event(), now=55)
    assert float(s3.cwnd[0]) != float(s3.acked[0]) * 0.8 or \
        float(s3.qa_end[0]) == 50 + 39.0
    assert bool(s3.trigger_qa[0])                      # still armed


def test_fast_increase_after_uncongested_window():
    p = params()
    s = mk_state(p, cwnd=4 * MTU, qa_end=1000.0)
    for t in range(6):
        s = smartt_update(p, s, event(rtt=26.0), now=t)
    # count exceeded cwnd -> +k*mtu per subsequent ACK
    before = float(s.cwnd[0])
    s2 = smartt_update(p, s, event(rtt=26.0), now=10)
    assert float(s2.cwnd[0]) - before >= 2 * MTU - 1
    assert bool(s2.fi_active[0])


def test_trim_decrements_and_arms_quickadapt():
    p = params()
    s = mk_state(p, cwnd=10 * MTU, qa_end=1000.0)
    s2 = smartt_update(p, s, event(has_ack=False, n_trims=2,
                                   trim_bytes=2 * MTU), now=5)
    assert np.isclose(float(s2.cwnd[0]), 8 * MTU)
    assert bool(s2.trigger_qa[0])


def test_timeout_counts_as_loss():
    p = params()
    s = mk_state(p, cwnd=10 * MTU, qa_end=1000.0)
    s2 = smartt_update(p, s, event(has_ack=False, n_timeouts=1,
                                   to_bytes=MTU), now=5)
    assert np.isclose(float(s2.cwnd[0]), 9 * MTU)
    assert bool(s2.trigger_qa[0])


def test_clamp_bounds():
    p = params()
    s = mk_state(p, cwnd=1.24 * 26 * MTU, qa_end=1000.0, fi_active=True,
                 fi_count=1e9)
    s2 = smartt_update(p, s, event(rtt=26.0), now=1)
    assert float(s2.cwnd[0]) <= float(p.maxcwnd) + 1e-3
    s3 = mk_state(p, cwnd=1.5 * MTU, avg_wtd=1.0, qa_end=1000.0)
    for t in range(10):
        s3 = smartt_update(p, s3, event(ecn=True, rtt=100.0), now=t)
    assert float(s3.cwnd[0]) >= MTU - 1e-3


def test_md_doubles_without_trimming():
    p_trim = make_cc_params(mtu=MTU, bdp=BDP, brtt=26.0, use_trimming=True)
    p_noto = make_cc_params(mtu=MTU, bdp=BDP, brtt=26.0, use_trimming=False)
    assert float(p_noto.md) == 2 * float(p_trim.md)


def test_ignore_phase_swallows_acks():
    p = params()
    s = mk_state(p, cwnd=10 * MTU, bytes_to_ignore=3 * MTU,
                 bytes_ignored=0.0, avg_wtd=1.0, qa_end=1000.0)
    # Alg. 1 l. 4-10: the check runs *after* the increment, so a 3-MTU
    # budget swallows exactly two ACKs (the third makes ignored == budget).
    for t in range(2):
        s = smartt_update(p, s, event(ecn=True, rtt=100.0), now=t)
    assert float(s.cwnd[0]) == 10 * MTU
    s = smartt_update(p, s, event(ecn=True, rtt=100.0), now=4)
    assert float(s.cwnd[0]) < 10 * MTU                 # phase over, MD applies


@pytest.mark.parametrize("algo", sorted(registry.ALGORITHMS))
def test_all_algorithms_run_and_clamp(algo):
    p = params()
    s = init_cc_state(4, p)
    fn = registry.get(algo)
    for t in range(20):
        s = fn(p, s, event(F=4, ecn=(t % 2 == 0), rtt=20.0 + 3 * t), now=t)
    c = np.asarray(s.cwnd)
    assert np.all(np.isfinite(c))
    if algo not in ("eqds",):   # vanilla EQDS pins cwnd to the cap
        assert np.all(c >= MTU - 1e-3) and np.all(c <= float(p.maxcwnd) + 1e-3)
