"""Engine-level backend parity: the Pallas cc_update kernel wired into the
simulator hot loop must be bit-for-bit interchangeable with the pure-jnp
update (interpret mode on CPU; same contract compiled on TPU)."""

import numpy as np
import pytest

from repro.core import registry
from repro.netsim.engine import SimConfig, build, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim import workloads

TREE = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)


def _run(backend):
    wl = workloads.incast(TREE, degree=3, size_bytes=16 * 4096, seed=0)
    sim = build(SimConfig(link=LinkConfig(), tree=TREE, algo="smartt",
                          cc_backend=backend), wl)
    st = sim.run(max_ticks=20000)
    st.now.block_until_ready()
    return sim, st


def test_pallas_backend_matches_jnp_bit_for_bit():
    sim_j, st_j = _run("jnp")
    sim_p, st_p = _run("pallas")
    s_j, s_p = summarize(sim_j, st_j), summarize(sim_p, st_p)
    assert s_j["all_done"] and s_p["all_done"]
    np.testing.assert_array_equal(np.asarray(st_j.fct), np.asarray(st_p.fct))
    np.testing.assert_array_equal(np.asarray(st_j.goodput),
                                  np.asarray(st_p.goodput))
    # stronger than the acceptance bar: the whole CC trajectory endpoint
    np.testing.assert_array_equal(np.asarray(st_j.cc.cwnd),
                                  np.asarray(st_p.cc.cwnd))
    assert int(st_j.now) == int(st_p.now)
    assert s_j["trims"] == s_p["trims"] and s_j["acks"] == s_p["acks"]


def test_registry_backend_resolution():
    assert registry.get("smartt") is registry.get("smartt", "jnp")
    assert registry.get("smartt", "pallas") is not registry.get("smartt")
    with pytest.raises(KeyError):
        registry.get("swift", "pallas")       # no pallas port of baselines
    with pytest.raises(KeyError):
        registry.get("smartt", "cuda")        # unknown backend
    with pytest.raises(KeyError):
        registry.get("nope")
