"""Engine-level backend parity: the Pallas kernels wired into the
simulator hot loop (cc_update, the fused enqueue-rank + arbitration
kernel, the packed sent-ring drain) must be bit-for-bit interchangeable
with the pure-jnp phases (interpret mode on CPU; same contract compiled
on TPU)."""

import jax
import numpy as np
import pytest

from repro.core import registry
from repro.kernels.enqueue_arb import ops as enqueue_arb_ops
from repro.kernels.ring_drain import ops as ring_drain_ops
from repro.netsim.engine import SimConfig, build, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim import collectives, workloads

TREE = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)
TREE_3T = FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2,
                        pods=2, core_uplinks=2)


def _run(backend):
    wl = workloads.incast(TREE, degree=3, size_bytes=16 * 4096, seed=0)
    sim = build(SimConfig(link=LinkConfig(), tree=TREE, algo="smartt",
                          cc_backend=backend), wl)
    st = sim.run(max_ticks=20000)
    st.now.block_until_ready()
    return sim, st


def _assert_states_equal(st_a, st_b):
    la, _ = jax.tree.flatten(st_a)
    lb, _ = jax.tree.flatten(st_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_backend_matches_jnp_bit_for_bit():
    sim_j, st_j = _run("jnp")
    sim_p, st_p = _run("pallas")
    s_j, s_p = summarize(sim_j, st_j), summarize(sim_p, st_p)
    assert s_j["all_done"] and s_p["all_done"]
    np.testing.assert_array_equal(np.asarray(st_j.fct), np.asarray(st_p.fct))
    np.testing.assert_array_equal(np.asarray(st_j.goodput),
                                  np.asarray(st_p.goodput))
    # stronger than the acceptance bar: the whole CC trajectory endpoint
    np.testing.assert_array_equal(np.asarray(st_j.cc.cwnd),
                                  np.asarray(st_p.cc.cwnd))
    assert int(st_j.now) == int(st_p.now)
    assert s_j["trims"] == s_p["trims"] and s_j["acks"] == s_p["acks"]


def _run_fixed(tree, *, fabric_backend, transport_backend, ticks, wl=None,
               **cfg):
    if wl is None:
        wl = workloads.permutation(tree, size_bytes=32 * 1024, seed=2)
    sim = build(SimConfig(link=LinkConfig(), tree=tree, algo="smartt",
                          fabric_backend=fabric_backend,
                          transport_backend=transport_backend, **cfg), wl)
    st = sim.run(max_ticks=ticks)
    st.now.block_until_ready()
    return st


@pytest.mark.parametrize("tree", [TREE, TREE_3T], ids=["2tier", "3tier"])
def test_fabric_transport_pallas_matches_jnp_bit_for_bit(tree):
    """The fused enqueue-rank/arbitration kernel and the packed ring-drain
    kernel, engine-deep: every SimState leaf bitwise equal to the jnp
    phases after a full permutation run (2-tier and 3-tier fabrics)."""
    st_j = _run_fixed(tree, fabric_backend="jnp", transport_backend="jnp",
                      ticks=6000)
    st_p = _run_fixed(tree, fabric_backend="pallas",
                      transport_backend="pallas", ticks=6000)
    _assert_states_equal(st_j, st_p)


def test_pallas_drain_timeout_path_matches_jnp():
    """Trimming off forces losses to recover via RTO — the lost/timeout
    lanes of the ring-drain kernel, not just the ACK-free path."""
    wl = workloads.incast(TREE, degree=3, size_bytes=8 * 4096, seed=1)
    st_j = _run_fixed(TREE, fabric_backend="jnp", transport_backend="jnp",
                      ticks=8000, wl=wl, trimming=False)
    st_p = _run_fixed(TREE, fabric_backend="pallas",
                      transport_backend="pallas", ticks=8000, wl=wl,
                      trimming=False)
    _assert_states_equal(st_j, st_p)


def test_kernel_ops_backend_resolution():
    for mod in (enqueue_arb_ops, ring_drain_ops):
        with pytest.raises(KeyError):
            mod.get("cuda")
        with pytest.raises(KeyError):
            mod.get("")
    enq, arb = enqueue_arb_ops.get("jnp")
    assert callable(enq) and callable(arb)
    assert callable(ring_drain_ops.get("pallas"))


def test_registry_backend_resolution():
    assert registry.get("smartt") is registry.get("smartt", "jnp")
    assert registry.get("smartt", "pallas") is not registry.get("smartt")
    with pytest.raises(KeyError):
        registry.get("swift", "pallas")       # no pallas port of baselines
    with pytest.raises(KeyError):
        registry.get("smartt", "cuda")        # unknown backend
    with pytest.raises(KeyError):
        registry.get("nope")


def test_fabric_transport_pallas_dependency_gated_collective():
    """Backend parity under dependency gating (DESIGN.md Sec. 11): the
    activation predicate reads goodput the ring-drain kernel helped
    produce, so the kernels and the jnp phases must release every
    dependent flow on the same tick, engine-deep."""
    wl = collectives.ring_allreduce(TREE_3T, chunk_bytes=2 * 4096, nodes=8)
    st_j = _run_fixed(TREE_3T, fabric_backend="jnp", transport_backend="jnp",
                      ticks=8000, wl=wl)
    st_p = _run_fixed(TREE_3T, fabric_backend="pallas",
                      transport_backend="pallas", ticks=8000, wl=wl)
    assert bool(np.asarray(st_j.done).all())
    _assert_states_equal(st_j, st_p)
