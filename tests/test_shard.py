"""Device-sharded lane execution (netsim/shard.py, DESIGN.md Sec. 7):
the shard_map path must be bit-for-bit identical to the single-device
vmap path — full final-state pytree, every lane — and lane padding must
be inert ballast.

Single-device runs exercise the shard_map machinery on a 1-device mesh
(same partition specs, same loop body); the true multi-device parity
tests run wherever >= 2 host devices are forced
(``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — CI's
multidevice job) and skip elsewhere.
"""

import jax
import numpy as np
import pytest

from repro.netsim import api, engine, shard

MULTI = jax.device_count() >= 2

POINTS = ({}, {"start_cwnd_mult": 0.5})
SEEDS = (0, 1, 2)


def _study():
    return api.study("tiny_3t", points=POINTS, seeds=SEEDS)


def _assert_state_equal(st_a, st_b):
    la, lb = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _sharded(st, mesh, max_ticks=None):
    """Run a Study's lane batch through the shard_map path explicitly
    (``run_lanes`` would short-circuit a 1-device mesh to vmap)."""
    mt = st._max_ticks(max_ticks)
    horizon_fn = st.sim.horizon_fn if st.sim.dims.leap else None
    states, consts_p, n_pad = shard.pad_lanes(st.init(), st.consts_b,
                                              st.axes, mesh.size)
    out = shard._run_lanes_sharded(st.sim.step_fn, horizon_fn, st.axes, mt,
                                   st.sim.dims.superstep, mesh, consts_p,
                                   states)
    if n_pad:
        out = jax.tree.map(lambda x: x[:st.n_lanes], out)
    return out


# --------------------------------------------------------------------------
# single-device (runs everywhere)
# --------------------------------------------------------------------------


def test_shard_map_on_one_device_matches_vmap():
    """shard_map with a 1-device mesh is the same program as the vmap
    path — bit-identical full final states."""
    st = _study()
    ref = st.run_states()
    out = _sharded(st, shard.lane_mesh(jax.devices()[:1]))
    _assert_state_equal(ref, out)


def test_run_lanes_short_circuits_small_mesh():
    """``run_lanes(mesh=1-device)`` must take the plain vmap path and
    stay bit-identical to ``mesh=None``."""
    st = _study()
    ref = st.run_states()
    out = st.run_states(mesh=shard.lane_mesh(jax.devices()[:1]))
    _assert_state_equal(ref, out)


def test_pad_lanes_shapes_and_inertness():
    """Padding to a non-dividing multiple appends copies of the last lane
    with every flow done; the gated loop then freezes them bitwise (a pad
    lane's final state == its initial state) while real lanes are
    untouched."""
    st = _study()
    B = st.n_lanes
    padded, consts_p, n_pad = shard.pad_lanes(st.init(), st.consts_b,
                                              st.axes, 4)
    assert n_pad == (-B) % 4 and n_pad > 0
    assert padded.now.shape[0] == B + n_pad
    assert bool(np.all(np.asarray(padded.done)[B:]))
    # swept consts leaves padded alongside, deduped leaves untouched
    for leaf, ax in zip(jax.tree.leaves(consts_p),
                        shard.axes_leaves(st.axes)):
        if ax == 0:
            assert np.asarray(leaf).shape[0] == B + n_pad
    # run the padded batch; real lanes match the unpadded run, pad lanes
    # froze at their (done-marked) init
    mesh = shard.lane_mesh(jax.devices()[:1])
    horizon_fn = st.sim.horizon_fn if st.sim.dims.leap else None
    mt = st._max_ticks(None)
    init_pad = jax.device_get(jax.tree.map(lambda x: x[B:], padded))
    out = shard._run_lanes_sharded(st.sim.step_fn, horizon_fn, st.axes, mt,
                                   st.sim.dims.superstep, mesh, consts_p,
                                   padded)
    ref = st.run_states()
    _assert_state_equal(ref, jax.tree.map(lambda x: x[:B], out))
    _assert_state_equal(init_pad, jax.tree.map(lambda x: x[B:], out))


def test_pad_lanes_noop_when_divisible():
    st = _study()
    states0 = st.init()
    padded, consts_p, n_pad = shard.pad_lanes(states0, st.consts_b,
                                              st.axes, st.n_lanes)
    assert n_pad == 0
    assert padded is states0 and consts_p is st.consts_b


# --------------------------------------------------------------------------
# multi-device (CI multidevice job; skips on a single-device host)
# --------------------------------------------------------------------------


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=N)")
def test_multi_device_study_bit_identical_to_vmap():
    """THE acceptance property: a Study sharded over every forced host
    device produces lane states bit-identical to the single-device vmap
    path — full final-state pytree, including ``now`` and metrics.  Lane
    count (6) does not divide the device count, so the pad path is
    exercised too."""
    st = _study()
    ref = st.run_states()
    out = st.run_states(mesh=shard.lane_mesh())
    _assert_state_equal(ref, out)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_multi_device_study_run_results_match():
    """The typed results of a sharded ``Study.run`` are row-for-row equal
    to the plain run."""
    st = _study()
    ref = st.run()
    out = st.run(mesh=shard.lane_mesh())
    assert [r.row() for r in ref.results] == [r.row() for r in out.results]
    _assert_state_equal(ref.states, out.states)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_multi_device_run_batch_matches():
    """``Sim.run_batch(mesh=...)`` parity — and transitively parity with
    every standalone ``run(seed=s)`` (test_api covers that leg)."""
    sc = api._resolve("tiny_3t")
    sim = engine.build(sc.cfg, sc.wl)
    seeds = np.arange(5)
    ref = sim.run_batch(seeds, max_ticks=sc.max_ticks)
    out = sim.run_batch(seeds, max_ticks=sc.max_ticks,
                        mesh=shard.lane_mesh())
    _assert_state_equal(ref, out)
