"""Chaos layer (ISSUE 8): randomized dynamic fault schedules driving the
engine's soundness invariants.

A seeded generator draws arbitrary-but-valid :class:`FaultSchedule`
timelines (fail / degrade / repair events on random ports and switches,
plus bounded flapping windows) that are guaranteed to end all-healthy.
Each drawn schedule must uphold:

* **conservation** — the packet ledger (sent == delivered + trimmed +
  dropped + blackholed + queued + on-wire) closes at every tick boundary;
* **leap parity** — leap-on and leap-off trajectories are bit-for-bit
  identical across the full state pytree (the fault-transition clamp in
  ``fabric.horizon`` is what makes this hold);
* **no permanent stall** — once the last repair lands, every flow
  completes within a generous budget (with and without the recovery
  knobs: a healthy fabric plus armed retransmission timers must always
  drain).

The seeded numpy draws always run; hypothesis (a declared test
dependency — CI installs ``.[test]`` and pins ``derandomize=True``)
additionally drives the same properties through minimized search where
available, matching the ``tests/test_topology.py`` idiom.
"""

import jax
import numpy as np
import pytest

from repro.netsim import workloads
from repro.netsim.engine import SimConfig, build
from repro.netsim.faults import FaultEvent, FaultSchedule, Flap
from repro.netsim.units import FatTreeConfig, LinkConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # local envs without the test extra
    HAVE_HYPOTHESIS = False

LINK = LinkConfig()
TREE3 = FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2,
                      pods=2, core_uplinks=1)                      # core 2:1

# every (kind, i, j) coordinate valid on TREE3, switch kills included
_TARGETS = (
    [("t0_up", i, j) for i in range(4) for j in range(2)]
    + [("t1_up", i, 0) for i in range(4)]
    + [("t2_down", i, j) for i in range(2) for j in range(2)]
    + [("t1_down", i, j) for i in range(4) for j in range(2)]
    + [("switch", i, 0) for i in range(4, 10)]    # T1 + core switches
)

# all real faults end by here; every touched target is repaired at T_HEAL
T_HEAL = 300


def chaos_schedule(seed: int) -> FaultSchedule:
    """A random valid schedule over TREE3 that ends all-healthy: up to 5
    fail/degrade/repair events and up to one flap window, all strictly
    inside [0, T_HEAL), plus a closing repair for every touched target."""
    rng = np.random.default_rng(seed)
    touched, events = set(), []
    for _ in range(int(rng.integers(1, 6))):
        kind, i, j = _TARGETS[int(rng.integers(len(_TARGETS)))]
        t = int(rng.integers(0, 250))
        period = int(rng.choice([0, 0, 1, 2, 3]))   # lean toward dead
        events.append(FaultEvent(t=t, kind=kind, i=i, j=j, period=period))
        touched.add((kind, i, j))
    flaps = ()
    if rng.integers(2):
        kind, i, j = _TARGETS[int(rng.integers(len(_TARGETS)))]
        cycle = int(rng.integers(8, 40))
        up = int(rng.integers(1, cycle))
        t0 = int(rng.integers(0, 120))
        flaps = (Flap(kind=kind, i=i, j=j, up=up, cycle=cycle,
                      t=t0, t_end=int(rng.integers(t0 + 1, T_HEAL))),)
    events += [FaultEvent(t=T_HEAL, kind=k, i=i, j=j, period=1)
               for (k, i, j) in sorted(touched)]
    return FaultSchedule(events=tuple(events), flaps=flaps)


def _recovery_knobs(seed: int) -> dict:
    """Half the draws run with the recovery transport on."""
    if seed % 2:
        return dict(rto_backoff_max=2, evict_on_timeout=True)
    return {}


def _conservation_ledger(dims, st):
    sent = int(np.sum(np.asarray(st.next_seq))) + int(st.m.n_retx)
    on_wire = int(np.sum(np.asarray(st.infl)[:, :, 0] == 1))
    queued = int(np.sum(np.asarray(st.q_size)[:dims.NQ]))
    sunk = (int(st.m.delivered_pkts) + int(st.m.n_trim)
            + int(st.m.n_drop) + int(st.m.n_black))
    return sent, sunk + on_wire + queued


def check_conservation(seed: int, ticks: int = 400) -> None:
    wl = workloads.permutation(TREE3, size_bytes=24 * 4096, seed=seed)
    sched = chaos_schedule(seed)
    sim = build(SimConfig(link=LINK, tree=TREE3, faults=sched,
                          **_recovery_knobs(seed)), wl)
    step = jax.jit(sim.step)
    s = sim.init()
    for t in range(ticks):
        s = step(s)
        sent, accounted = _conservation_ledger(sim.dims, s)
        assert sent == accounted, (
            f"seed {seed} tick {t + 1}: {sent} sent, {accounted} accounted"
            f"\nschedule: {sched}")


def check_leap_parity(seed: int, max_ticks: int = 6000) -> None:
    wl = workloads.permutation(TREE3, size_bytes=24 * 4096, seed=seed)
    sched = chaos_schedule(seed)
    kw = dict(faults=sched, fault_start=int(seed % 3) * 17,
              **_recovery_knobs(seed))
    states = {}
    for leap in (False, True):
        sim = build(SimConfig(link=LINK, tree=TREE3, leap=leap, **kw), wl)
        states[leap] = sim.run(max_ticks=max_ticks)
        states[leap].now.block_until_ready()
    la, lb = jax.tree.leaves(states[False]), jax.tree.leaves(states[True])
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"seed {seed}\n{sched}")


def check_no_permanent_stall(seed: int, budget: int = 30000) -> None:
    wl = workloads.permutation(TREE3, size_bytes=24 * 4096, seed=seed)
    sched = chaos_schedule(seed)
    sim = build(SimConfig(link=LINK, tree=TREE3, faults=sched,
                          **_recovery_knobs(seed)), wl)
    s = sim.run(max_ticks=budget)
    done = np.asarray(s.done)
    assert done.all(), (
        f"seed {seed}: {int(done.sum())}/{done.size} flows done after "
        f"{budget} ticks on an all-healthy-after-{T_HEAL} fabric"
        f"\nschedule: {sched}")


# ---- seeded draws (always run) -------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_chaos_conservation(seed):
    check_conservation(seed)


@pytest.mark.parametrize("seed", range(8))
def test_chaos_leap_parity(seed):
    check_leap_parity(seed)


@pytest.mark.parametrize("seed", range(4))
def test_chaos_no_permanent_stall(seed):
    check_no_permanent_stall(seed)


def test_chaos_schedule_generator_is_valid_and_heals():
    """Generator sanity: every draw compiles against the topology and is
    all-healthy at and after T_HEAL."""
    from repro.netsim import faults as fm
    from repro.netsim.state import derive
    wl = workloads.permutation(TREE3, size_bytes=4096, seed=0)
    topo, _, _, _ = derive(SimConfig(link=LINK, tree=TREE3), wl)
    for seed in range(40):
        cf = fm.compile_tables(chaos_schedule(seed), topo, 0)
        for t in (T_HEAL, T_HEAL + 1, T_HEAL + 1000):
            assert (fm.np_port_period(cf, 0, t) == 1).all(), seed


# ---- hypothesis search (when available; CI pins the seed) ----------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None, derandomize=True)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_chaos_leap_parity_hypothesis(seed):
        check_leap_parity(seed, max_ticks=4000)

    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_chaos_conservation_hypothesis(seed):
        check_conservation(seed, ticks=250)


# ---- dependency-gated collectives under faults (DESIGN.md Sec. 11) -------

def check_collective_no_stall(seed: int, budget: int = 30000) -> None:
    """A mid-collective fault must never deadlock activation: once the
    schedule heals (all-healthy after T_HEAL by construction), stalled
    parents finish via timeout recovery and every dependent flow is
    eventually released — the DAG drains."""
    from repro.netsim import collectives
    wl = collectives.ring_allreduce(TREE3, chunk_bytes=4 * 4096, nodes=8)
    sched = chaos_schedule(seed)
    sim = build(SimConfig(link=LINK, tree=TREE3, faults=sched,
                          **_recovery_knobs(seed)), wl)
    s = sim.run(max_ticks=budget)
    done = np.asarray(s.done)
    assert done.all(), (
        f"seed {seed}: {int(done.sum())}/{done.size} collective flows done "
        f"after {budget} ticks on an all-healthy-after-{T_HEAL} fabric"
        f"\nschedule: {sched}")


@pytest.mark.parametrize("seed", range(4))
def test_chaos_collective_no_permanent_stall(seed):
    check_collective_no_stall(seed)


def test_mid_collective_uplink_kill_does_not_deadlock():
    """The ISSUE's pointed case: kill both uplinks of the rack hosting a
    ring participant mid-collective, heal later; the dependency chain
    threads through the dead rack, so a wrong activation predicate (or a
    lost release) would stall the whole ring forever."""
    from repro.netsim import collectives
    wl = collectives.ring_allreduce(TREE3, chunk_bytes=4 * 4096, nodes=8)
    sched = FaultSchedule(events=(
        FaultEvent(t=40, kind="t0_up", i=0, j=0, period=0),
        FaultEvent(t=40, kind="t0_up", i=0, j=1, period=0),
        FaultEvent(t=400, kind="t0_up", i=0, j=0, period=1),
        FaultEvent(t=400, kind="t0_up", i=0, j=1, period=1)))
    sim = build(SimConfig(link=LINK, tree=TREE3, faults=sched), wl)
    s = sim.run(max_ticks=30000)
    assert int(s.m.n_black) > 0, "the kill never bit"
    assert bool(np.asarray(s.done).all()), "collective stalled permanently"
