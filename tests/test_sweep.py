"""Batched config-sweep runner: one compiled step per grid, pointwise
equivalence with standalone builds, and traced-parameter coverage."""

import numpy as np
import pytest

from repro.analysis import trace_guard
from repro.netsim import engine, workloads
from repro.netsim.state import SimConfig
from repro.netsim.sweep import apply_point, build_sweep
from repro.netsim.units import FatTreeConfig, LinkConfig

TREE = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)
CFG = SimConfig(link=LinkConfig(), tree=TREE, algo="smartt", lb="reps")

POINTS = (
    [{"start_cwnd_mult": a, "react_every": r}
     for a in (0.5, 0.75, 1.0, 1.25) for r in (1, 4)]
    + [{"fd": 0.4, "kmin_frac": 0.1, "kmax_frac": 0.5}]
)


def _wl():
    return workloads.incast(TREE, degree=4, size_bytes=32 * 4096, seed=1)


def test_grid_costs_exactly_one_step_compilation():
    sw = build_sweep(CFG, _wl(), POINTS)
    assert sw.n_points == 9
    with trace_guard("engine.step", expect=1):
        states = sw.run(max_ticks=30000)
        states.now.block_until_ready()
    assert bool(np.all(np.asarray(states.done)))
    rows = sw.summaries(states)
    assert len(rows) == len(POINTS) and all(r["all_done"] for r in rows)
    # the sweep actually sweeps: start_cwnd changes the congestion story
    fct_max = [r["fct_max"] for r in rows]
    assert len(set(fct_max)) > 1


def test_swept_point_matches_standalone_build():
    wl = _wl()
    sw = build_sweep(CFG, wl, POINTS)
    states = sw.run(max_ticks=30000)
    for i in (0, 3, len(POINTS) - 1):
        sim_i = engine.build(apply_point(CFG, POINTS[i]), wl)
        st_i = sim_i.run(max_ticks=30000)
        np.testing.assert_array_equal(np.asarray(st_i.fct),
                                      np.asarray(states.fct)[i])
        np.testing.assert_array_equal(np.asarray(st_i.goodput),
                                      np.asarray(states.goodput)[i])


def test_unsweepable_key_raises():
    with pytest.raises(KeyError):
        build_sweep(CFG, _wl(), [{"algo": 1.0}])
    with pytest.raises(ValueError):
        build_sweep(CFG, _wl(), [])


def test_apply_point_routes_cc_keys_into_overrides():
    cfg = apply_point(CFG, {"fd": 0.5, "start_cwnd_mult": 0.7})
    assert ("fd", 0.5) in cfg.cc_overrides
    assert cfg.start_cwnd_mult == 0.7


def test_apply_point_unknown_key_names_the_valid_ones():
    with pytest.raises(KeyError, match="unsweepable key 'bogus'") as ei:
        apply_point(CFG, {"bogus": 1.0})
    assert "start_cwnd_mult" in str(ei.value)      # actionable: lists keys


@pytest.mark.parametrize("key", ["superstep", "leap", "trimming",
                                 "cc_backend", "lb", "tree"])
def test_apply_point_dims_changing_key_raises(key):
    """Keys that change Dims (shapes/branch selectors) cannot ride one
    compiled step; the error says to build one Scenario per value."""
    with pytest.raises(KeyError, match="changes Dims"):
        apply_point(CFG, {key: 1})


def test_summaries_rows_line_up_with_points_order():
    """Sweep.summaries must return rows in ``points`` order: row i is the
    summary of the standalone build of points[i]."""
    points = [{"start_cwnd_mult": a} for a in (1.25, 0.5, 1.0)]   # shuffled
    wl = _wl()
    sw = build_sweep(CFG, wl, points)
    rows = sw.summaries(sw.run(max_ticks=30000))
    assert [dict(p) for p in sw.points] == points
    for i, pt in enumerate(points):
        st_i = engine.build(apply_point(CFG, pt), wl).run(max_ticks=30000)
        np.testing.assert_array_equal(rows[i]["fct_ticks"],
                                      np.asarray(st_i.fct))
        assert rows[i]["ticks"] == int(st_i.now)
    # the swept knob actually distinguishes the rows
    assert len({r["fct_max"] for r in rows}) > 1
