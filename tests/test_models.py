"""Per-architecture smoke tests (reduced configs, CPU): one forward + one
train step, asserting output shapes and the absence of NaNs — plus
family-level consistency checks (decode vs forward, unroll vs scan)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.optim import adamw
from repro.train.step import TrainConfig, make_train_step

KEY = jax.random.key(0)


def make_batch(cfg, b=2, s=32, seed=3):
    k = jax.random.fold_in(KEY, seed)
    out = {}
    if cfg.frontend == "tokens":
        toks = jax.random.randint(k, (b, s), 0, cfg.vocab, jnp.int32)
        out["tokens"] = toks
    else:
        out["embeds"] = (jax.random.normal(k, (b, s, cfg.d_model), jnp.float32)
                         * 0.1).astype(jnp.bfloat16)
        toks = jax.random.randint(k, (b, s), 0, cfg.vocab, jnp.int32)
    out["labels"] = toks
    if cfg.cross_kv_len:
        out["cross"] = (jax.random.normal(
            k, (b, cfg.cross_kv_len, cfg.d_model), jnp.float32) * 0.1
        ).astype(jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = lm.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, aux = lm.forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    tcfg = TrainConfig(adam=adamw.AdamWConfig(lr=1e-3, warmup_steps=1))
    step = make_train_step(cfg, tcfg)
    opt = adamw.init(tcfg.adam, params)
    p2, o2, stats = step(params, opt, batch)
    assert np.isfinite(float(stats["loss"]))
    assert np.isfinite(float(stats["grad_norm"]))
    # parameters actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "minicpm3-4b", "mamba2-780m",
                                  "jamba-1.5-large-398b", "mixtral-8x22b",
                                  "llama-3.2-vision-90b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.n_experts:   # avoid capacity-drop noise in the equivalence check
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = lm.init_params(cfg, KEY)
    B, S, MAX = 2, 16, 24
    kt = jax.random.fold_in(KEY, 7)
    if cfg.frontend == "tokens":
        toks = jax.random.randint(kt, (B, MAX), 0, cfg.vocab, jnp.int32)
        full = {"tokens": toks[:, :S + 1]}
        pre = {"tokens": toks[:, :S]}
        dec = {"tokens": toks[:, S:S + 1]}
    else:
        emb = (jax.random.normal(kt, (B, MAX, cfg.d_model), jnp.float32)
               * 0.1).astype(jnp.bfloat16)
        full = {"embeds": emb[:, :S + 1]}
        pre = {"embeds": emb[:, :S]}
        dec = {"embeds": emb[:, S:S + 1]}
    if cfg.cross_kv_len:
        cross = (jax.random.normal(kt, (B, cfg.cross_kv_len, cfg.d_model),
                                   jnp.float32) * 0.1).astype(jnp.bfloat16)
        full["cross"] = cross
        pre["cross"] = cross
    want, _ = lm.forward(params, cfg, full, remat=False)
    want = np.asarray(want[:, -1], np.float32)
    _, caches, cache_len = lm.prefill(params, cfg, pre, max_len=MAX,
                                      remat=False)
    got, _ = lm.decode_step(params, cfg, dec, caches, cache_len + 1)
    got = np.asarray(got[:, 0], np.float32)
    err = np.max(np.abs(want - got)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 0.05, err


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-1.5-large-398b"])
def test_unroll_matches_scan(arch):
    """The roofline extractor's unrolled lowering is numerically identical
    to the scan-based production path (checked in f32 — bf16 merely
    amplifies reduction-order rounding through deep recurrent stacks)."""
    cfg = get_config(arch, reduced=True)
    params = lm.init_params(cfg, KEY)
    params = jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)
    batch = make_batch(cfg)
    a, _ = lm.forward(params, cfg, batch, remat=False)
    cfg_u = dataclasses.replace(cfg, unroll=True)
    b, _ = lm.forward(params, cfg_u, batch, remat=False)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-4, atol=1e-5)


def test_param_counts_match_public_sizes():
    """Full configs land near their nameplate parameter counts."""
    expect = {
        "qwen2-0.5b": (0.35e9, 0.75e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "minicpm3-4b": (3e9, 5e9),
        "phi3-mini-3.8b": (3.3e9, 4.5e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "mixtral-8x22b": (120e9, 160e9),
        "dbrx-132b": (110e9, 150e9),
        "jamba-1.5-large-398b": (330e9, 450e9),
        "llama-3.2-vision-90b": (80e9, 110e9),
        "musicgen-large": (2.5e9, 4.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
