"""Content-addressed result cache + checkpoint/resume (netsim/cache.py,
DESIGN.md Sec. 7): cache-hit lanes must be bit-equal to fresh-run lanes
(full state digest), the code digest must invalidate on any simulator
source edit, and a killed chunked Study must resume to a result
bit-equal to an uninterrupted run."""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.netsim import api, cache

POINTS = ({}, {"start_cwnd_mult": 0.5})
SEEDS = (0, 1)


def _study():
    return api.study("tiny_incast3", points=POINTS, seeds=SEEDS)


def _digest(states):
    return cache.state_digest(jax.device_get(states))


# --------------------------------------------------------------------------
# hit/miss accounting + bit-equality
# --------------------------------------------------------------------------


def test_cache_hits_are_bit_equal_to_fresh(tmp_path):
    st = _study()
    plain = st.run()                       # uncached reference
    rc = cache.ResultCache(tmp_path / "c")

    cold = st.run(cache=rc)
    assert (cold.cache_hits, cold.cache_misses) == (0, st.n_lanes)
    assert len(rc) == st.n_lanes

    warm = st.run(cache=rc)
    assert (warm.cache_hits, warm.cache_misses) == (st.n_lanes, 0)

    # full-state bitwise equality across all three paths, and identical
    # typed rows
    assert _digest(plain.states) == _digest(cold.states) == \
        _digest(warm.states)
    assert [r.row() for r in plain.results] == \
        [r.row() for r in warm.results]
    # the recorded per-lane digests match what the lanes actually hold
    for lane, key in enumerate(st.lane_keys()):
        lane_st = jax.tree.map(lambda x: np.asarray(x)[lane],
                               jax.device_get(plain.states))
        meta = json.loads((rc.root / f"{key}.json").read_text())
        assert meta["state_digest"] == cache.state_digest(lane_st)


def test_new_points_recompute_only_new_lanes(tmp_path):
    """The headline economy: extending a sweep with one new point costs
    exactly S fresh lanes; the old points come from the cache."""
    rc = cache.ResultCache(tmp_path / "c")
    api.study("tiny_incast3", points=POINTS, seeds=SEEDS).run(cache=rc)
    grown = api.study("tiny_incast3",
                      points=POINTS + ({"start_cwnd_mult": 0.75},),
                      seeds=SEEDS)
    res = grown.run(cache=rc)
    assert res.cache_hits == len(POINTS) * len(SEEDS)
    assert res.cache_misses == len(SEEDS)
    # and the stitched grid equals a fresh full run, bitwise
    assert _digest(res.states) == _digest(grown.run().states)


def test_seed_point_and_budget_are_all_keyed(tmp_path):
    rc = cache.ResultCache(tmp_path / "c")
    api.study("tiny_incast3", seeds=(0,)).run(cache=rc)
    # different seed, different point, different tick budget: all miss
    assert api.study("tiny_incast3", seeds=(1,)).run(cache=rc) \
        .cache_hits == 0
    assert api.study("tiny_incast3", points=[{"rto_mult": 5.0}],
                     seeds=(0,)).run(cache=rc).cache_hits == 0
    assert api.study("tiny_incast3", seeds=(0,)).run(
        max_ticks=12_345, cache=rc).cache_hits == 0
    # same everything: hit
    assert api.study("tiny_incast3", seeds=(0,)).run(cache=rc) \
        .cache_hits == 1


# --------------------------------------------------------------------------
# code digest
# --------------------------------------------------------------------------


def test_code_digest_invalidates_on_source_edit(tmp_path):
    """Editing any .py under the simulator tree changes the digest (and
    therefore orphans every lane key); unrelated bytes do not."""
    a, b = tmp_path / "a", tmp_path / "b"
    for root in (a, b):
        (root / "pkg").mkdir(parents=True)
        (root / "pkg" / "mod.py").write_text("X = 1\n")
        (root / "pkg" / "notes.txt").write_text("not code\n")
    assert cache.code_digest([a]) == cache.code_digest([b])

    key_before = cache.lane_key("scen", (), 0, cache.code_digest([b]))
    (b / "pkg" / "mod.py").write_text("X = 2\n")
    dig_b = cache.code_digest([b])
    assert dig_b != cache.code_digest([a])
    assert cache.lane_key("scen", (), 0, dig_b) != key_before

    # non-source bytes are not part of the digest: editing a .txt leaves
    # tree ``a`` equal to a fresh twin with the original text file
    (a / "pkg" / "notes.txt").write_text("still not code\n")
    c = tmp_path / "c"
    (c / "pkg").mkdir(parents=True)
    (c / "pkg" / "mod.py").write_text("X = 1\n")
    (c / "pkg" / "notes.txt").write_text("different non-code bytes\n")
    assert cache.code_digest([a]) == cache.code_digest([c])


def test_default_code_digest_covers_simulator_tree():
    """The default digest is stable within a process and hex-shaped."""
    d1, d2 = cache.code_digest(), cache.code_digest()
    assert d1 == d2 and len(d1) == 64 and int(d1, 16) >= 0


def test_scenario_digest_sensitivity():
    sc = api._resolve("tiny_incast3")
    d0 = cache.scenario_digest(sc, 1000)
    assert d0 == cache.scenario_digest(sc, 1000)
    assert d0 != cache.scenario_digest(sc, 2000)
    assert d0 != cache.scenario_digest(sc.with_(algo="swift"), 1000)
    wl2 = dataclasses.replace(sc.wl, size=sc.wl.size + 1)
    assert d0 != cache.scenario_digest(sc.with_(wl=wl2), 1000)


# --------------------------------------------------------------------------
# checkpoint / resume
# --------------------------------------------------------------------------


class _Killed(RuntimeError):
    pass


def test_kill_then_resume_is_bit_equal(tmp_path, monkeypatch):
    """Kill a chunked Study after the first chunk flushed; re-running
    against the same cache resumes from the finished lanes and the final
    grid is bit-equal to an uninterrupted, uncached run."""
    st = _study()
    plain = st.run()
    rc = cache.ResultCache(tmp_path / "c")

    real_put = cache.ResultCache.put
    calls = {"n": 0}

    def dying_put(self, *a, **kw):
        if calls["n"] >= 2:            # let chunk 0 (2 lanes) land
            raise _Killed("simulated kill mid-grid")
        calls["n"] += 1
        return real_put(self, *a, **kw)

    monkeypatch.setattr(cache.ResultCache, "put", dying_put)
    with pytest.raises(_Killed):
        st.run(cache=rc, chunk_lanes=2)
    monkeypatch.setattr(cache.ResultCache, "put", real_put)

    assert len(rc) == 2                # exactly the flushed chunk
    resumed = st.run(cache=rc, chunk_lanes=2)
    assert resumed.cache_hits == 2
    assert resumed.cache_misses == st.n_lanes - 2
    assert _digest(resumed.states) == _digest(plain.states)
    assert [r.row() for r in resumed.results] == \
        [r.row() for r in plain.results]


def test_chunked_uncached_run_matches(tmp_path):
    """``chunk_lanes`` alone (no cache) just bounds the batch size —
    still bit-equal to the one-shot run, including a chunk size that
    does not divide the lane count."""
    st = _study()
    plain = st.run()
    chunked = st.run(chunk_lanes=3)
    assert _digest(plain.states) == _digest(chunked.states)


def test_corrupt_entry_is_a_miss_not_an_error(tmp_path):
    st = _study()
    rc = cache.ResultCache(tmp_path / "c")
    st.run(cache=rc)
    # truncate one npz: that lane must silently recompute
    victim = st.lane_keys()[0]
    (rc.root / f"{victim}.npz").write_bytes(b"not an npz")
    res = st.run(cache=rc)
    assert res.cache_hits == st.n_lanes - 1
    assert res.cache_misses == 1
    assert _digest(res.states) == _digest(st.run().states)


def test_prune_drops_stale_code_entries(tmp_path):
    st = _study()
    rc = cache.ResultCache(tmp_path / "c")
    st.run(cache=rc)
    n = len(rc)
    assert rc.prune() == 0             # all entries current
    # forge a stale entry
    (rc.root / "deadbeef.json").write_text('{"code_digest": "old"}')
    (rc.root / "deadbeef.npz").write_bytes(b"")
    assert rc.prune() == 1
    assert len(rc) == n
