"""Property-based tests (hypothesis) for CC invariants and end-to-end
netsim conservation laws."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.smartt import smartt_update
from repro.core.types import CCEvent, init_cc_state, make_cc_params
from repro.core import reps
from repro.netsim.engine import SimConfig, build, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim import workloads

MTU = 4096.0
BDP = 26 * MTU


def _params():
    return make_cc_params(mtu=MTU, bdp=BDP, brtt=26.0)


def _event(F, rng):
    return CCEvent(
        has_ack=jnp.asarray(rng.random(F) < 0.7),
        ack_bytes=jnp.full((F,), MTU, jnp.float32),
        ecn=jnp.asarray(rng.random(F) < 0.5),
        rtt=jnp.asarray(rng.uniform(15, 120, F), jnp.float32),
        ack_entropy=jnp.asarray(rng.integers(0, 256, F), jnp.int32),
        n_trims=jnp.asarray(rng.integers(0, 2, F), jnp.int32),
        trim_bytes=jnp.asarray(rng.integers(0, 2, F) * MTU, jnp.float32),
        n_timeouts=jnp.asarray(rng.integers(0, 2, F), jnp.int32),
        to_bytes=jnp.asarray(rng.integers(0, 2, F) * MTU, jnp.float32),
        unacked=jnp.asarray(rng.uniform(0, BDP, F), jnp.float32),
        credit_grant=jnp.zeros((F,), jnp.float32),
    )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 30))
def test_cwnd_always_within_bounds(seed, steps):
    """Alg. 1 l. 36: cwnd in [mtu, 1.25*bdp] after every update, for any
    event sequence."""
    rng = np.random.default_rng(seed)
    p = _params()
    s = init_cc_state(8, p)
    for t in range(steps):
        s = smartt_update(p, s, _event(8, rng), now=float(t * 3))
        c = np.asarray(s.cwnd)
        assert np.all(c >= MTU - 1e-3) and np.all(c <= 1.25 * BDP + 1e-3)
        assert np.all(np.isfinite(np.asarray(s.avg_wtd)))
        assert np.all((np.asarray(s.avg_wtd) >= 0)
                      & (np.asarray(s.avg_wtd) <= 1 + 1e-6))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_marked_ack_never_increases_window(seed):
    """With QuickAdapt/FastIncrease structurally disabled, an ECN-marked
    ACK can only shrink (or hold) the window."""
    rng = np.random.default_rng(seed)
    p = _params()
    s = init_cc_state(4, p)
    s = s._replace(
        cwnd=jnp.asarray(rng.uniform(2 * MTU, BDP, 4), jnp.float32),
        avg_wtd=jnp.ones((4,), jnp.float32),      # WTD open
        qa_end=jnp.full((4,), 1e9, jnp.float32),  # no QA boundary
        fi_count=jnp.zeros((4,), jnp.float32))
    ev = _event(4, rng)._replace(
        has_ack=jnp.ones((4,), bool), ecn=jnp.ones((4,), bool),
        rtt=jnp.asarray(rng.uniform(30, 120, 4), jnp.float32),  # > brtt band
        n_trims=jnp.zeros((4,), jnp.int32),
        trim_bytes=jnp.zeros((4,), jnp.float32),
        n_timeouts=jnp.zeros((4,), jnp.int32),
        to_bytes=jnp.zeros((4,), jnp.float32))
    s2 = smartt_update(p, s, ev, now=5.0)
    assert np.all(np.asarray(s2.cwnd) <= np.asarray(s.cwnd) + 1e-3)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_clean_fast_ack_never_decreases_window(seed):
    rng = np.random.default_rng(seed)
    p = _params()
    s = init_cc_state(4, p)
    s = s._replace(cwnd=jnp.asarray(rng.uniform(2 * MTU, BDP, 4), jnp.float32),
                   qa_end=jnp.full((4,), 1e9, jnp.float32))
    ev = _event(4, rng)._replace(
        has_ack=jnp.ones((4,), bool), ecn=jnp.zeros((4,), bool),
        rtt=jnp.full((4,), 26.0, jnp.float32),
        n_trims=jnp.zeros((4,), jnp.int32),
        trim_bytes=jnp.zeros((4,), jnp.float32),
        n_timeouts=jnp.zeros((4,), jnp.int32),
        to_bytes=jnp.zeros((4,), jnp.float32))
    s2 = smartt_update(p, s, ev, now=5.0)
    assert np.all(np.asarray(s2.cwnd) >= np.asarray(s.cwnd) - 1e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 50))
def test_reps_entropy_range(seed, steps):
    """REPS never emits an entropy outside [0, num_entropies)."""
    rng = np.random.default_rng(seed)
    p = reps.make_lb_params(num_entropies=256, bdp_pkts=26)
    s = reps.init_lb_state(8, p, seed=seed)
    flow_ids = jnp.arange(8, dtype=jnp.int32)
    for t in range(steps):
        mask = jnp.asarray(rng.random(8) < 0.8)
        seqs = jnp.asarray(rng.integers(0, 100, 8), jnp.int32)
        s, ent = reps.on_send(reps.LB_REPS, p, s, mask, seqs, flow_ids, t)
        e = np.asarray(ent)
        assert np.all((e >= 0) & (e < 256))
        s = reps.on_ack(reps.LB_REPS, p, s,
                        jnp.asarray(rng.random(8) < 0.5),
                        jnp.asarray(rng.random(8) < 0.3),
                        jnp.asarray(rng.integers(0, 256, 8), jnp.int32),
                        flow_ids, t)
        assert np.all(np.asarray(s.cached_entropy) % 256 >= 0)


@settings(max_examples=6, deadline=None)
@given(
    algo=st.sampled_from(["smartt", "swift", "mprdma", "eqds"]),
    seed=st.integers(0, 1000),
    trimming=st.booleans(),
)
def test_netsim_conserves_and_completes(algo, seed, trimming):
    """Any small random workload: every flow finishes, receiver goodput
    equals flow size exactly (no lost/duplicated bytes), metrics finite."""
    tree = FatTreeConfig(racks=2, nodes_per_rack=4, uplinks=2)
    rng = np.random.default_rng(seed)
    n = tree.n_nodes
    f = int(rng.integers(2, 6))
    src = rng.choice(n, size=f, replace=False).astype(np.int32)
    dst = np.array([(s + rng.integers(1, n)) % n for s in src], np.int32)
    dst = np.where(dst == src, (dst + 1) % n, dst).astype(np.int32)
    size = (rng.integers(1, 40, f) * 4096).astype(np.int32)
    wl = workloads.Workload(
        name="rand", src=src, dst=dst, size=size,
        t_start=rng.integers(0, 50, f).astype(np.int32),
        order=np.zeros(f, np.int32))
    cfg = SimConfig(link=LinkConfig(), tree=tree, algo=algo, lb="reps",
                    trimming=trimming)
    sim = build(cfg, wl)
    st_ = sim.run(max_ticks=30000)
    s = summarize(sim, st_)
    assert s["all_done"], (algo, seed, s["n_done"], f)
    np.testing.assert_array_equal(s["goodput_bytes"], size)
