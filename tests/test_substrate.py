"""Substrate tests: optimizer, data pipeline, checkpointer, compression,
sharding spec rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.train import compression


def test_adamw_optimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                            total_steps=200, grad_clip=0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(cfg, params)
    for _ in range(150):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw.update(cfg, state, params, g)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_bf16_moments_close_to_f32():
    t = jnp.asarray(np.random.default_rng(0).standard_normal(64), jnp.float32)
    outs = {}
    for mdt in ("float32", "bfloat16"):
        cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=mdt,
                                warmup_steps=1, grad_clip=0)
        params = {"w": jnp.zeros(64)}
        state = adamw.init(cfg, params)
        for _ in range(100):
            g = {"w": 2 * (params["w"] - t)}
            params, state, _ = adamw.update(cfg, state, params, g)
        outs[mdt] = np.asarray(params["w"])
    assert np.max(np.abs(outs["float32"] - outs["bfloat16"])) < 0.15


def test_zero1_spec_rules():
    sizes = {"pod": 2, "data": 16, "model": 16}
    # plain TP param: data axis lands on the free divisible dim
    sp = adamw.zero1_spec(P(None, "model"), (8192, 1024), ("pod", "data"), sizes)
    assert sp == P(("pod", "data"), "model")
    # FSDP param already data-sharded: unchanged (no duplicate axes)
    sp = adamw.zero1_spec(P(("pod", "data"), "model"), (8192, 1024),
                          ("pod", "data"), sizes)
    assert sp == P(("pod", "data"), "model")
    # nothing divisible: replicated
    sp = adamw.zero1_spec(P(None), (7,), ("pod", "data"), sizes)
    assert sp == P(None)


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_data_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=4, seed=7)
    a = SyntheticLM(cfg)
    b1 = next(a)
    b2 = next(a)
    # restart from saved state reproduces the stream exactly
    c = SyntheticLM(cfg)
    c.restore({"step": 1})
    np.testing.assert_array_equal(next(c)["tokens"], b2["tokens"])
    # different hosts draw different data
    d = SyntheticLM(DataConfig(vocab=512, seq_len=32, global_batch=4, seed=7,
                               host_id=1, n_hosts=2))
    assert not np.array_equal(next(d)["tokens"][:2], b1["tokens"][:2])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.asarray(3, jnp.int32)}}
    for step in (1, 2, 3, 4):
        ckpt.save(d, step, tree, extra={"data": {"step": step}}, keep=2)
    assert ckpt.all_steps(d) == [3, 4]
    step, restored, extra = ckpt.restore_latest(d, tree)
    assert step == 4 and extra == {"data": {"step": 4}}
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_checkpoint_crash_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((3,))}
    ckpt.save(d, 1, tree)
    # simulate a crash mid-save: stray .tmp dir must not be listed
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024) * 5, jnp.float32)
    q, s = compression.quantize(x)
    err = np.asarray(compression.dequantize(q, s) - x)
    # per-block max-scale int8: error <= scale/2 = max|block|/254
    per_block = np.abs(np.asarray(x)).reshape(-1, compression.BLOCK).max(1)
    bound = per_block / 254 + 1e-6
    assert np.all(np.abs(err).reshape(-1, compression.BLOCK).max(1) <= bound)
