"""Batched serving: prefill + greedy decode loop over the model zoo's
cache-carrying serve path."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import lm


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "max_len"))
def generate(params, cfg, tokens, *, max_new: int, max_len: int):
    """Greedy generation for token-frontend models.

    tokens: i32[B, S_prompt].  Returns i32[B, max_new].
    """
    if cfg.frontend != "tokens":
        raise ValueError("generate() requires a token frontend")
    batch = {"tokens": tokens}
    last_logits, caches, cache_len = lm.prefill(params, cfg, batch,
                                                max_len=max_len)
    first = jnp.argmax(last_logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)

    def body(carry, _):
        tok, caches, cl = carry
        logits, caches = lm.decode_step(params, cfg, {"tokens": tok[:, None]},
                                        caches, cl + 1)
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)
        return (nxt, caches, cl + 1), tok

    (_, _, _), toks = jax.lax.scan(body, (first, caches, cache_len),
                                   None, length=max_new)
    return toks.T                                            # [B, max_new]
