"""Deterministic synthetic LM data pipeline.

Produces a reproducible token stream (a seeded Markov-ish mixture with
enough structure that a model's loss visibly falls) sharded by host:
host h of H draws disjoint index ranges, so multi-host training reads
disjoint data with no coordination.  The iterator state is one integer —
checkpointable, so restarts resume mid-epoch exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    structure: int = 64     # markov states — lower = easier to learn


class SyntheticLM:
    """Deterministic, restartable synthetic token stream."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.host_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        k = cfg.structure
        # sparse-ish markov transition over k states, each state emitting a
        # biased distribution over a vocab slice
        self.trans = rng.dirichlet(np.ones(k) * 0.1, size=k).astype(np.float32)
        self.emit_base = rng.integers(0, max(cfg.vocab - 16, 1), size=k)

    def __iter__(self):
        return self

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])

    def __next__(self):
        cfg = self.cfg
        # unique, deterministic seed per (host, step)
        seq_rng = np.random.default_rng(
            (cfg.seed, cfg.host_id, self.step, 0xDA7A))
        b, s = self.host_batch, cfg.seq_len
        k = self.trans.shape[0]
        states = np.zeros((b, s), np.int64)
        st = seq_rng.integers(0, k, size=b)
        u = seq_rng.random((b, s))
        cum = np.cumsum(self.trans, axis=1)
        for t in range(s):
            states[:, t] = st
            st = (cum[st] < u[:, t:t + 1]).sum(axis=1)
            st = np.minimum(st, k - 1)
        offs = seq_rng.integers(0, 16, size=(b, s))
        tokens = (self.emit_base[states] + offs) % cfg.vocab
        self.step += 1
        x = tokens.astype(np.int32)
        labels = np.concatenate([x[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        return {"tokens": x, "labels": labels}
