"""High-level attention op: GQA head broadcasting + padding + kernel dispatch."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention


def gqa_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Sk, D] with Hq % Hkv == 0."""
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    sq, sk = q.shape[2], k.shape[2]
    bq = min(block_q, sq) if sq % block_q else block_q
    while sq % bq:
        bq //= 2
    bk = min(block_k, sk) if sk % block_k else block_k
    while sk % bk:
        bk //= 2
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=max(bq, 1), block_k=max(bk, 1),
                           interpret=interpret)
