"""Pallas TPU kernel: FlashAttention-style blocked causal attention.

Online-softmax over KV blocks with the query block resident in VMEM.
Tiling targets the MXU: (BLOCK_Q, D) x (D, BLOCK_K) matmuls with
128-aligned dimensions.  Grid = (batch*heads, q_blocks); the KV loop runs
inside the kernel with ``jax.lax.fori_loop`` so the working set stays
(BLOCK_Q + 2*BLOCK_K) x D in VMEM.

Used by the model zoo when ``use_pallas=True`` (TPU runtime); the pure-JAX
chunked equivalent in ``repro.models.attention`` is the XLA path used for
CPU smoke tests and the dry-run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, sk, block_k, causal, window, scale):
    _, bq, d = q_ref.shape
    q = q_ref[0].astype(jnp.float32) * scale
    qi = pl.program_id(1)
    q_off = qi * bq + (sk - pl.num_programs(1) * bq)   # align ends

    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc = jnp.zeros((bq, d), jnp.float32)

    n_kb = sk // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                             # [bq, bk]
        qpos = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        mask = jnp.ones((bq, block_k), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q: [B, H, Sq, D]; k/v: [B, H, Sk, D] (kv heads pre-broadcast).
    Sq % block_q == 0 and Sk % block_k == 0 required (pad upstream)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq lens ({sq},{sk}) must tile ({block_q},{block_k})")
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)

    kernel = functools.partial(_attn_kernel, sk=sk, block_k=block_k,
                               causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
