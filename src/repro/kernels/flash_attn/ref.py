"""Pure-jnp oracle for blocked causal attention: naive softmax(QK^T/sqrt(d))V."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, H, Sq, D], k/v: [B, H, Sk, D] (kv heads already broadcast).
    ``window`` > 0 applies sliding-window attention of that width.
    Returns [B, H, Sq, D] in f32."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d)
    sq, sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (decode-friendly)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    probs = _softmax(logits)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
