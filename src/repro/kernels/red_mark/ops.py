"""Jit'd wrapper for the red_mark kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.red_mark.kernel import red_mark


def red_mark_op(q_size, arrivals, *, cap: int, kmin: float, kmax: float,
                tick, salt: int = 0xECD, interpret: bool = True):
    return red_mark(jnp.asarray(q_size, jnp.int32),
                    jnp.asarray(arrivals, jnp.int32),
                    cap, kmin, kmax, tick, salt, interpret=interpret)
