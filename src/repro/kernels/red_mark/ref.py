"""Pure-jnp oracle for the RED/trim switch-datapath kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.netsim import hashing


def red_mark_ref(q_size, arrivals, cap, kmin, kmax, tick, salt):
    """RED dequeue-marking + trim admission for every port.

    Args:
      q_size: i32[Q] current occupancy of each port queue.
      arrivals: i32[Q] packets attempting to enqueue this tick.
      cap/kmin/kmax: queue capacity and RED thresholds (scalars).
      tick, salt: hash lanes for the marking coin flip.

    Returns:
      mark: bool[Q] — ECN-mark the packet dequeued from this port
            (probability linear in occupancy between kmin and kmax).
      admit: i32[Q] — how many of the arrivals fit (rest get trimmed).
      trim: i32[Q] — arrivals that must be trimmed (buffer full).
    """
    qf = q_size.astype(jnp.float32)
    p = jnp.clip((qf - kmin) / jnp.maximum(kmax - kmin, 1e-6), 0.0, 1.0)
    qidx = jnp.arange(q_size.shape[-1], dtype=jnp.int32)
    qidx = jnp.broadcast_to(qidx.reshape((1,) * (q_size.ndim - 1) + (-1,)),
                            q_size.shape)
    u = hashing.uniform01(tick.astype(jnp.int32) * jnp.int32(131071) + qidx,
                          salt.astype(jnp.int32))
    mark = (u < p) & (q_size > 0)
    space = jnp.maximum(cap.astype(jnp.int32) - q_size, 0)
    admit = jnp.minimum(arrivals, space)
    trim = arrivals - admit
    return mark, admit, trim
