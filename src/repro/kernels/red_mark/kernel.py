"""Pallas TPU kernel: RED ECN dequeue-marking + trim admission.

The switch datapath of the paper (Sec. 2.1: RED with dequeue marking,
Sec. 3.3: trim-on-full).  At 51.2 Tb/s a switch marks/trims millions of
packets per millisecond; as with cc_update, the TPU-native formulation is a
vector sweep over all port queues: occupancy planes stream through VMEM in
(8, 128) tiles, the marking coin-flips come from the same splitmix32
counter hash the engine uses (deterministic, stateless).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
LANES = 128


def _kernel(scal_ref, qsz_ref, arr_ref, qidx_ref, mark_ref, admit_ref, trim_ref):
    cap, kmin, kmax, tick, salt = (scal_ref[0, i] for i in range(5))
    q_size = qsz_ref[...]
    arrivals = arr_ref[...]
    qf = q_size.astype(jnp.float32)
    p = jnp.clip((qf - kmin) / jnp.maximum(kmax - kmin, 1e-6), 0.0, 1.0)
    # splitmix32 coin flip — same hash lanes as the oracle, computed on the
    # *global* queue index plane so tiling never changes the decision
    from repro.netsim.hashing import uniform01
    u = uniform01(tick.astype(jnp.int32) * jnp.int32(131071) + qidx_ref[...],
                  salt.astype(jnp.int32))
    mark_ref[...] = ((u < p) & (q_size > 0)).astype(jnp.int32)
    space = jnp.maximum(cap.astype(jnp.int32) - q_size, 0)
    admit = jnp.minimum(arrivals, space)
    admit_ref[...] = admit
    trim_ref[...] = arrivals - admit


@functools.partial(jax.jit, static_argnames=("interpret",))
def red_mark(q_size, arrivals, cap, kmin, kmax, tick, salt, *,
             interpret: bool = True):
    """Blocked RED marking over all port queues.  Shapes: i32[Q] -> i32[Q]x3."""
    Q = q_size.shape[0]
    rows = max(1, -(-Q // LANES))
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    Qp = rows_pad * LANES

    def shape2d(x, fill=0):
        return jnp.pad(x, (0, Qp - Q), constant_values=fill).reshape(rows_pad, LANES)

    qidx = shape2d(jnp.arange(Q, dtype=jnp.int32))
    scal = jnp.stack([jnp.asarray(v, jnp.float32) for v in
                      (cap, kmin, kmax, tick, salt)]).reshape(1, 5)
    tile = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        _kernel,
        grid=(rows_pad // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((1, 5), lambda i: (0, 0)), tile, tile, tile],
        out_specs=[tile] * 3,
        out_shape=[jax.ShapeDtypeStruct((rows_pad, LANES), jnp.int32)] * 3,
        interpret=interpret,
    )(scal, shape2d(q_size), shape2d(arrivals), qidx)
    mark, admit, trim = (o.reshape(-1)[:Q] for o in outs)
    return mark != 0, admit, trim
