"""Jit'd high-level wrapper: CCState/CCEvent pytrees -> cc_update kernel.

Drop-in replacement for ``repro.core.smartt.smartt_update`` (SMaRTT fields
only) running through the Pallas kernel.  ``interpret=True`` executes the
kernel body on CPU for validation; on a TPU runtime pass interpret=False.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import CCEvent, CCParams, CCState
from repro.kernels.cc_update import ref as R
from repro.kernels.cc_update.kernel import cc_update


def pack_params(p: CCParams) -> jnp.ndarray:
    return jnp.stack([jnp.asarray(getattr(p, n), jnp.float32).reshape(())
                      for n in R.PARAM_FIELDS])


def smartt_update_pallas(p: CCParams, s: CCState, ev: CCEvent, now,
                         *, interpret: bool = True) -> CCState:
    F = s.cwnd.shape[0]
    brtt = jnp.broadcast_to(p.brtt, (F,)).astype(jnp.float32)
    trtt = jnp.broadcast_to(p.trtt, (F,)).astype(jnp.float32)
    mi = jnp.broadcast_to(p.mi, (F,)).astype(jnp.float32)
    sf = tuple(getattr(s, n).astype(jnp.float32) for n in R.STATE_F32)
    si = (s.trigger_qa.astype(jnp.int32), s.fi_active.astype(jnp.int32),
          s.ack_count.astype(jnp.int32))
    ef = tuple(getattr(ev, n).astype(jnp.float32) for n in R.EVENT_F32)
    ei = tuple(getattr(ev, n).astype(jnp.int32) for n in R.EVENT_I32)
    f32s, i32s = cc_update(pack_params(p), now, brtt, trtt, mi,
                           sf, si, ef, ei, interpret=interpret)
    kw = dict(zip(R.STATE_F32, f32s))
    kw["trigger_qa"] = i32s[0] != 0
    kw["fi_active"] = i32s[1] != 0
    kw["ack_count"] = i32s[2]
    return s._replace(**kw)
