"""Pure-jnp oracle for the cc_update kernel.

The oracle *is* the paper-faithful implementation in ``repro.core.smartt``:
the kernel must produce bit-identical window updates.  This module adapts it
to the kernel's packed flat-array calling convention.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.smartt import smartt_update
from repro.core.types import CCEvent, CCParams, CCState

# scalar parameter vector layout (see ops.py)
PARAM_FIELDS = (
    "mtu", "bdp", "maxcwnd", "mincwnd", "fd", "md", "fi", "k_fast",
    "qa_scaling", "wtd_alpha", "wtd_thresh", "fi_rtt_tol", "react_every",
)

STATE_F32 = ("cwnd", "acked", "qa_end", "bytes_to_ignore", "bytes_ignored",
             "fi_count", "avg_wtd")
STATE_I32 = ("trigger_qa", "fi_active", "ack_count")
EVENT_F32 = ("ack_bytes", "rtt", "trim_bytes", "to_bytes", "unacked")
EVENT_I32 = ("has_ack", "ecn", "n_trims", "n_timeouts")


def _params_from_vec(vec, brtt, trtt, mi):
    kw = {name: vec[i] for i, name in enumerate(PARAM_FIELDS)}
    kw["react_every"] = kw["react_every"].astype(jnp.int32)
    kw["brtt"] = brtt
    kw["trtt"] = trtt
    kw["mi"] = mi
    z = jnp.zeros(())
    for extra in ("sw_ai", "sw_beta", "sw_max_mdf", "bbr_probe_gain",
                  "bbr_drain_gain", "bbr_cwnd_gain"):
        kw[extra] = z
    return CCParams(**kw)


def _state(shape, f32s, i32s):
    z = jnp.zeros(shape, jnp.float32)
    kw = dict(zip(STATE_F32, f32s))
    kw["trigger_qa"] = i32s[0] != 0
    kw["fi_active"] = i32s[1] != 0
    kw["ack_count"] = i32s[2]
    for unused in ("last_dec", "bw_est", "rtprop", "win_delivered", "win_end",
                   "pacing_rate", "credits", "spec_budget"):
        kw[unused] = z
    return CCState(**kw)


def cc_update_ref(param_vec, brtt, trtt, mi, now,
                  state_f32s, state_i32s, event_f32s, event_i32s):
    """Flat-argument oracle.  All per-flow arrays share one (arbitrary)
    shape; returns (state_f32s', state_i32s') in the same layout."""
    p = _params_from_vec(param_vec, brtt, trtt, mi)
    s = _state(brtt.shape, state_f32s, state_i32s)
    ev = CCEvent(
        has_ack=event_i32s[0] != 0,
        ack_bytes=event_f32s[0],
        ecn=event_i32s[1] != 0,
        rtt=event_f32s[1],
        ack_entropy=jnp.zeros(brtt.shape, jnp.int32),
        n_trims=event_i32s[2],
        trim_bytes=event_f32s[2],
        n_timeouts=event_i32s[3],
        to_bytes=event_f32s[3],
        unacked=event_f32s[4],
        credit_grant=jnp.zeros(brtt.shape, jnp.float32),
    )
    s2 = smartt_update(p, s, ev, now)
    f32s = tuple(getattr(s2, n) for n in STATE_F32)
    i32s = (s2.trigger_qa.astype(jnp.int32), s2.fi_active.astype(jnp.int32),
            s2.ack_count)
    return f32s, i32s
