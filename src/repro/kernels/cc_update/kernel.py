"""Pallas TPU kernel: SMaRTT per-flow congestion-window update.

This is the NIC datapath of the paper (Sec. 1.1.3: one packet every 40 ns at
800 Gb/s — the CC update must be branch-free and memory-lean).  On TPU the
natural analogue is a struct-of-arrays sweep over the flow table: flow state
lives in HBM as (F/128, 128)-shaped f32/i32 planes, the kernel streams
(8, 128) VMEM tiles through the VPU, applying the entire Alg. 1-3 update as
a branchless vector program.

The arithmetic is *shared* with the engine: the kernel body calls
``repro.core.smartt.smartt_update`` on VMEM-resident tiles, so kernel and
oracle cannot drift apart.  The Pallas layer contributes blocking, padding
and the VMEM working-set contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.cc_update import ref as R

# VMEM tile: 8 sublanes x 128 lanes (f32 native TPU tile)
BLOCK_ROWS = 8
LANES = 128

N_STATE_F32 = len(R.STATE_F32)
N_STATE_I32 = len(R.STATE_I32)
N_EVENT_F32 = len(R.EVENT_F32)
N_EVENT_I32 = len(R.EVENT_I32)


def _kernel(param_ref, now_ref, brtt_ref, trtt_ref, mi_ref,
            *refs):
    sf = [refs[i][...] for i in range(N_STATE_F32)]
    off = N_STATE_F32
    si = [refs[off + i][...] for i in range(N_STATE_I32)]
    off += N_STATE_I32
    ef = [refs[off + i][...] for i in range(N_EVENT_F32)]
    off += N_EVENT_F32
    ei = [refs[off + i][...] for i in range(N_EVENT_I32)]
    off += N_EVENT_I32
    out_f = refs[off:off + N_STATE_F32]
    out_i = refs[off + N_STATE_F32:]

    pvec = param_ref[0, :]
    now = now_ref[0, 0]
    f32s, i32s = R.cc_update_ref(
        pvec, brtt_ref[...], trtt_ref[...], mi_ref[...], now, sf, si, ef, ei)
    for dst, val in zip(out_f, f32s):
        dst[...] = val
    for dst, val in zip(out_i, i32s):
        dst[...] = val


@functools.partial(jax.jit, static_argnames=("interpret",))
def cc_update(param_vec, now, brtt, trtt, mi,
              state_f32s, state_i32s, event_f32s, event_i32s,
              *, interpret: bool = True):
    """Blocked SMaRTT update over the flow table.

    Args:
      param_vec: f32[NP] scalar parameters (layout ``ref.PARAM_FIELDS``).
      now: scalar tick.
      brtt/trtt/mi: f32[F] per-flow constants.
      state_*: tuples of f32[F]/i32[F] per-flow state planes.
      event_*: tuples of f32[F]/i32[F] per-flow event planes.

    Returns (state_f32s', state_i32s') with original length F.
    """
    F = brtt.shape[0]
    rows = max(1, -(-F // LANES))
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    Fp = rows_pad * LANES

    def shape2d(x):
        x = jnp.pad(x, (0, Fp - F))
        return x.reshape(rows_pad, LANES)

    brtt2, trtt2, mi2 = shape2d(brtt), shape2d(jnp.broadcast_to(trtt, (F,))), shape2d(jnp.broadcast_to(mi, (F,)))
    # avoid div-by-zero on padded lanes of (trtt - brtt), rtt etc.
    brtt2 = jnp.where(brtt2 == 0, 1.0, brtt2)
    trtt2 = jnp.where(trtt2 == 0, 2.0, trtt2)
    ins = [shape2d(x) for x in (*state_f32s, *state_i32s, *event_f32s, *event_i32s)]

    grid = (rows_pad // BLOCK_ROWS,)
    tile = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, param_vec.shape[0]), lambda i: (0, 0))
    now_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    out_shapes = (
        [jax.ShapeDtypeStruct((rows_pad, LANES), jnp.float32)] * N_STATE_F32
        + [jax.ShapeDtypeStruct((rows_pad, LANES), jnp.int32)] * N_STATE_I32
    )
    outs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scalar_spec, now_spec] + [tile] * (3 + len(ins)),
        out_specs=[tile] * len(out_shapes),
        out_shape=out_shapes,
        interpret=interpret,
    )(param_vec.reshape(1, -1).astype(jnp.float32),
      jnp.asarray(now, jnp.float32).reshape(1, 1),
      brtt2, trtt2, mi2, *ins)

    def unshape(x):
        return x.reshape(-1)[:F]

    f32s = tuple(unshape(o) for o in outs[:N_STATE_F32])
    i32s = tuple(unshape(o) for o in outs[N_STATE_F32:])
    return f32s, i32s
