"""Pure-jnp reference arithmetic for the enqueue-rank + arbitration kernel.

Two vector programs the tick runs every cycle at fabric scale:

``enqueue_rank_ref``
    Same-destination enqueue ranking + capacity acceptance, grouped by
    feeding switch.  Row ``sw`` of the inputs holds the gathered per-slot
    values of the emitters in ``topology.in_tbl[sw]`` (ascending emitter
    id; padded slots carry the sentinel destination ``NQ``, which never
    equals a real queue id).  An emitter's rank is the number of
    lower-slot emitters in its group enqueueing to the same queue — since
    same-queue emitters always share a feeding switch and slots are
    id-ascending, this equals the global smaller-id count the fabric's
    historical [NE, NE] compare+reduce produced, bit for bit, at
    O(NSW * DMAX^2) instead of O(NE^2).

``rr_pick_ref``
    Per-row round-robin argmin arbitration (sender flow pick, EQDS grant
    pick): smallest (slot - rr) mod K among eligible slots.  Padded slots
    must be ineligible; they then take the same key as ineligible real
    slots (K + 1) at higher indices, so the first-min argmin — and the
    no-candidate fallback index 0 — are unchanged by padding.

The Pallas kernel bodies call these functions on VMEM-resident tiles, so
kernel and reference cannot drift apart (the ``kernels/cc_update``
contract, DESIGN.md Sec. 6).
"""

from __future__ import annotations

import jax.numpy as jnp

I32 = jnp.int32


def enqueue_rank_ref(gdst, ghead, gsize, cap: int, nq: int):
    """Rank, acceptance, and queue position per fan-in slot.

    Args:
      gdst:  i32 [..., D] destination queue per slot (``NQ`` = no enqueue).
      ghead: i32 [..., D] head index of that queue (``q_head[gdst]``).
      gsize: i32 [..., D] occupancy of that queue (``q_size[gdst]``).
      cap:   static per-port capacity (packets).
      nq:    static queue count (sentinel destination).

    Returns ``(rank, acc, pos)``, each [..., D]:
      rank: same-destination arrival rank within the tick,
      acc:  packet accepted (destination real and rank fits the free space),
      pos:  ring slot it lands in (meaningful only where ``acc``).
    """
    d = gdst.shape[-1]
    jd = jnp.arange(d, dtype=I32)
    same = (gdst[..., :, None] == gdst[..., None, :]) & \
        (jd[None, :] < jd[:, None])
    rank = jnp.sum(same.astype(I32), axis=-1)
    acc = (gdst < nq) & (rank < cap - gsize)
    pos = (ghead + gsize + rank) % cap
    return rank, acc, pos


def rr_pick_ref(elig, rr, kmax: int):
    """Round-robin pick per row: the eligible slot with the smallest
    ``(slot - rr) mod kmax`` key.

    Args:
      elig: bool [..., K] eligibility per slot (padded slots False).
      rr:   i32 [...] per-row round-robin cursor.
      kmax: static modulus (the *unpadded* slot count).

    Returns ``(has, sel)``: any-eligible flag and the picked slot index
    (0 where nothing is eligible — the caller gates on ``has``).
    """
    k = elig.shape[-1]
    keys = (jnp.arange(k, dtype=I32) - rr[..., None]) % kmax
    keys = jnp.where(elig, keys, kmax + 1)
    sel = jnp.argmin(keys, axis=-1)
    has = jnp.any(elig, axis=-1)
    return has, sel.astype(I32)
