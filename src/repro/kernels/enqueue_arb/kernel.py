"""Pallas TPU kernels: fused enqueue-rank + round-robin arbitration.

The fabric's two per-tick arbitration problems as blocked vector programs:

  * ``enqueue_rank`` — same-destination enqueue ranking + capacity
    acceptance + ring-position assignment, one row per switch fan-in group
    ([NSW, DMAX] after the topology's ``in_tbl`` gather).  The pairwise
    compare+reduce runs entirely inside the tile, so the O(DMAX^2) work
    never touches HBM.
  * ``rr_pick`` — per-row round-robin argmin (sender flow arbitration,
    EQDS grant arbitration) over [N, K] eligibility tiles.

Both kernel bodies call the shared jnp reference (``ref.py``) on
VMEM-resident tiles — the ``kernels/cc_update`` discipline — so kernel and
oracle cannot drift apart.  Rows pad to the 8-sublane boundary and lanes to
128; padded destination slots carry the sentinel queue id ``nq`` (rank
contributions to real slots come only from *lower* slot indices, and pads
sit above every real slot, so padding never perturbs a real rank) and
padded eligibility slots are False (their keys tie with ineligible real
slots at higher indices, leaving the first-min argmin unchanged).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.enqueue_arb import ref as R

BLOCK_ROWS = 8
LANES = 128

I32 = jnp.int32


def _pad2(x, rows_pad: int, cols_pad: int, fill):
    r, c = x.shape
    return jnp.pad(x, ((0, rows_pad - r), (0, cols_pad - c)),
                   constant_values=fill)


def _enqueue_kernel(gdst_ref, ghead_ref, gsize_ref,
                    rank_ref, acc_ref, pos_ref, *, cap: int, nq: int):
    rank, acc, pos = R.enqueue_rank_ref(
        gdst_ref[...], ghead_ref[...], gsize_ref[...], cap=cap, nq=nq)
    rank_ref[...] = rank
    acc_ref[...] = acc.astype(I32)
    pos_ref[...] = pos


@functools.partial(jax.jit,
                   static_argnames=("cap", "nq", "interpret"))
def enqueue_rank(gdst, ghead, gsize, *, cap: int, nq: int,
                 interpret: bool = True):
    """Blocked enqueue-rank over the switch fan-in groups.

    Args: i32 [S, D] per-slot destination queue / queue head / queue
    occupancy (``D = fan_max``).  Returns ``(rank, acc, pos)`` as
    i32/bool/i32 [S, D] (see ``ref.enqueue_rank_ref``).
    """
    s, d = gdst.shape
    sp = -(-s // BLOCK_ROWS) * BLOCK_ROWS
    dp = -(-d // LANES) * LANES
    outs = pl.pallas_call(
        functools.partial(_enqueue_kernel, cap=cap, nq=nq),
        grid=(sp // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, dp), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((BLOCK_ROWS, dp), lambda i: (i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((sp, dp), I32)] * 3,
        interpret=interpret,
    )(_pad2(gdst, sp, dp, nq), _pad2(ghead, sp, dp, 0),
      _pad2(gsize, sp, dp, 0))
    rank, acc, pos = (o[:s, :d] for o in outs)
    return rank, acc != 0, pos


def _rr_kernel(elig_ref, rr_ref, has_ref, sel_ref, *, kmax: int):
    elig = elig_ref[...] != 0
    rr = rr_ref[...][:, 0]
    has, sel = R.rr_pick_ref(elig, rr, kmax=kmax)
    lanes = elig.shape[-1]
    has_ref[...] = jnp.broadcast_to(has.astype(I32)[:, None],
                                    (elig.shape[0], lanes))
    sel_ref[...] = jnp.broadcast_to(sel[:, None], (elig.shape[0], lanes))


@functools.partial(jax.jit, static_argnames=("kmax", "interpret"))
def rr_pick(elig, rr, *, kmax: int, interpret: bool = True):
    """Blocked round-robin argmin over [N, K] eligibility rows.

    Returns ``(has, sel)`` as bool[N] / i32[N] (see ``ref.rr_pick_ref``).
    """
    n, k = elig.shape
    np_ = -(-n // BLOCK_ROWS) * BLOCK_ROWS
    kp = -(-k // LANES) * LANES
    elig2 = _pad2(elig.astype(I32), np_, kp, 0)
    rr2 = _pad2(jnp.broadcast_to(rr[:, None], (n, 1)), np_, kp, 0)
    has, sel = pl.pallas_call(
        functools.partial(_rr_kernel, kmax=kmax),
        grid=(np_ // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, kp), lambda i: (i, 0))] * 2,
        out_specs=[pl.BlockSpec((BLOCK_ROWS, kp), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((np_, kp), I32)] * 2,
        interpret=interpret,
    )(elig2, rr2)
    return has[:n, 0] != 0, sel[:n, 0]
