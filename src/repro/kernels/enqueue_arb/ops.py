"""Backend dispatch for the enqueue-rank + arbitration kernel.

``get(backend)`` resolves ``SimConfig.fabric_backend`` to a pair of
phase-facing callables (the engine passes them into ``fabric.arrivals``
and ``sender.grants``/``sends``):

  ``enqueue(in_tbl, in_pos, sw_of_q, edst, q_head, q_size, cap, nq)
      -> (acc, pos, q_counts)``
      Same-destination enqueue acceptance + ring position per
      enqueue-capable emitter (the compact [EQ] axis — see
      ``topology.build_topology``), plus the per-queue accepted count.
      The switch-group gather/scatter (``in_tbl``/``in_pos``) and the
      ``sw_of_q`` group-reduce stay out here in jnp — only the
      O(DMAX^2) compare+reduce core differs per backend.  ``q_counts``
      replaces a ``segment_sum`` scatter: every writer into queue q sits
      in the fan-in group of q's owning switch, so a [NQ, DMAX]
      compare+mask reduce over ``gdst[sw_of_q]`` counts acceptances
      densely.

  ``arb(elig, rr, kmax) -> (has, sel)``
      Per-row round-robin argmin (see ``ref.rr_pick_ref``).

Both backends are bit-for-bit interchangeable (asserted engine-deep in
tests/test_engine_pallas.py); ``pallas`` runs in interpret mode off-TPU,
exactly like the ``cc_update`` registry entry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.enqueue_arb import kernel as K
from repro.kernels.enqueue_arb import ref as R

I32 = jnp.int32

BACKENDS = ("jnp", "pallas")


def enqueue_rank(in_tbl, in_pos, sw_of_q, edst, q_head, q_size, cap: int,
                 nq: int, *, backend: str = "jnp", interpret: bool = True):
    """Acceptance + queue position for every emitter's enqueue attempt.

    ``edst`` is i32 [EQ] over the compact enqueue-capable emitters
    (sentinel ``nq`` = no enqueue this tick); ``q_head``/``q_size`` are
    the [NQ+1] queue rings.  Returns ``(acc, pos, q_counts)``
    ([EQ] bool / [EQ] i32 / [NQ] i32), bit-identical to the historical
    global [NE, NE] compare+reduce + segment_sum for every emitter with
    ``edst < nq``.
    """
    gdst = jnp.concatenate([edst, jnp.full((1,), nq, I32)])[in_tbl]
    ghead = q_head[gdst]
    gsize = q_size[gdst]
    if backend == "pallas":
        _, acc_g, pos = K.enqueue_rank(gdst, ghead, gsize, cap=cap, nq=nq,
                                       interpret=interpret)
    else:
        _, acc_g, pos = R.enqueue_rank_ref(gdst, ghead, gsize, cap=cap,
                                           nq=nq)
    # accepted count per queue, scatter-free: all of queue q's writers
    # live in the fan-in group of its owning switch, so a [NQ, DMAX]
    # compare+mask over that group's gathered destinations counts them
    qsel = gdst[sw_of_q] == jnp.arange(nq, dtype=I32)[:, None]
    q_counts = jnp.sum(jnp.where(qsel & acc_g[sw_of_q], 1, 0),
                       axis=1).astype(I32)
    # in_pos is each compact emitter's flat slot in the group tables
    return acc_g.reshape(-1)[in_pos], pos.reshape(-1)[in_pos], q_counts


def rr_pick(elig, rr, kmax: int, *, backend: str = "jnp",
            interpret: bool = True):
    """Round-robin argmin per row — see ``ref.rr_pick_ref``."""
    if backend == "pallas":
        return K.rr_pick(elig, rr, kmax=kmax, interpret=interpret)
    return R.rr_pick_ref(elig, rr, kmax=kmax)


def get(backend: str):
    """Resolve a fabric backend name to ``(enqueue, arb)`` callables."""
    if backend not in BACKENDS:
        raise KeyError(
            f"unknown fabric backend {backend!r}; have {BACKENDS}")
    interpret = jax.default_backend() != "tpu"
    return (functools.partial(enqueue_rank, backend=backend,
                              interpret=interpret),
            functools.partial(rr_pick, backend=backend,
                              interpret=interpret))
