"""Full SSD op = Pallas intra-chunk kernel + jnp inter-chunk recurrence.

Also provides ``ssd_jnp`` — the identical chunked algorithm in pure jnp —
which the model zoo uses on CPU / in the dry-run (XLA path), so the Pallas
kernel and the deployed math share one decomposition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_chunk_scan


def _inter_chunk(y_intra, s_chunk, t_chunk, loga, C_mat, chunk):
    """Combine chunk states and add the cross-chunk correction.
    Returns (y, final_state [BH, N, P])."""
    BH, L, P = y_intra.shape
    NC = L // chunk
    S0 = jnp.zeros(s_chunk.shape[2:], jnp.float32)

    def scan_one(sc, tc):
        def step(S, inp):
            Sc, Tc = inp
            return Tc * S + Sc, S   # emit state *before* the chunk
        S_final, prev = jax.lax.scan(step, S0, (sc, tc[:, None, None]))
        return prev, S_final        # [NC, N, P], [N, P]

    prev_states, final_state = jax.vmap(scan_one)(s_chunk, t_chunk)

    # y_inter[t] = exp(L_t) * C_t @ S_prev(chunk(t))
    la = loga.reshape(BH, NC, chunk).astype(jnp.float32)
    Lc = jnp.cumsum(la, axis=-1)                             # [BH, NC, C]
    Cr = C_mat.reshape(BH, NC, chunk, -1).astype(jnp.float32)
    y_inter = jnp.einsum("bcin,bcnp->bcip", Cr, prev_states) * \
        jnp.exp(Lc)[..., None]
    return y_intra + y_inter.reshape(BH, L, P), final_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, loga, B, C, *, chunk: int = 128, interpret: bool = True):
    """Pallas-backed SSD: x,[BH,L,P] loga,[BH,L] B/C,[BH,L,N] -> y [BH,L,P]."""
    y_intra, s_chunk, t_chunk = ssd_chunk_scan(x, loga, B, C, chunk=chunk,
                                               interpret=interpret)
    y, _ = _inter_chunk(y_intra, s_chunk, t_chunk, loga, C, chunk)
    return y


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_jnp(x, loga, B, C, *, chunk: int = 128):
    """Same chunked decomposition in pure jnp (XLA path for CPU/dry-run)."""
    y, _ = ssd_jnp_with_state(x, loga, B, C, chunk=chunk)
    return y


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_jnp_with_state(x, loga, B, C, *, chunk: int = 128):
    """As ssd_jnp but also returns the final SSM state [BH, N, P]
    (needed when a prefill hands off to recurrent decode)."""
    BH, L, P = x.shape
    N = B.shape[-1]
    NC = L // chunk
    xr = x.reshape(BH, NC, chunk, P).astype(jnp.float32)
    lar = loga.reshape(BH, NC, chunk).astype(jnp.float32)
    Br = B.reshape(BH, NC, chunk, N).astype(jnp.float32)
    Cr = C.reshape(BH, NC, chunk, N).astype(jnp.float32)
    Lc = jnp.cumsum(lar, axis=-1)
    diff = Lc[..., :, None] - Lc[..., None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.exp(jnp.where(mask, diff, -1e30))
    G = jnp.einsum("bcin,bcjn->bcij", Cr, Br) * M
    y_intra = jnp.einsum("bcij,bcjp->bcip", G, xr)
    decay_end = jnp.exp(Lc[..., -1:] - Lc)                   # [BH, NC, C]
    s_chunk = jnp.einsum("bcjn,bcj,bcjp->bcnp", Br, decay_end, xr)
    t_chunk = jnp.exp(Lc[..., -1])
    return _inter_chunk(y_intra.reshape(BH, L, P), s_chunk, t_chunk, loga,
                        C, chunk)
