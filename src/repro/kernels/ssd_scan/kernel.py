"""Pallas TPU kernel: Mamba-2 SSD chunked scan (intra-chunk portion).

The SSD trick (Dao & Gu, arXiv:2405.21060) splits the linear recurrence into
(a) an intra-chunk quadratic part — attention-shaped matmuls that feed the
MXU — and (b) a tiny inter-chunk state recurrence.  This kernel computes,
per (sequence, chunk) grid cell with everything VMEM-resident:

    L        = cumsum(loga)                       # [C]
    y_intra  = ((C B^T) ∘ exp(L_i - L_j) ∘ causal) x   # [C, P]
    S_chunk  = (B ∘ exp(L_end - L))^T x           # [N, P]
    T_chunk  = exp(L_end)                         # scalar chunk decay

The O(n_chunks) inter-chunk recurrence and the rank-1 correction
``y_inter = exp(L) * C @ S_prev`` run in plain jnp in ``ops.py`` — they are
bandwidth-trivial compared to the chunk matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_BIG = -1e30


def _ssd_kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, s_ref, t_ref):
    _, C, P = x_ref.shape
    x = x_ref[0].astype(jnp.float32)          # [C, P]
    la = loga_ref[0].astype(jnp.float32)      # [C]
    Bm = b_ref[0].astype(jnp.float32)         # [C, N]
    Cm = c_ref[0].astype(jnp.float32)         # [C, N]

    L = jnp.cumsum(la)                        # inclusive cumsum of log-decay
    # decay matrix M[i, j] = exp(L_i - L_j) for j <= i (segment-sum form)
    diff = L[:, None] - L[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    M = jnp.exp(jnp.where(jj <= ii, diff, NEG_BIG))
    G = (Cm @ Bm.T) * M                       # [C, C] gated attention scores
    y_ref[0] = (G @ x).astype(y_ref.dtype)

    decay_end = jnp.exp(L[-1] - L)            # [C]
    s_ref[0, 0] = ((Bm * decay_end[:, None]).T @ x).astype(s_ref.dtype)  # [N, P]
    t_ref[0, 0] = jnp.exp(L[-1]).astype(t_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, loga, B, C, *, chunk: int = 128, interpret: bool = True):
    """Intra-chunk SSD pass.

    Args:
      x: [BH, L, P] (pre-scaled by dt), loga: [BH, L], B/C: [BH, L, N].
      chunk: chunk length (L % chunk == 0).

    Returns:
      y_intra: [BH, L, P], s_chunk: [BH, L/chunk, N, P], t_chunk: [BH, L/chunk]
    """
    BH, L, P = x.shape
    N = B.shape[-1]
    if L % chunk:
        raise ValueError(f"L={L} must be a multiple of chunk={chunk}")
    NC = L // chunk

    y, s, t = pl.pallas_call(
        _ssd_kernel,
        grid=(BH, NC),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, N, P), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, NC, N, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, NC), jnp.float32),
        ],
        interpret=interpret,
    )(x, loga, B, C)
    return y, s, t
