"""Pure-jnp oracle for the Mamba-2 SSD kernel: naive sequential recurrence.

    S_t = exp(dt_t * A) * S_{t-1} + (dt_t * x_t) outer B_t
    y_t = C_t @ S_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, loga, B, C):
    """x: [BH, L, P] inputs, loga: [BH, L] = dt*A (negative),
    B, C: [BH, L, N].  x is pre-scaled by dt.  Returns y: [BH, L, P]."""

    def scan_one(x1, loga1, B1, C1):
        def body(S, inp):
            xt, lat, Bt, Ct = inp
            S = jnp.exp(lat) * S + jnp.outer(Bt, xt)       # [N, P]
            return S, Ct @ S                                # [P]

        N = B1.shape[-1]
        P = x1.shape[-1]
        S0 = jnp.zeros((N, P), jnp.float32)
        _, y = jax.lax.scan(body, S0, (x1, loga1, B1, C1))
        return y

    return jax.vmap(scan_one)(x.astype(jnp.float32), loga.astype(jnp.float32),
                              B.astype(jnp.float32), C.astype(jnp.float32))
