"""Pallas TPU kernel: packed sent-ring ACK/trim/timeout drain.

Phase 3's hot loop as a blocked vector program: the [NF, W] sent-ring
planes stream through VMEM in (8, W-padded) tiles together with one
[8, 128] lane-packed per-flow scalar tile each for the i32 event inputs
(has_ack / ack_seq / started) and the f32 timeout threshold; the whole
free/lose/timeout cascade plus the per-flow reductions happen on-tile.
The kernel body calls the shared jnp reference (``ref.py``) on the VMEM
tiles — the ``kernels/cc_update`` discipline — so kernel and oracle cannot
drift apart.  Padded rows/lanes hold zeros, which the reference leaves
inert (a zero state is never freed, lost, or timed out).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ring_drain import ref as R

BLOCK_ROWS = 8
LANES = 128

I32 = jnp.int32
F32 = jnp.float32


def _pad2(x, rows_pad: int, cols_pad: int):
    r, c = x.shape
    return jnp.pad(x, ((0, rows_pad - r), (0, cols_pad - c)))


def _kernel(t_ref, scal_i_ref, scal_f_ref, lbits_ref, bitmap_ref,
            s0_ref, s1_ref, s2_ref, state_ref, counts_ref,
            *, w: int, ww: int, maxw: int):
    t = t_ref[0, 0]
    si = scal_i_ref[...]
    has_ack = si[:, 0] == 1
    ack_seq = si[:, 1]
    started = si[:, 2] == 1
    rto = scal_f_ref[...][:, 0]
    state, n_to, spur, un = R.ring_drain_ref(
        t, rto, started, has_ack, ack_seq, lbits_ref[...], bitmap_ref[...],
        s0_ref[...], s1_ref[...], s2_ref[...], w=w, ww=ww, maxw=maxw)
    state_ref[...] = state
    rows = n_to.shape[0]
    counts_ref[...] = jnp.concatenate(
        [n_to[:, None], spur[:, None], un[:, None],
         jnp.zeros((rows, LANES - 3), I32)], axis=1)


@functools.partial(jax.jit, static_argnames=("w", "ww", "maxw", "interpret"))
def ring_drain(t, rto, started, has_ack, ack_seq, lbits, bitmap,
               sent0, sent1, sent2, *, w: int, ww: int, maxw: int,
               interpret: bool = True):
    """Blocked sent-ring drain over the flow table.

    Same contract as ``ref.ring_drain_ref`` with unpadded [F]/[F, w]/
    [F, ww]/[F, maxw] inputs; returns ``(state', n_to, spur,
    unacked_pkts)`` with original shapes.
    """
    f = sent0.shape[0]
    fp = -(-f // BLOCK_ROWS) * BLOCK_ROWS
    wp = -(-w // LANES) * LANES
    wwp = -(-ww // LANES) * LANES
    mwp = -(-maxw // LANES) * LANES

    scal_i = _pad2(jnp.stack(
        [has_ack.astype(I32), ack_seq, started.astype(I32)], axis=1),
        fp, LANES)
    scal_f = _pad2(rto.astype(F32)[:, None], fp, LANES)

    def tile(cols):
        return pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0))

    state, counts = pl.pallas_call(
        functools.partial(_kernel, w=w, ww=ww, maxw=maxw),
        grid=(fp // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  tile(LANES), tile(LANES), tile(wwp), tile(mwp),
                  tile(wp), tile(wp), tile(wp)],
        out_specs=[tile(wp), tile(LANES)],
        out_shape=[jax.ShapeDtypeStruct((fp, wp), I32),
                   jax.ShapeDtypeStruct((fp, LANES), I32)],
        interpret=interpret,
    )(jnp.asarray(t, I32).reshape(1, 1), scal_i, scal_f,
      _pad2(lbits, fp, wwp), _pad2(bitmap, fp, mwp),
      _pad2(sent0, fp, wp), _pad2(sent1, fp, wp), _pad2(sent2, fp, wp))
    return (state[:f, :w], counts[:f, 0], counts[:f, 1], counts[:f, 2])
