"""Backend dispatch for the packed sent-ring drain kernel.

``get(backend)`` resolves ``SimConfig.transport_backend`` to the drain
callable ``transport.control`` folds its ACK/trim/timeout events through:

  ``drain(t, rto, started, has_ack, ack_seq, lbits, bitmap,
          sent0, sent1, sent2) -> (state', n_to, spur, unacked_pkts)``

with the contract of ``ref.ring_drain_ref`` (unpadded inputs).  Both
backends are bit-for-bit interchangeable (asserted engine-deep in
tests/test_engine_pallas.py); ``pallas`` runs in interpret mode off-TPU,
exactly like the ``cc_update`` registry entry.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.ring_drain import kernel as K
from repro.kernels.ring_drain import ref as R

BACKENDS = ("jnp", "pallas")


def ring_drain(t, rto, started, has_ack, ack_seq, lbits, bitmap,
               sent0, sent1, sent2, *, backend: str = "jnp",
               interpret: bool = True):
    w = sent0.shape[1]
    ww = lbits.shape[1]
    maxw = bitmap.shape[1]
    if backend == "pallas":
        return K.ring_drain(t, rto, started, has_ack, ack_seq, lbits,
                            bitmap, sent0, sent1, sent2,
                            w=w, ww=ww, maxw=maxw, interpret=interpret)
    return R.ring_drain_ref(t, rto, started, has_ack, ack_seq, lbits,
                            bitmap, sent0, sent1, sent2,
                            w=w, ww=ww, maxw=maxw)


def get(backend: str):
    """Resolve a transport backend name to the drain callable."""
    if backend not in BACKENDS:
        raise KeyError(
            f"unknown transport backend {backend!r}; have {BACKENDS}")
    return functools.partial(ring_drain, backend=backend,
                             interpret=jax.default_backend() != "tpu")
