"""Pure-jnp reference for the packed sent-ring drain (transport phase 3).

One call folds this tick's three loss/ack event sources into the sent-ring
state plane:

  1. free the slot matched by this tick's cumulative ACK,
  2. mark trim-notified slots lost (the [NF, WW] loss-bitmap words from the
     trim ring, expanded arithmetically — ``(word >> bit) & 1`` over an
     iota — instead of the [NF, W] advanced gather the phase used to pay
     XLA:CPU scatter prices for),
  3. fire retransmission timeouts (with the spurious-retx audit against
     the receiver dedupe bitmap, a static ``MAXW``-step select instead of
     a per-element gather),

and reduces the per-flow timeout / spurious / still-outstanding counts the
transport needs.  Everything is elementwise + row reductions over the
[NF, W] tile — no gathers, no scatters — which is both the fast jnp path
on CPU and, verbatim, the Pallas kernel body (``kernel.py`` calls this
function on VMEM-resident tiles, so kernel and oracle cannot drift).

Inputs may be lane-padded beyond the true ring width ``w`` (the Pallas
tiles are); padded lanes hold zeros and provably stay inert: a zero state
is never freed, lost, or timed out.
"""

from __future__ import annotations

import jax.numpy as jnp

I32 = jnp.int32
F32 = jnp.float32


def ring_drain_ref(t, rto, started, has_ack, ack_seq, lbits, bitmap,
                   sent0, sent1, sent2, *, w: int, ww: int, maxw: int):
    """Drain ACK/trim/timeout events into the sent-ring state plane.

    Args:
      t:        i32 scalar current tick.
      rto:      f32 [F] per-flow retransmission timeout.
      started:  bool [F] flow started and unfinished.
      has_ack:  bool [F] an ACK for this flow landed this tick.
      ack_seq:  i32 [F] the ACKed sequence number (0 where no ACK).
      lbits:    i32 [F, >=ww] trim-ring loss-bitmap words.
      bitmap:   i32 [F, >=maxw] receiver dedupe bitmap (spurious audit).
      sent0/1/2: i32 [F, >=w] sent-ring state / seq / send-tick planes.
      w, ww, maxw: true (unpadded) ring width, loss words, bitmap words.

    Returns ``(state', n_to, spur, unacked_pkts)``: the new state plane
    (same padded width as ``sent0``) and per-flow i32 counts of fired
    timeouts, spurious retransmissions, and still-outstanding packets.
    """
    f, wt = sent0.shape                               # wt >= w (padding)
    wbits = jnp.arange(wt, dtype=I32)

    # 1. ACK frees its slot when the slot still holds that sequence.
    #    ``hit`` is one-hot per row (aslot < w <= wt), so "the hit lane
    #    still holds this sequence" collapses to ONE boolean any-reduce
    #    instead of two masked sums — every reduction here is a separate
    #    XLA fusion that re-streams the [F, W] planes, so fewer
    #    reductions is fewer passes (DESIGN.md Sec. 6.4)
    aslot = ack_seq % w
    hit = wbits[None, :] == aslot[:, None]
    match = has_ack & jnp.any(
        hit & (sent0 != 0) & (sent1 == ack_seq[:, None]), axis=1)
    state = jnp.where(match[:, None] & hit, 0, sent0)

    # 2. trim-notified packets -> lost (awaiting retransmission)
    bits = ((lbits[:, :ww, None] >> jnp.arange(32, dtype=I32)) & 1)
    bits = bits.reshape(f, ww * 32)                   # == [F, w]
    if wt > w:
        bits = jnp.pad(bits, ((0, 0), (0, wt - w)))
    lost = (bits == 1) & (state == 1)
    state = jnp.where(lost, 3, state)

    # 3. timeouts, with the spurious-retx audit against the receiver
    #    dedupe bitmap (does the receiver already hold this sequence?)
    to_mask = (state == 1) & \
        ((t - sent2).astype(F32) > rto[:, None]) & started[:, None]
    sp_word = sent1 // 32
    bm = jnp.zeros_like(sent1)
    for wd in range(maxw):                            # static, small
        bm = bm + jnp.where(sp_word == wd, bitmap[:, wd, None], 0)
    already = ((bm >> (sent1 % 32)) & 1) == 1
    state = jnp.where(to_mask, 3, state)

    # the three per-flow counts are 0/1 sums bounded by the ring width,
    # so for any practical width they pack into 10-bit fields of ONE
    # i32 reduction (no cross-field carry: each field's row total <= wt
    # < 1024) — one pass over the [F, W] tile instead of three
    if wt < 1024:
        packed = jnp.sum(
            (to_mask.astype(I32) << 20)
            + ((to_mask & already).astype(I32) << 10)
            + (state == 1).astype(I32), axis=1)
        n_to = packed >> 20
        spur = (packed >> 10) & 1023
        unacked_pkts = packed & 1023
    else:                                             # unbounded fallback
        n_to = jnp.sum(to_mask.astype(I32), axis=1)
        spur = jnp.sum((to_mask & already).astype(I32), axis=1)
        unacked_pkts = jnp.sum((state == 1).astype(I32), axis=1)
    return state, n_to, spur, unacked_pkts
