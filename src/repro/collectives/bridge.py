"""Transport-aware collective cost model: the bridge between the training
framework's collectives and the paper's transport.

The roofline harness extracts per-step collective traffic from the
compiled HLO; this module replays that traffic *through the SMaRTT netsim*
(cross-pod DP all-reduce = ring permutation over the oversubscribed fabric;
MoE expert-parallel dispatch = windowed alltoall — exactly the paper's
Sec. 4.4/4.5 workloads) and returns achieved efficiency + straggler spread
under each transport.  This is how "SMaRTT as a first-class feature" shows
up in the training stack: the collective term of the roofline can be
quoted under SMaRTT, Swift, or EQDS instead of an idealized link model.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.engine import SimConfig, build, jain_fairness, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim import workloads


@dataclasses.dataclass(frozen=True)
class CollectiveEstimate:
    kind: str
    algo: str
    nodes: int
    wire_bytes_per_node: int
    ideal_ticks: int
    achieved_ticks: int
    efficiency: float          # ideal/achieved
    straggler_spread: float    # (max-min)/mean FCT
    trims: int
    fairness: float


# ring algorithms: bytes each node puts on the wire per collective
_WIRE_FACTOR = {
    "all-reduce": 2.0,         # reduce-scatter + all-gather, ~2x payload
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "collective-permute": 1.0,
    "all-to-all": 1.0,
}


def estimate(kind: str, bytes_per_device: float, *, algo: str = "smartt",
             nodes: int = 32, oversub: int = 4, lb: str = "reps",
             max_bytes: int = 2 << 20, seed: int = 0) -> CollectiveEstimate:
    """Simulate one collective over the cross-pod fabric.

    bytes_per_device: payload each participant contributes.  Scaled down to
    ``max_bytes`` (simulation budget) — efficiency is rate-like and stable
    in flow size once flows >> BDP.
    """
    if kind not in _WIRE_FACTOR:
        raise KeyError(kind)
    link = LinkConfig()
    per_rack = 16
    racks = max(nodes // per_rack, 2)
    tree = FatTreeConfig(racks=racks, nodes_per_rack=per_rack,
                         uplinks=max(per_rack // oversub, 1))
    n = tree.n_nodes

    wire = bytes_per_device * _WIRE_FACTOR[kind]
    size = int(min(wire, max_bytes))
    size = max(size // 4096 * 4096, 4096)

    if kind == "all-to-all":
        group = min(n, 16)
        pair = max(size // group // 4096 * 4096, 4096)
        wl = workloads.alltoall(tree, size_bytes=pair, window=4, nodes=group)
        bottleneck_pkts = (group - 1) * (pair // 4096) * \
            max(1, group // (per_rack * tree.uplinks // per_rack or 1))
    else:
        # ring neighbor exchange -> cross-rack permutation
        wl = workloads.permutation(tree, size_bytes=size, seed=seed)
        bottleneck_pkts = (size // 4096) * (per_rack // tree.uplinks)

    cfg = SimConfig(link=link, tree=tree, algo=algo, lb=lb)
    sim = build(cfg, wl)
    st = sim.run(max_ticks=1_000_000)
    s = summarize(sim, st)
    done = np.asarray(st.done)
    fct = s["fct_ticks"][done]
    ideal = bottleneck_pkts + sim.timing.brtt_inter
    achieved = int(fct.max()) if done.all() else 10 ** 9
    return CollectiveEstimate(
        kind=kind, algo=algo, nodes=n,
        wire_bytes_per_node=size,
        ideal_ticks=ideal,
        achieved_ticks=achieved,
        efficiency=min(ideal / achieved, 1.0) if achieved else 0.0,
        straggler_spread=float((fct.max() - fct.min()) / max(fct.mean(), 1)),
        trims=s["trims"],
        fairness=jain_fairness(fct),
    )


def refine_collective_term(t_collective_s: float, kind: str,
                           bytes_per_device: float, *, algo: str = "smartt",
                           **kw) -> dict:
    """Scale an idealized roofline collective term by the transport's
    achieved efficiency on that traffic pattern."""
    est = estimate(kind, bytes_per_device, algo=algo, **kw)
    eff = max(est.efficiency, 1e-3)
    return {
        "ideal_s": t_collective_s,
        "transport": algo,
        "efficiency": eff,
        "refined_s": t_collective_s / eff,
        "straggler_spread": est.straggler_spread,
        "trims": est.trims,
    }
