"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Attention-free: runs the ``long_500k`` shape (sub-quadratic decode with an
O(1)-size recurrent state).
"""

from repro.models.config import (FFN_NONE, LayerSpec, MIXER_MAMBA,
                                 ModelConfig, SSMConfig)

PATTERN = (LayerSpec(MIXER_MAMBA, FFN_NONE),)


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        d_model=1536,
        n_layers=48,
        pattern=PATTERN,
        vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                      n_groups=1, chunk=128),
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-reduced",
        d_model=64,
        n_layers=2,
        pattern=PATTERN,
        vocab=512,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                      n_groups=1, chunk=16),
        tie_embeddings=True,
    )
