"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained).
[hf:databricks/dbrx-base; unverified]

16 experts divide the 16-way model axis exactly -> expert-parallel sharding
(``moe_ep=True``), which emits the alltoall collective pattern the paper
studies in Sec. 4.5.
"""

from repro.models.config import ModelConfig, moe_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        d_model=6144,
        n_layers=40,
        pattern=moe_pattern(),
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab=100352,
        rope_theta=500000.0,
        n_experts=16,
        top_k=4,
        moe_ep=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-reduced",
        d_model=64,
        n_layers=2,
        pattern=moe_pattern(),
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab=512,
        n_experts=4,
        top_k=2,
        moe_ep=True,
        q_chunk=16,
        k_chunk=16,
    )
