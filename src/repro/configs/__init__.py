"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib

ARCH_MODULES = {
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "musicgen-large": "repro.configs.musicgen_large",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
}

ARCH_IDS = tuple(ARCH_MODULES)


def get_config(arch: str, *, reduced: bool = False):
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(ARCH_MODULES[arch])
    return mod.reduced() if reduced else mod.config()
