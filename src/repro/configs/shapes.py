"""Assigned input shapes x step kinds, and ShapeDtypeStruct builders.

  train_4k      seq=4096    global_batch=256   train_step
  prefill_32k   seq=32768   global_batch=32    serve prefill
  decode_32k    seq=32768   global_batch=128   serve decode (1 new token,
                                               KV cache of seq_len)
  long_500k     seq=524288  global_batch=1     long-context decode —
                                               SSM/hybrid only (sub-quadratic);
                                               skipped for pure full-attention
                                               archs per the task spec.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import MIXER_MAMBA, ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def is_subquadratic(cfg: ModelConfig) -> bool:
    return any(s.mixer == MIXER_MAMBA for s in cfg.pattern)


def applicable_shapes(cfg: ModelConfig):
    """The task spec: long_500k only for SSM/hybrid families."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not is_subquadratic(cfg):
            continue
        out.append(s)
    return out


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: Shape, *, batch: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns a dict matching the step signature:
      train   -> {"batch": {tokens/embeds, labels[, cross]}}
      prefill -> {"batch": {tokens/embeds[, cross]}}
      decode  -> {"batch": {tokens/embeds}, "caches": ..., "cache_len": ...}
    """
    b = batch or shape.global_batch
    d = cfg.d_model
    emb = jnp.bfloat16

    def front(s):
        if cfg.frontend == "tokens":
            return {"tokens": _sd((b, s), jnp.int32)}
        return {"embeds": _sd((b, s, d), emb)}

    if shape.kind == "train":
        batch_spec = dict(front(shape.seq))
        batch_spec["labels"] = _sd((b, shape.seq), jnp.int32)
        if cfg.cross_kv_len:
            batch_spec["cross"] = _sd((b, cfg.cross_kv_len, d), emb)
        return {"batch": batch_spec}

    if shape.kind == "prefill":
        batch_spec = dict(front(shape.seq))
        if cfg.cross_kv_len:
            batch_spec["cross"] = _sd((b, cfg.cross_kv_len, d), emb)
        return {"batch": batch_spec, "max_len": shape.seq}

    # decode: one new token against a cache of length seq
    batch_spec = dict(front(1))
    caches = jax.eval_shape(
        lambda: lm.init_cache(cfg, b, shape.seq))
    return {
        "batch": batch_spec,
        "caches": caches,
        "cache_len": _sd((b,), jnp.int32),
    }


def synth_inputs(cfg: ModelConfig, shape: Shape, key, *, batch: int | None = None):
    """Concrete random inputs matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, shape, batch=batch)

    def realize(sd, k):
        if sd.dtype == jnp.int32:
            return jax.random.randint(k, sd.shape, 0, max(cfg.vocab, 2), jnp.int32)
        return jax.random.normal(k, sd.shape, jnp.float32).astype(sd.dtype) * 0.02

    keys = iter(jax.random.split(key, 64))
    out = {}
    for name, v in specs.items():
        if name == "batch":
            out["batch"] = {kk: realize(vv, next(keys)) for kk, vv in v.items()}
        elif name == "caches":
            out["caches"] = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), v)
        elif name == "cache_len":
            out["cache_len"] = jnp.full(v.shape, shape.seq, jnp.int32)
        else:
            out[name] = v
    return out
