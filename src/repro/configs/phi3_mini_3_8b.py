"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""

from repro.models.config import ModelConfig, dense_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        d_model=3072,
        n_layers=32,
        pattern=dense_pattern(),
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab=32064,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b-reduced",
        d_model=64,
        n_layers=2,
        pattern=dense_pattern(),
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab=512,
        q_chunk=16,
        k_chunk=16,
    )
