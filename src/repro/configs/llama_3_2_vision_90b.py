"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB per the task spec: ``input_specs`` provides
precomputed patch embeddings as the cross-attention context.
"""

from repro.models.config import (FFN_DENSE, LayerSpec, MIXER_ATTN,
                                 MIXER_CROSS, ModelConfig)

PATTERN = (
    LayerSpec(MIXER_ATTN, FFN_DENSE),
    LayerSpec(MIXER_ATTN, FFN_DENSE),
    LayerSpec(MIXER_ATTN, FFN_DENSE),
    LayerSpec(MIXER_ATTN, FFN_DENSE),
    LayerSpec(MIXER_CROSS, FFN_DENSE),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        d_model=8192,
        n_layers=100,
        pattern=PATTERN,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        rope_theta=500000.0,
        cross_kv_len=4096,        # stub patch-embedding context
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b-reduced",
        d_model=64,
        n_layers=5,
        pattern=PATTERN,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        rope_theta=500000.0,
        cross_kv_len=32,
        q_chunk=16,
        k_chunk=16,
    )
