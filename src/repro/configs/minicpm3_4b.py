"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention).  [hf:openbmb/MiniCPM3-4B; hf]

MLA dimensions follow the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope=64, qk_rope=32, v_head=64.  Decode caches only the latent —
~10x smaller KV cache than GQA at the same depth.
"""

from repro.models.config import MLAConfig, ModelConfig, dense_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        d_model=2560,
        n_layers=62,
        pattern=dense_pattern(),
        n_heads=40,
        n_kv_heads=40,
        head_dim=96,              # qk_nope + qk_rope (64 + 32)
        d_ff=6400,
        vocab=73448,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-reduced",
        d_model=64,
        n_layers=2,
        pattern=dense_pattern(),
        n_heads=5,                # keep the non-divisible head count
        n_kv_heads=5,
        head_dim=24,
        d_ff=128,
        vocab=512,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        q_chunk=16,
        k_chunk=16,
    )
