"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the task spec: ``input_specs`` provides
precomputed frame embeddings [B, S, d_model]; the head predicts the 2048
codebook entries.  (The multi-codebook delay pattern collapses to a single
stream under the stub.)
"""

from repro.models.config import ModelConfig, dense_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        d_model=2048,
        n_layers=48,
        pattern=dense_pattern(),
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab=2048,
        frontend="embeddings",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-reduced",
        d_model=64,
        n_layers=2,
        pattern=dense_pattern(),
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=128,
        frontend="embeddings",
        q_chunk=16,
        k_chunk=16,
    )
