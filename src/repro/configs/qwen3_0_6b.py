"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.models.config import ModelConfig, dense_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        d_model=1024,
        n_layers=28,
        pattern=dense_pattern(),
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab=151936,
        rope_theta=1000000.0,
        qk_norm=True,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-reduced",
        d_model=64,
        n_layers=2,
        pattern=dense_pattern(),
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        qk_norm=True,
        tie_embeddings=True,
        q_chunk=16,
        k_chunk=16,
    )
