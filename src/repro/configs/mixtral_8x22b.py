"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

8 experts do NOT divide the 16-way model axis -> TP-in-expert sharding
(d_ff=16384 shards cleanly); the EP-vs-TP trade is a hillclimb axis.
"""

from repro.models.config import ModelConfig, moe_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        d_model=6144,
        n_layers=56,
        pattern=moe_pattern(),
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=32768,
        rope_theta=1000000.0,
        sliding_window=4096,
        n_experts=8,
        top_k=2,
        moe_ep=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-reduced",
        d_model=64,
        n_layers=2,
        pattern=moe_pattern(),
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        sliding_window=32,
        n_experts=4,
        top_k=2,
        q_chunk=16,
        k_chunk=16,
    )
