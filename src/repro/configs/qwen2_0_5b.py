"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias.  [arXiv:2407.10671; hf]

14 heads do not divide a 16-way model axis: attention falls back to
replicated projections (sharding rule, DESIGN.md Sec. 8) while MLP and
vocab still shard — the roofline shows the cost honestly.
"""

from repro.models.config import ModelConfig, dense_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        d_model=896,
        n_layers=24,
        pattern=dense_pattern(),
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151936,
        rope_theta=1000000.0,
        attn_bias=True,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-reduced",
        d_model=56,
        n_layers=2,
        pattern=dense_pattern(),
        n_heads=7,                # keep the awkward head count in the family
        n_kv_heads=1,
        head_dim=8,
        d_ff=128,
        vocab=512,
        attn_bias=True,
        tie_embeddings=True,
        q_chunk=16,
        k_chunk=16,
    )
