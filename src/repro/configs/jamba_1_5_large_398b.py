"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave.
[arXiv:2403.19887; hf]

Block of 8 layers: 7 mamba + 1 attention (position 4); MoE on every other
layer.  Hardware adaptation: Jamba ships Mamba-1 layers; we use the
Mamba-2 SSD form throughout (TPU-native chunked matmuls — DESIGN.md
Sec. 2).  Runs the ``long_500k`` shape.  Optimizer states must be
ZeRO-sharded + bf16 to fit 16 GB/chip (see repro.optim).
"""

from repro.models.config import (FFN_DENSE, FFN_MOE, LayerSpec,
                                 MIXER_ATTN, MIXER_MAMBA, ModelConfig,
                                 SSMConfig)

PATTERN = (
    LayerSpec(MIXER_MAMBA, FFN_DENSE),
    LayerSpec(MIXER_MAMBA, FFN_MOE),
    LayerSpec(MIXER_MAMBA, FFN_DENSE),
    LayerSpec(MIXER_MAMBA, FFN_MOE),
    LayerSpec(MIXER_ATTN, FFN_DENSE),
    LayerSpec(MIXER_MAMBA, FFN_MOE),
    LayerSpec(MIXER_MAMBA, FFN_DENSE),
    LayerSpec(MIXER_MAMBA, FFN_MOE),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        d_model=8192,
        n_layers=72,
        pattern=PATTERN,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=65536,
        n_experts=16,
        top_k=2,
        moe_ep=True,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                      n_groups=1, chunk=128),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-reduced",
        d_model=64,
        n_layers=8,
        pattern=PATTERN,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        n_experts=4,
        top_k=2,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                      n_groups=1, chunk=16),
        q_chunk=16,
        k_chunk=16,
    )
