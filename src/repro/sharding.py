"""Sharding rules for the production meshes.

Axes: single-pod mesh is ``(data=16, model=16)``; multi-pod adds a leading
``pod`` axis that *extends data parallelism hierarchically* (gradients
all-reduce inside a pod over ICI, then across pods — XLA emits the
hierarchical collective from the nested spec).

Divisibility fallback: any tensor dim not divisible by its target axis size
is replicated instead (e.g. qwen2's 14 attention heads on a 16-way model
axis).  This keeps every (arch x mesh) combination lowerable; the roofline
table then *shows* the cost of replication rather than hiding a crash.
"""

from __future__ import annotations

import logging

import jax
from jax.sharding import PartitionSpec as P

log = logging.getLogger(__name__)


class Shardings:
    """Mesh-aware spec factory with divisibility fallback.

    ``mesh=None`` disables all constraints (CPU smoke-test mode).
    """

    def __init__(self, mesh=None, *, seq_shard: bool = False,
                 decode_replicate: bool = False):
        self.mesh = mesh
        self.enabled = mesh is not None
        self.seq_shard = seq_shard
        # decode optimization: replicate the (tiny) per-token activations
        # over the data axes so matmuls contract against *locally sharded*
        # 2D weights (partial-sum + small all-reduce) instead of
        # all-gathering FSDP weight shards for a one-token batch
        self.decode_replicate = decode_replicate
        if self.enabled:
            names = mesh.axis_names
            sizes = dict(zip(names, mesh.devices.shape)) if hasattr(mesh, "devices") \
                else dict(zip(names, mesh.axis_sizes))
            self.batch_axes = tuple(a for a in ("pod", "data") if a in names)
            self.model_axis = "model" if "model" in names else None
            self.sizes = sizes
        else:
            self.batch_axes = ()
            self.model_axis = None
            self.sizes = {}

    # ---------------- axis helpers ----------------

    def axis_size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            out = 1
            for a in axis:
                out *= self.sizes.get(a, 1)
            return out
        return self.sizes.get(axis, 1)

    def maybe(self, axis, dim: int, what: str = ""):
        """axis if dim divides evenly over it, else None (replicate)."""
        if not self.enabled or axis is None:
            return None
        n = self.axis_size(axis)
        if dim % n == 0:
            return axis
        log.info("sharding fallback: %s dim %d not divisible by %s=%d -> replicated",
                 what, dim, axis, n)
        return None

    @property
    def batch(self):
        return self.batch_axes if self.batch_axes else None

    @property
    def model(self):
        return self.model_axis

    @property
    def seq(self):
        """Sequence-parallel axis for inter-block activations."""
        return self.model_axis if (self.seq_shard and self.enabled) else None

    # ---------------- constraints ----------------

    def constrain(self, x, spec: P):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    def constrain_act(self, x):
        """[B, S, D] residual-stream activations."""
        if not self.enabled:
            return x
        s = self.seq if (self.seq and x.shape[1] % self.axis_size(self.seq) == 0) else None
        return self.constrain(x, P(self.batch, s, None))

    def constrain_dec(self, x):
        """Decode-path activation entering a weight matmul."""
        if not self.enabled:
            return x
        if self.decode_replicate:
            return self.constrain(x, P(*([None] * x.ndim)))
        return self.constrain(x, P(self.batch, *([None] * (x.ndim - 1))))

    def constrain_heads(self, x):
        """[B, S, H, Dh]."""
        if not self.enabled:
            return x
        if self.decode_replicate:
            # decode2d: forcing (batch, heads) sharding right after the
            # projection makes GSPMD all-gather the weight over `data`
            # (measured — EXPERIMENTS.md Sec. Perf); leave the tiny
            # per-token tensor free and reshard at the cache instead.
            return x
        h = self.maybe(self.model, x.shape[2], "attn heads")
        return self.constrain(x, P(self.batch, None, h, None))

    def constrain_ffn(self, h):
        """[B, S, F] (or [..., F]) ffn hidden."""
        if not self.enabled:
            return h
        if self.decode_replicate:
            # decode2d: hidden sharded over the *combined* axes, batch
            # replicated (tiny per-token tensors, weights never move)
            comb = tuple([*(self.batch_axes or ()), self.model])
            f = self.maybe(comb, h.shape[-1], "ffn hidden (combined)")
            return self.constrain(h, P(*([None] * (h.ndim - 1)), f))
        f = self.maybe(self.model, h.shape[-1], "ffn hidden")
        spec = [self.batch] + [None] * (h.ndim - 2) + [f]
        return self.constrain(h, P(*spec))

    def constrain_logits(self, x):
        if not self.enabled:
            return x
        if self.decode_replicate:
            comb = tuple([*(self.batch_axes or ()), self.model])
            v = self.maybe(comb, x.shape[-1], "vocab (combined)")
            return self.constrain(x, P(None, None, v))
        v = self.maybe(self.model, x.shape[-1], "vocab")
        return self.constrain(x, P(self.batch, None, v))


NOSHARD = Shardings(None)
