"""AdamW with memory-tiering for 100B+ models on 16 GB/chip:

* moment dtype is configurable (fp32 / bf16) — jamba-398b needs bf16
  moments to fit (DESIGN.md Sec. 8);
* optional fp32 master copy of bf16 params;
* ZeRO-1: a helper that extends parameter PartitionSpecs with the ``data``
  axis for optimizer state, so moments/master shard over data parallel
  replicas (XLA then emits reduce-scatter + all-gather around the update).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"       # "float32" | "bfloat16"
    master_weights: bool = False        # fp32 master copy of bf16 params
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict
    master: Optional[dict]


def init(cfg: AdamWConfig, params) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    master = None
    if cfg.master_weights:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        master=master,
    )


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, state: AdamWState, params, grads):
    """Returns (new_params, new_state, stats)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v, pm):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        base = (pm if pm is not None else p).astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return new, m32.astype(mdt), v32.astype(mdt)

    masters = state.master if state.master is not None else \
        jax.tree.map(lambda _: None, params)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_pm = tdef.flatten_up_to(masters) if state.master is not None \
        else [None] * len(flat_p)

    new_p, new_m, new_v, new_master = [], [], [], []
    for p, g, m, v, pm in zip(flat_p, flat_g, flat_m, flat_v, flat_pm):
        np_, nm, nv = upd(p, g, m, v, pm)
        new_master.append(np_ if state.master is not None else None)
        new_p.append(np_.astype(p.dtype))
        new_m.append(nm)
        new_v.append(nv)

    params2 = jax.tree.unflatten(tdef, new_p)
    state2 = AdamWState(
        step=step,
        mu=jax.tree.unflatten(tdef, new_m),
        nu=jax.tree.unflatten(tdef, new_v),
        master=jax.tree.unflatten(tdef, new_master)
        if state.master is not None else None,
    )
    return params2, state2, {"lr": lr, "grad_norm": gnorm}


# --------------------------------------------------------------------------
# ZeRO-1 sharding
# --------------------------------------------------------------------------


def zero1_spec(param_spec: P, shape, data_axes, axis_sizes) -> P:
    """Extend a parameter spec with data-axis sharding on the first
    divisible, currently-unsharded dim (optimizer-state sharding).
    No-op when the data axes already appear (FSDP-sharded params)."""
    spec = list(param_spec) if param_spec else []
    spec += [None] * (len(shape) - len(spec))
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    used = set()
    for entry in spec:
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            used.add(a)
    if used & set(axes):
        return P(*spec)     # already data-sharded (FSDP): ZeRO-1 is implied
    n = 1
    for a in axes:
        n *= axis_sizes.get(a, 1)
    for i, (dim, cur) in enumerate(zip(shape, spec)):
        if cur is None and dim % n == 0 and dim >= n:
            spec[i] = data_axes
            return P(*spec)
    return P(*spec)  # nothing divisible: stays replicated over data


def zero1_state_specs(cfg: AdamWConfig, param_specs, param_shapes, sh):
    """Build the AdamWState spec tree from parameter specs."""
    def ext(ps, shp):
        return zero1_spec(ps, shp.shape, sh.batch_axes or ("data",), sh.sizes)

    mom = jax.tree.map(ext, param_specs, param_shapes)
    return AdamWState(
        step=P(),
        mu=mom,
        nu=jax.tree.map(lambda x: x, mom),
        master=mom if cfg.master_weights else None,
    )
