"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Projections are kept *separate* (wz/wx/wB/wC/wdt rather than one fused
in_proj) so each shards independently on the model axis without slicing a
sharded dimension; B/C are group-shared and replicated (they are tiny and
every head shard needs them).

Training/prefill uses the chunked SSD decomposition (`ssd_jnp`, identical
math to the Pallas kernel); decode updates the [H, P, N] state recurrently.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import ssd_jnp_with_state
from repro.models import layers as L


def mamba_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    return {
        "wz": L.dense_init(ks[0], d, di),
        "wx": L.dense_init(ks[1], d, di),
        "wB": L.dense_init(ks[2], d, gn),
        "wC": L.dense_init(ks[3], d, gn),
        "wdt": L.dense_init(ks[4], d, h),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "Dskip": jnp.ones((h,), jnp.float32),
        "conv_x": (jax.random.normal(ks[5], (s.conv_kernel, di), jnp.float32)
                   * (s.conv_kernel * di) ** -0.5).astype(L.PARAM_DTYPE),
        "conv_B": (jax.random.normal(ks[6], (s.conv_kernel, gn), jnp.float32)
                   * (s.conv_kernel * gn) ** -0.5).astype(L.PARAM_DTYPE),
        "conv_C": (jax.random.normal(ks[7], (s.conv_kernel, gn), jnp.float32)
                   * (s.conv_kernel * gn) ** -0.5).astype(L.PARAM_DTYPE),
        "norm": L.rmsnorm_init(di),
        "out": L.dense_init(jax.random.fold_in(key, 99), di, d, scale=di ** -0.5),
    }


def _causal_conv(x, w):
    """Depthwise causal conv: x [B, S, C], w [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def mamba_apply(p, cfg, x, sh=None, return_state: bool = False):
    """x: [B, S, D] -> [B, S, D] (optionally also the decode cache)."""
    s = cfg.ssm
    b, sl, d = x.shape
    di = s.d_inner(d)
    h = s.n_heads(d)
    pdim = s.head_dim
    n = s.d_state
    g = s.n_groups

    z = x @ p["wz"]
    x_pre, B_pre, C_pre = x @ p["wx"], x @ p["wB"], x @ p["wC"]
    xs = jax.nn.silu(_causal_conv(x_pre, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(B_pre, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(C_pre, p["conv_C"]))
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    if sh is not None:
        xs = sh.constrain_ffn(xs)
        z = sh.constrain_ffn(z)

    A = -jnp.exp(p["A_log"])                                  # [H] negative
    loga = dt * A                                             # [B, S, H]
    xh = xs.reshape(b, sl, h, pdim)
    xbar = xh * dt[..., None]

    # expand groups to heads (GVA-style sharing)
    rep = h // g
    Bh = jnp.repeat(Bm.reshape(b, sl, g, n), rep, axis=2)
    Ch = jnp.repeat(Cm.reshape(b, sl, g, n), rep, axis=2)

    # pad to a chunk multiple: x=0 contributes nothing; loga=0 (decay 1)
    # leaves the carried state untouched, so the final state stays exact
    chunk = min(s.chunk, sl)
    pad = (-sl) % chunk
    slp = sl + pad

    # [B, S, H, *] -> [B*H, S, *] for the SSD core
    def to_bh(t):
        t = jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return t.transpose(0, 2, 1, 3).reshape(b * h, slp, t.shape[-1])

    loga_p = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_jnp_with_state(
        to_bh(xbar), loga_p.transpose(0, 2, 1).reshape(b * h, slp),
        to_bh(Bh), to_bh(Ch), chunk=chunk)
    y = y.reshape(b, h, slp, pdim)[:, :, :sl].transpose(0, 2, 1, 3)  # [B, S, H, P]
    y = y + xh.astype(jnp.float32) * p["Dskip"][None, None, :, None]
    y = y.reshape(b, sl, di).astype(x.dtype)

    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    out = y @ p["out"]
    if not return_state:
        return out
    k = s.conv_kernel - 1
    cache = {
        # ssd state comes back [BH, N, P] -> decode layout [B, H, P, N]
        "ssm": state.reshape(b, h, n, pdim).transpose(0, 1, 3, 2),
        "conv_x": x_pre[:, -k:].astype(jnp.float32),
        "conv_B": B_pre[:, -k:].astype(jnp.float32),
        "conv_C": C_pre[:, -k:].astype(jnp.float32),
    }
    return out, cache


def mamba_init_cache(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di, h, gn = s.d_inner(d), s.n_heads(d), s.n_groups * s.d_state
    return {
        "ssm": jnp.zeros((batch, h, s.head_dim, s.d_state), dtype),
        "conv_x": jnp.zeros((batch, s.conv_kernel - 1, di), dtype),
        "conv_B": jnp.zeros((batch, s.conv_kernel - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, s.conv_kernel - 1, gn), dtype),
    }


def _conv_step(cache, x1, w):
    """cache [B, K-1, C], x1 [B, C] -> (new_cache, out [B, C])."""
    hist = jnp.concatenate([cache, x1[:, None]], axis=1)      # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                     w.astype(jnp.float32))
    return hist[:, 1:], out.astype(x1.dtype)


def mamba_decode(p, cfg, x1, cache, sh=None):
    """Single-token step. x1: [B, 1, D]."""
    s = cfg.ssm
    b, _, d = x1.shape
    h = s.n_heads(d)
    pdim, n, g = s.head_dim, s.d_state, s.n_groups
    x0 = x1[:, 0]

    z = x0 @ p["wz"]
    cache_cx, xs = _conv_step(cache["conv_x"], x0 @ p["wx"], p["conv_x"])
    cache_cb, Bm = _conv_step(cache["conv_B"], x0 @ p["wB"], p["conv_B"])
    cache_cc, Cm = _conv_step(cache["conv_C"], x0 @ p["wC"], p["conv_C"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus((x0 @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])  # [B,H]

    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                       # [B, H]
    xh = xs.reshape(b, h, pdim).astype(jnp.float32)
    xbar = xh * dt[..., None]
    rep = h // g
    Bh = jnp.repeat(Bm.reshape(b, g, n), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(b, g, n), rep, axis=1).astype(jnp.float32)

    S = cache["ssm"] * a[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xbar, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", S, Ch)
    y = y + xh * p["Dskip"][None, :, None]
    y = y.reshape(b, s.d_inner(d)).astype(x1.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    new_cache = {"ssm": S, "conv_x": cache_cx, "conv_B": cache_cb,
                 "conv_C": cache_cc}
    return (y @ p["out"])[:, None], new_cache
