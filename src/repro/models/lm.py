"""Full language model: embedding/frontend -> layer stack -> head, plus the
serving paths (prefill with cache emission, single-token decode).

Inputs are a dict batch:
  tokens  i32[B, S]          (frontend="tokens")
  embeds  f[B, S, D]         (frontend="embeddings": musicgen frames /
                              VLM patch stub — see DESIGN.md Sec. 5)
  cross   f[B, Sk, D]        (VLM cross-attention context, stub embeddings)
  labels  i32[B, S]          (training; -1 = masked)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import mla as MLA
from repro.models.config import (FFN_NONE, MIXER_CROSS,
                                 MIXER_MAMBA, ModelConfig)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    params = {"groups": B.stack_init(ks[0], cfg),
              "final_norm": L.rmsnorm_init(cfg.d_model)}
    if cfg.frontend == "tokens":
        params["embed"] = L.embed_init(ks[1], cfg.padded_vocab, cfg.d_model)
    if not cfg.tie_embeddings or cfg.frontend != "tokens":
        params["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.padded_vocab,
                                         scale=0.02)
    return params


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------


def _frontend(params, cfg, batch, sh):
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["embeds"].astype(L.PARAM_DTYPE)
    if sh is not None:
        x = sh.constrain_act(x)
    return x


def _head(params, cfg, x, sh):
    if "lm_head" in params:
        logits = x @ params["lm_head"]
    else:
        logits = x @ params["embed"].T
    if sh is not None:
        logits = sh.constrain_logits(logits)
    return logits


def forward(params, cfg: ModelConfig, batch, sh=None, remat: bool = True):
    """Returns (logits f32[B, S, Vpad], aux_loss)."""
    x = _frontend(params, cfg, batch, sh)
    bsz, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    cross = batch.get("cross")
    if cross is not None:
        cross = cross.astype(x.dtype)
    x, aux = B.stack_apply(params["groups"], cfg, x, positions, sh,
                           cross_feed=cross, remat=remat)
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_eps)
    return _head(params, cfg, x, sh), aux


def loss_fn(params, cfg: ModelConfig, batch, sh=None, remat: bool = True,
            aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, batch, sh, remat)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    nll = L.cross_entropy(logits, jnp.maximum(labels, 0), mask)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Per-pattern-position caches, stacked over repetitions [G, ...]."""
    G = cfg.repeats
    caches = []
    for spec in cfg.pattern:
        if spec.mixer == MIXER_MAMBA:
            c = M.mamba_init_cache(cfg, batch, jnp.float32)
        elif spec.mixer == MIXER_CROSS:
            dh = cfg.head_dim_
            c = {"k": jnp.zeros((batch, cfg.cross_kv_len, cfg.n_kv_heads, dh), dtype),
                 "v": jnp.zeros((batch, cfg.cross_kv_len, cfg.n_kv_heads, dh), dtype)}
        elif cfg.mla is not None:
            m = cfg.mla
            c = {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                 "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype)}
        else:
            dh = cfg.head_dim_
            c = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
                 "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype)}
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (G,) + a.shape), c))
    return caches


def _attn_decode_layer(p, cfg, spec, x1, positions, cache, cache_len, sh,
                       cross_feed=None):
    """One layer, one token.  Returns (x1, new_cache)."""
    h = L.rmsnorm(x1, p["ln"], cfg.rms_eps)
    if sh is not None:
        h = sh.constrain_dec(h)
    if spec.mixer == MIXER_MAMBA:
        mix, cache = M.mamba_decode(p["mixer"], cfg, h, cache, sh)
    elif spec.mixer == MIXER_CROSS:
        q, _, _ = A.attn_qkv(p["mixer"], cfg, h, h, None, sh)
        kc, vc = cache["k"], cache["v"]
        clen = jnp.full((x1.shape[0],), kc.shape[1], jnp.int32)
        out = A.decode_attention(q, kc, vc, clen)
        out = out.reshape(*x1.shape[:-1], cfg.n_heads * cfg.head_dim_)
        if sh is not None:
            out = sh.constrain_ffn(out)   # contract-dim layout for wo
        mix = out @ p["mixer"]["wo"]
    elif cfg.mla is not None:
        mix, ckv, kr = MLA.mla_decode(p["mixer"], cfg, h, positions,
                                      cache["ckv"], cache["kr"], cache_len)
        cache = {"ckv": ckv, "kr": kr}
    else:
        q, k, v = A.attn_qkv(p["mixer"], cfg, h, h, positions, sh)
        idx = cache_len[:, None] - 1
        upd = lambda c, val: jax.vmap(
            lambda cb, ib, vb: jax.lax.dynamic_update_slice(
                cb, vb.astype(cb.dtype), (ib[0], 0, 0)))(c, idx, val)
        kc, vc = upd(cache["k"], k), upd(cache["v"], v)
        out = A.decode_attention(q, kc, vc, cache_len,
                                 window=cfg.sliding_window)
        out = out.reshape(*x1.shape[:-1], cfg.n_heads * cfg.head_dim_)
        if sh is not None:
            out = sh.constrain_ffn(out)   # contract-dim layout for wo
        mix = out @ p["mixer"]["wo"]
        cache = {"k": kc, "v": vc}
    x1 = x1 + mix
    if spec.ffn != FFN_NONE:
        h2 = L.rmsnorm(x1, p["ln2"], cfg.rms_eps)
        if sh is not None:
            h2 = sh.constrain_dec(h2)
        if spec.ffn == "moe":
            from repro.models import moe as MOE
            out, _ = MOE.moe_apply(p["ffn"], cfg, h2, sh)
        else:
            out = L.swiglu(p["ffn"], h2, sh)
        x1 = x1 + out
    return x1, cache


def decode_step(params, cfg: ModelConfig, batch, caches, cache_len, sh=None):
    """One new token against existing caches.

    batch: tokens i32[B, 1] or embeds [B, 1, D]; cache_len i32[B] = prefix
    length including this token.  Returns (logits [B, 1, Vpad], caches').
    """
    x = _frontend(params, cfg, batch, sh)
    positions = (cache_len - 1)[:, None]

    def body(x, slices):
        group_slice, cache_slice = slices
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            x, c = _attn_decode_layer(group_slice[i], cfg, spec, x,
                                      positions, cache_slice[i], cache_len, sh)
            new_caches.append(c)
        return x, new_caches

    if cfg.unroll:
        outs = []
        for r in range(cfg.repeats):
            x, c = body(x, jax.tree.map(lambda t: t[r], (params["groups"], caches)))
            outs.append(c)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, new_caches = jax.lax.scan(body, x, (params["groups"], caches))
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_eps)
    return _head(params, cfg, x, sh), new_caches


def prefill(params, cfg: ModelConfig, batch, max_len: int, sh=None,
            remat: bool = False):
    """Process a prompt, returning (logits, caches, cache_len).

    Caches are allocated at ``max_len``; attention caches carry the prompt
    K/V; mamba caches carry the final SSM/conv states.

    ``remat`` defaults to False: there is no backward pass, so checkpoint
    wrappers only obstruct GSPMD constraint propagation (measured: a
    spurious 7.5 GiB/layer expert-tensor all-gather — EXPERIMENTS.md
    Sec. Perf).
    """
    x = _frontend(params, cfg, batch, sh)
    bsz, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    cross = batch.get("cross")
    if cross is not None:
        cross = cross.astype(x.dtype)

    def body(x, group_slice):
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            p = group_slice[i]
            h = L.rmsnorm(x, p["ln"], cfg.rms_eps)
            if spec.mixer == MIXER_MAMBA:
                mix, cache = M.mamba_apply(p["mixer"], cfg, h, sh,
                                           return_state=True)
            elif spec.mixer == MIXER_CROSS:
                mix = A.attn_apply(p["mixer"], cfg, h, None, sh,
                                   cross_feed=cross)
                _, k, v = A.attn_qkv(p["mixer"], cfg, cross, cross, None, sh)
                cache = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
            elif cfg.mla is not None:
                mix = MLA.mla_apply(p["mixer"], cfg, h, positions, sh)
                ckv, kr = MLA.mla_latents(p["mixer"], cfg, h, positions)
                pad = max_len - s
                cache = {"ckv": jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16),
                         "kr": jnp.pad(kr[:, :, 0, :], ((0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16)}
            else:
                q, k, v = A.attn_qkv(p["mixer"], cfg, h, h, positions, sh)
                mix = A.gqa(q, k, v, causal=True, window=cfg.sliding_window,
                            q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)
                mix = mix.reshape(*x.shape[:-1], cfg.n_heads * cfg.head_dim_)
                mix = mix @ p["mixer"]["wo"]
                pad = max_len - s
                cache = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16),
                         "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)}
            x = x + mix
            if spec.ffn != FFN_NONE:
                h2 = L.rmsnorm(x, p["ln2"], cfg.rms_eps)
                if spec.ffn == "moe":
                    from repro.models import moe as MOE
                    out, _ = MOE.moe_apply(p["ffn"], cfg, h2, sh)
                else:
                    out = L.swiglu(p["ffn"], h2, sh)
                x = x + out
            if sh is not None:
                x = sh.constrain_act(x)
            new_caches.append(cache)
        return x, new_caches

    if remat:
        body = jax.checkpoint(body)
    if cfg.unroll:
        outs = []
        for r in range(cfg.repeats):
            x, c = body(x, jax.tree.map(lambda t: t[r], params["groups"]))
            outs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, caches = jax.lax.scan(body, x, params["groups"])
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_eps)
    cache_len = jnp.full((bsz,), s, jnp.int32)
    return _head(params, cfg, x[:, -1:], sh), caches, cache_len
