"""Decoder layers: dispatch over (mixer, ffn) kinds + scan-over-groups.

Depth is organized as ``pattern x repeats``: parameters for each position in
the pattern are stacked across repetitions and the stack is consumed by one
``lax.scan`` (compile time O(|pattern|), memory O(1) layers live), with
``jax.checkpoint`` around the scan body for activation rematerialization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models.config import (FFN_MOE, FFN_NONE, MIXER_ATTN,
                                 MIXER_CROSS, MIXER_MAMBA, LayerSpec)


def layer_init(key, cfg, spec: LayerSpec):
    k1, k2 = jax.random.split(key)
    p = {"ln": L.rmsnorm_init(cfg.d_model)}
    if spec.mixer in (MIXER_ATTN, MIXER_CROSS):
        if cfg.mla is not None:
            p["mixer"] = MLA.mla_init(k1, cfg)
        else:
            p["mixer"] = A.attn_init(k1, cfg, cross=spec.mixer == MIXER_CROSS)
    elif spec.mixer == MIXER_MAMBA:
        p["mixer"] = M.mamba_init(k1, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != FFN_NONE:
        p["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"] = (MOE.moe_init(k2, cfg) if spec.ffn == FFN_MOE
                    else L.swiglu_init(k2, cfg.d_model, cfg.d_ff))
    return p


def layer_apply(p, cfg, spec: LayerSpec, x, positions, sh, cross_feed=None):
    """Training/eval forward for one layer.  Returns (x, aux_loss)."""
    h = L.rmsnorm(x, p["ln"], cfg.rms_eps)
    if spec.mixer == MIXER_CROSS:
        mix = A.attn_apply(p["mixer"], cfg, h, None, sh, cross_feed=cross_feed)
    elif spec.mixer == MIXER_ATTN:
        if cfg.mla is not None:
            mix = MLA.mla_apply(p["mixer"], cfg, h, positions, sh)
        else:
            mix = A.attn_apply(p["mixer"], cfg, h, positions, sh)
    else:
        mix = M.mamba_apply(p["mixer"], cfg, h, sh)
    x = x + mix
    if sh is not None:
        x = sh.constrain_act(x)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != FFN_NONE:
        h2 = L.rmsnorm(x, p["ln2"], cfg.rms_eps)
        if spec.ffn == FFN_MOE:
            out, aux = MOE.moe_apply(p["ffn"], cfg, h2, sh)
        else:
            out = L.swiglu(p["ffn"], h2, sh)
        x = x + out
        if sh is not None:
            x = sh.constrain_act(x)
    return x, aux


def stack_init(key, cfg):
    """Init the full depth: list over pattern positions, each stacked [G, ...]."""
    G = cfg.repeats
    groups = []
    for i, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), G)
        stacked = jax.vmap(lambda k: layer_init(k, cfg, spec))(keys)
        groups.append(stacked)
    return groups


def stack_apply(groups, cfg, x, positions, sh, cross_feed=None,
                remat: bool = True):
    """Scan over repetitions; each body runs one full pattern."""

    def body(x, group_slice):
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.pattern):
            x, a = layer_apply(group_slice[i], cfg, spec, x, positions, sh,
                               cross_feed=cross_feed)
            aux = aux + a
        return x, aux

    if remat:
        body = jax.checkpoint(body)

    if cfg.unroll:
        aux = jnp.zeros((), jnp.float32)
        for r in range(cfg.repeats):
            x, a = body(x, jax.tree.map(lambda t: t[r], groups))
            aux = aux + a
        return x, aux

    x, auxs = jax.lax.scan(lambda c, g: body(c, g), x, groups)
    return x, jnp.sum(auxs)
