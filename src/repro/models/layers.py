"""Shared neural-net building blocks (pure JAX, dict-pytree parameters)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16


def dense_init(key, d_in, d_out, scale=None, dtype=PARAM_DTYPE):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def rmsnorm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float, positions):
    """positions: i32[...S] -> (cos, sin) [..., S, head_dim/2] f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., S, H, D]; cos/sin: [..., S, D/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


def swiglu_init(key, d_model, d_ff, dtype=PARAM_DTYPE):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(params, x, sh=None):
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    if sh is not None:
        h = sh.constrain_ffn(h)
    return h @ params["down"]


def embed_init(key, vocab, d_model, dtype=PARAM_DTYPE):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def cross_entropy(logits, labels, mask=None):
    """logits [B,S,V] (any float dtype), labels i32 [B,S] -> mean nll."""
    logits = logits.astype(jnp.float32)
    m = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(m, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
