"""Model configuration.

A model is a repeated ``layer pattern``: e.g. a dense transformer is
``(attn+dense,) * L``; Jamba is ``(mamba, mamba+moe, ..., attn, ...) * G``.
Scan-over-layers stacks parameters across pattern repetitions, so compile
time is O(pattern length), not O(depth).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

MIXER_ATTN = "attn"
MIXER_MAMBA = "mamba"
MIXER_CROSS = "cross"    # cross-attention onto frontend embeddings (VLM)

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = MIXER_ATTN
    ffn: str = FFN_DENSE


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int                       # total depth = len(pattern) * repeats
    pattern: tuple                      # tuple[LayerSpec]
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                   # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 0
    vocab_pad_to: int = 256
    rope_theta: float = 10000.0
    qk_norm: bool = False               # qwen3
    attn_bias: bool = False             # qwen2 QKV bias
    sliding_window: int = 0             # mixtral SWA
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_ep: bool = False                # expert-parallel (vs TP-in-expert) sharding
    moe_sorted: bool = False            # sort-based dispatch (vs one-hot einsum)
    moe_bf16: bool = False              # bf16 dispatch/combine tensors
    moe_local_chunks: int = 0           # local-capacity routing: capacity
                                        # computed within each of N seq chunks
                                        # (removes the cross-shard cumsum)
    attn_bf16: bool = False             # bf16 attention scores/probs (vs f32)
    # family extras
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # frontend: "tokens" (LM) or "embeddings" (musicgen frames / VLM patches)
    frontend: str = "tokens"
    cross_kv_len: int = 0               # stub image/frame context length (VLM)
    # attention implementation chunk sizes (pure-JAX blocked attention)
    q_chunk: int = 512
    k_chunk: int = 1024
    # unroll every structural loop (layer stack, attention chunk loops):
    # used by the roofline extractor so XLA cost_analysis counts every
    # executed op exactly once (scan bodies are otherwise counted once
    # regardless of trip count)
    unroll: bool = False

    def __post_init__(self):
        if self.n_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"pattern length {len(self.pattern)}")

    @property
    def repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        pad = self.vocab_pad_to
        return -(-self.vocab // pad) * pad

    def param_count(self) -> int:
        """Analytic parameter count (for 6*N*D roofline math)."""
        from repro.models import lm
        import jax

        shapes = jax.eval_shape(lambda: lm.init_params(self, jax.random.key(0)))
        return sum(int(x.size) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts).

        Expert tensors are the rank-3+ ``gate``/``up``/``down`` leaves
        under ``ffn`` (leading dims: [G-stack,] experts)."""
        if not self.n_experts:
            return self.param_count()
        from repro.models import lm
        import jax

        shapes = jax.eval_shape(lambda: lm.init_params(self, jax.random.key(0)))
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
            n = int(leaf.size)
            if keys[-1] in ("gate", "up", "down") and "ffn" in keys \
                    and len(leaf.shape) >= 3 and self.n_experts in leaf.shape:
                n = n * self.top_k // self.n_experts
            total += n
        return total


def dense_pattern() -> tuple:
    return (LayerSpec(MIXER_ATTN, FFN_DENSE),)


def moe_pattern() -> tuple:
    return (LayerSpec(MIXER_ATTN, FFN_MOE),)
