"""Mixture-of-Experts: token-choice top-k routing with GShard-style
capacity-bounded einsum dispatch (GSPMD-friendly: the dispatch/combine
tensors shard cleanly over either the expert axis (EP) or the hidden axis
(TP-in-expert)).

Expert-parallel sharding emits the alltoall traffic pattern the paper
studies in Sec. 4.5 — the collectives bridge (repro.collectives) maps it
onto the netsim alltoall workload.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale_in = d ** -0.5
    scale_out = f ** -0.5

    def experts(k, d_in, d_out, scale):
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32)
                * scale).astype(L.PARAM_DTYPE)

    return {
        "router": L.dense_init(ks[0], d, e, scale=0.02, dtype=jnp.float32),
        "gate": experts(ks[1], d, f, scale_in),
        "up": experts(ks[2], d, f, scale_in),
        "down": experts(ks[3], f, d, scale_out),
    }


def moe_apply(p, cfg, x, sh=None):
    """x: [B, S, D] -> [B, S, D] plus auxiliary load-balancing loss."""
    if cfg.moe_sorted:
        return moe_apply_sorted(p, cfg, x, sh)
    if cfg.moe_local_chunks > 1 and x.shape[1] % cfg.moe_local_chunks == 0:
        return moe_apply_local(p, cfg, x, sh)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, -(-int(cfg.capacity_factor * s * k) // e))   # ceil

    logits = x.astype(jnp.float32) @ p["router"]             # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [B, S, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, choice) within its expert's buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # [B, S, K, E]
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                    # [B, S*K, E]
    pos = pos.reshape(b, s, k, e)
    keep = (pos < cap) * onehot                              # drop overflow
    pos_cap = jnp.minimum(pos, cap - 1).astype(jnp.int32)

    # dispatch [B, S, E, C] / combine weights.  bf16 mode (hillclimb):
    # the [B,S,K,E,C] one-hots are the layer's largest tensors and 0/1 is
    # exactly representable — build them *directly* in bf16 (a cast after
    # an f32 one_hot leaves the dominant f32 buffer in the profile).
    ddt = jnp.bfloat16 if cfg.moe_bf16 else jnp.float32
    oh_cap = jax.nn.one_hot(pos_cap, cap, dtype=ddt)         # [B, S, K, E, C]
    disp = (keep.astype(ddt)[..., None] * oh_cap).sum(axis=2)
    comb = ((keep * gate_vals[..., None]).astype(ddt)[..., None] * oh_cap
            ).sum(axis=2)                                    # [B, S, E, C]

    # NB: bf16 x bf16 dots accumulate in f32 inside XLA; an explicit
    # preferred_element_type=f32 is unsupported by the CPU runtime.
    xe = jnp.einsum("bsec,bsd->ebcd", disp, x.astype(ddt))   # [E,B,C,D]
    xe = xe.astype(x.dtype)
    if sh is not None and sh.enabled:
        espec = sh.maybe(sh.model, e, "moe experts") if cfg.moe_ep else None
        xe = sh.constrain(xe, jax.sharding.PartitionSpec(espec, sh.batch, None, None))
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, p["gate"])) * \
        jnp.einsum("ebcd,edf->ebcf", xe, p["up"])
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["down"])          # [E, B, C, D]
    y = jnp.einsum("bsec,ebcd->bsd", comb, ye.astype(ddt)).astype(jnp.float32)

    # auxiliary load-balance loss (Switch-style)
    me = jnp.mean(onehot.sum(axis=2).reshape(-1, e), axis=0)
    pe = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(me * pe)
    return y.astype(x.dtype), aux


def moe_apply_local(p, cfg, x, sh=None):
    """Local-capacity routing (hillclimb, EXPERIMENTS.md Sec. Perf cell B).

    With sequence-parallel activations, the global capacity cumsum spans
    the model-sharded sequence dim — an inherently sequential op GSPMD can
    only satisfy by gathering the whole routing tensor.  Folding the
    sequence into ``moe_local_chunks`` independent routing groups (aligned
    with the SP shards, capacity cap/N each) keeps every cumsum local.
    Semantics match deployed EP systems, which enforce per-device capacity
    anyway; balance *improves* slightly (finer-grained overflow drops)."""
    b, s, d = x.shape
    n = cfg.moe_local_chunks
    import dataclasses as _dc
    sub = _dc.replace(cfg, moe_local_chunks=0)
    xr = x.reshape(b * n, s // n, d)
    y, aux = moe_apply(p, sub, xr, sh)
    return y.reshape(b, s, d), aux


def moe_apply_sorted(p, cfg, x, sh=None):
    """Sort-based dispatch (hillclimb optimization, EXPERIMENTS.md Sec. Perf).

    The one-hot einsum dispatch costs O(S*E*C*D) flops and materializes
    [B,S,E,C] tensors; sorting (token, choice) pairs by expert and
    gather/scattering into [E, C, D] buffers costs O(S log S + E*C*D) —
    for a 32k-token prefill that removes the dominant dispatch matmuls.
    Capacity is global over the device batch (slightly *better* balance
    than per-row capacity; equivalence at high capacity is tested)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cap = max(1, -(-int(cfg.capacity_factor * t * k) // e))

    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"]            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    exp_flat = gate_idx.reshape(t * k)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    gv_flat = gate_vals.reshape(t * k)
    order = jnp.argsort(exp_flat, stable=True)
    exp_s = exp_flat[order]
    first = jnp.searchsorted(exp_s, exp_s, side="left")
    rank = jnp.arange(t * k, dtype=first.dtype) - first      # pos within expert
    keep = rank < cap
    buf = jnp.where(keep, exp_s * cap + rank.astype(jnp.int32), e * cap)

    xe = jnp.zeros((e * cap + 1, d), x.dtype).at[buf].set(xf[tok_flat[order]])
    xe = xe[:e * cap].reshape(e, cap, d)
    if sh is not None and sh.enabled:
        espec = sh.maybe(sh.model, e, "moe experts") if cfg.moe_ep else None
        xe = sh.constrain(xe, jax.sharding.PartitionSpec(espec, None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["down"]).reshape(e * cap, d)

    contrib = jnp.where(keep[:, None], ye[jnp.minimum(buf, e * cap - 1)], 0.0)
    contrib = contrib * gv_flat[order][:, None]
    y = jnp.zeros((t, d), jnp.float32).at[tok_flat[order]].add(
        contrib.astype(jnp.float32))

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
    me = jnp.mean(onehot.sum(axis=1), axis=0)
    pe = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * pe)
    return y.reshape(b, s, d).astype(x.dtype), aux
