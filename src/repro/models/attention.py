"""Attention: GQA self-attention, sliding-window, cross-attention, and the
pure-JAX blocked (flash-style) implementation used on CPU and in the
dry-run.  The Pallas kernel in ``repro.kernels.flash_attn`` implements the
same online-softmax decomposition for TPU runtimes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


def _flash_body(q, k, v, q_off, k_off, causal, window):
    """One (q_chunk x k_chunk) online-softmax tile. All f32."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k)
    cq, ck = q.shape[2], k.shape[2]
    qpos = q_off + jnp.arange(cq)[:, None]
    kpos = k_off + jnp.arange(ck)[None, :]
    mask = jnp.ones((cq, ck), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return jnp.where(mask, s, NEG_INF)


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_chunk: int = 512, k_chunk: int = 1024,
                      scale: float | None = None, unroll: bool = False,
                      score_dtype=jnp.float32):
    """Flash-style attention in pure jnp (XLA path).

    q: [B, H, Sq, D]; k/v: [B, H, Sk, D].  Memory is O(q_chunk * k_chunk)
    per tile instead of O(Sq * Sk).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    dv = v.shape[-1]            # value head dim may differ (MLA)
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    while sq % q_chunk:
        q_chunk //= 2
    while sk % k_chunk:
        k_chunk //= 2
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = scale if scale is not None else d ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, h, nq, q_chunk, d)
    kf = k.astype(jnp.float32).reshape(b, h, nk, k_chunk, d)
    vf = v.astype(jnp.float32).reshape(b, h, nk, k_chunk, dv)
    q_base = sk - sq   # align ends (supports decode-style shorter q)

    def per_q(qi, qblk):
        m = jnp.full((b, h, q_chunk, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, q_chunk, 1), jnp.float32)
        acc = jnp.zeros((b, h, q_chunk, dv), jnp.float32)

        def body(ki, carry):
            m, l, acc = carry
            s = _flash_body(qblk, kf[:, :, ki], vf[:, :, ki],
                            q_base + qi * q_chunk, ki * k_chunk, causal, window)
            # score_dtype=bf16 halves the HBM traffic of the two largest
            # intermediates (scores + probs); softmax stats stay f32
            s = s.astype(score_dtype)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True)
                                .astype(jnp.float32))
            p = jnp.exp(s.astype(jnp.float32) - m_new).astype(score_dtype)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p.astype(jnp.float32), axis=-1,
                                        keepdims=True)
            acc_new = alpha * acc + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(jnp.float32), vf[:, :, ki])
            return m_new, l_new, acc_new

        if unroll:
            carry = (m, l, acc)
            for ki in range(nk):
                carry = body(ki, carry)
            m, l, acc = carry
        else:
            m, l, acc = jax.lax.fori_loop(0, nk, body, (m, l, acc))
        return acc / jnp.maximum(l, 1e-30)

    if unroll:
        out = jnp.stack([per_q(qi, qf[:, :, qi]) for qi in range(nq)])
    else:
        def scan_body(_, qi):
            return None, per_q(qi, qf[:, :, qi])

        _, out = jax.lax.scan(scan_body, None, jnp.arange(nq))
    # out: [nq, B, H, q_chunk, Dv] -> [B, H, Sq, Dv]
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, sq, dv)
    return out.astype(q.dtype)


def gqa(q, k, v, **kw):
    """Broadcast kv heads then run blocked attention.  q [B,S,H,D] layout."""
    hq, hkv = q.shape[2], k.shape[2]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if hq != hkv:
        rep = hq // hkv
        kt = jnp.repeat(kt, rep, axis=1)
        vt = jnp.repeat(vt, rep, axis=1)
    out = blocked_attention(qt, kt, vt, **kw)
    return out.transpose(0, 2, 1, 3)


def decode_attention(q1, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-token decode: q1 [B, 1, H, D]; caches [B, S, Hkv, D];
    cache_len i32[B] = valid prefix length (includes the new token).

    The caches are consumed in their storage dtype with f32 accumulation
    (``preferred_element_type``) — materializing an f32 copy of a 32k-500k
    token cache would double HBM traffic and, under SPMD, strip the cache's
    sharding right before the contraction (EXPERIMENTS.md Sec. Perf)."""
    b, s, hkv, d = k_cache.shape
    hq = q1.shape[2]
    rep = hq // hkv
    q = (q1[:, 0].astype(jnp.float32) * (d ** -0.5)).astype(k_cache.dtype)
    # bf16 dots accumulate in f32 inside XLA; explicit f32 outputs on bf16
    # operands are rejected by the CPU runtime, so cast after the einsum.
    if rep > 1:
        qr = q.reshape(b, hkv, rep, d)
        s_ = jnp.einsum("bgrd,bsgd->bgrs", qr, k_cache).astype(jnp.float32)
        s_ = s_.reshape(b, hq, s)
    else:
        s_ = jnp.einsum("bhd,bshd->bhs", q, k_cache).astype(jnp.float32)
    pos = jnp.arange(s)[None, None, :]
    mask = pos < cache_len[:, None, None]
    if window > 0:
        mask &= pos >= cache_len[:, None, None] - window
    s_ = jnp.where(mask, s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1).astype(v_cache.dtype)
    if rep > 1:
        pr = p.reshape(b, hkv, rep, s)
        out = jnp.einsum("bgrs,bsgd->bgrd", pr, v_cache
                         ).astype(jnp.float32).reshape(b, hq, d)
    else:
        out = jnp.einsum("bhs,bshd->bhd", p, v_cache).astype(jnp.float32)
    return out[:, None].astype(q1.dtype)                     # [B, 1, H, D]


# ---------------------------------------------------------------------------
# parameter init / apply for a GQA attention block
# ---------------------------------------------------------------------------


def attn_init(key, cfg, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 5)
    p = {
        "wq": L.dense_init(ks[0], d, hq * dh),
        "wk": L.dense_init(ks[1], d, hkv * dh),
        "wv": L.dense_init(ks[2], d, hkv * dh),
        "wo": L.dense_init(ks[3], hq * dh, d, scale=(hq * dh) ** -0.5),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((hq * dh,), L.PARAM_DTYPE)
        p["bk"] = jnp.zeros((hkv * dh,), L.PARAM_DTYPE)
        p["bv"] = jnp.zeros((hkv * dh,), L.PARAM_DTYPE)
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh)
        p["k_norm"] = L.rmsnorm_init(dh)
    return p


def attn_qkv(p, cfg, x, kv_src, positions, sh):
    """Project to q, k, v (RoPE'd, normed). kv_src = x (self) or cross feed."""
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*x.shape[:-1], hq, dh)
    k = k.reshape(*kv_src.shape[:-1], hkv, dh)
    v = v.reshape(*kv_src.shape[:-1], hkv, dh)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.rms_eps)
    if positions is not None:
        cos, sin = L.rope_freqs(dh, cfg.rope_theta, positions)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    if sh is not None:
        q, k, v = sh.constrain_heads(q), sh.constrain_heads(k), sh.constrain_heads(v)
    return q, k, v


def attn_apply(p, cfg, x, positions, sh, *, cross_feed=None):
    """Full attention block body (no residual/norm — the caller owns those)."""
    sdt = jnp.bfloat16 if cfg.attn_bf16 else jnp.float32
    if cross_feed is not None:
        q, k, v = attn_qkv(p, cfg, x, cross_feed, None, sh)
        out = gqa(q, k, v, causal=False, score_dtype=sdt,
                  q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk, unroll=cfg.unroll)
    else:
        q, k, v = attn_qkv(p, cfg, x, x, positions, sh)
        out = gqa(q, k, v, causal=True, window=cfg.sliding_window,
                  score_dtype=sdt,
                  q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk, unroll=cfg.unroll)
    out = out.reshape(*x.shape[:-1], cfg.n_heads * cfg.head_dim_)
    return out @ p["wo"]
