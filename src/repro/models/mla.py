"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries and KV are low-rank compressed; only the latent ``c_kv`` (plus a
shared single-head RoPE key) needs caching at decode time — the KV cache
shrinks by ~an order of magnitude versus GQA.  The decode path uses the
*absorbed* formulation (attention runs directly in latent space) so cached
latents are never re-expanded.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import blocked_attention

NEG_INF = -1e30


def mla_init(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wdq": L.dense_init(ks[0], d, m.q_lora_rank),
        "q_ln": L.rmsnorm_init(m.q_lora_rank),
        "wuq": L.dense_init(ks[1], m.q_lora_rank, h * qk),
        "wdkv": L.dense_init(ks[2], d, m.kv_lora_rank),
        "kv_ln": L.rmsnorm_init(m.kv_lora_rank),
        "wukv": L.dense_init(ks[3], m.kv_lora_rank,
                             h * (m.qk_nope_dim + m.v_head_dim)),
        "wkr": L.dense_init(ks[4], d, m.qk_rope_dim),
        "wo": L.dense_init(ks[5], h * m.v_head_dim, d,
                           scale=(h * m.v_head_dim) ** -0.5),
    }


def mla_latents(p, cfg, x, positions):
    """Compressed latents: c_kv [B,S,R], k_rope [B,S,1,Dr] (RoPE'd)."""
    m = cfg.mla
    c_kv = L.rmsnorm(x @ p["wdkv"], p["kv_ln"], cfg.rms_eps)
    k_rope = (x @ p["wkr"]).reshape(*x.shape[:-1], 1, m.qk_rope_dim)
    cos, sin = L.rope_freqs(m.qk_rope_dim, cfg.rope_theta, positions)
    k_rope = L.apply_rope(k_rope, cos, sin)
    return c_kv, k_rope


def mla_queries(p, cfg, x, positions):
    """q_nope [B,S,H,Dn], q_rope [B,S,H,Dr]."""
    m = cfg.mla
    h = cfg.n_heads
    q = L.rmsnorm(x @ p["wdq"], p["q_ln"], cfg.rms_eps) @ p["wuq"]
    q = q.reshape(*x.shape[:-1], h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    cos, sin = L.rope_freqs(m.qk_rope_dim, cfg.rope_theta, positions)
    q_rope = L.apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_apply(p, cfg, x, positions, sh=None):
    """Training/prefill path: expand latents to per-head K/V, run blocked
    attention on the concatenated (nope | rope) head dims."""
    m = cfg.mla
    h = cfg.n_heads
    q_nope, q_rope = mla_queries(p, cfg, x, positions)
    c_kv, k_rope = mla_latents(p, cfg, x, positions)
    kv = (c_kv @ p["wukv"]).reshape(*x.shape[:-1], h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:-1], m.qk_rope_dim))],
        axis=-1)
    if sh is not None:
        q, k, v = sh.constrain_heads(q), sh.constrain_heads(k), sh.constrain_heads(v)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out = blocked_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True,
                            q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk,
                            scale=scale, unroll=cfg.unroll)
    out = out.transpose(0, 2, 1, 3).reshape(*x.shape[:-1], h * m.v_head_dim)
    return out @ p["wo"]


def mla_decode(p, cfg, x1, positions, ckv_cache, krope_cache, cache_len):
    """Absorbed-matrix decode: attention in latent space.

    x1: [B, 1, D]; ckv_cache: [B, S, R]; krope_cache: [B, S, Dr];
    cache_len i32[B] (length *after* inserting this token's latent).
    Returns ([B, 1, D], updated caches).
    """
    m = cfg.mla
    h = cfg.n_heads
    b = x1.shape[0]
    q_nope, q_rope = mla_queries(p, cfg, x1, positions)      # [B,1,H,*]
    c_kv, k_rope = mla_latents(p, cfg, x1, positions)        # [B,1,R],[B,1,1,Dr]

    idx = cache_len[:, None] - 1
    ckv_cache = jax.vmap(lambda c, i, v: jax.lax.dynamic_update_slice(c, v, (i[0], 0)))(
        ckv_cache, idx, c_kv)
    krope_cache = jax.vmap(lambda c, i, v: jax.lax.dynamic_update_slice(c, v, (i[0], 0)))(
        krope_cache, idx, k_rope[:, :, 0, :])

    # absorb W_uk into the query:  q_lat [B,H,R]
    wukv = p["wukv"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk = wukv[..., :m.qk_nope_dim]                         # [R, H, Dn]
    w_uv = wukv[..., m.qk_nope_dim:]                         # [R, H, Dv]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                         krope_cache.astype(jnp.float32))
    scores *= (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    pos = jnp.arange(ckv_cache.shape[1])[None, None, :]
    scores = jnp.where(pos < cache_len[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x1.dtype)
    return out @ p["wo"], ckv_cache, krope_cache
