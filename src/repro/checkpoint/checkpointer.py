"""Fault-tolerant checkpointing: atomic, keep-k, auto-resume.

Layout:  <dir>/step_<N>/{arrays.npz, meta.json}   (+ step_<N>.tmp during
write, renamed atomically on completion so a crash mid-save never corrupts
the restore path).  ``latest_step`` scans for the newest *complete*
checkpoint, so training loops restart from the last good state after a
node failure — the framework-level counterpart of the transport-level
resilience REPS provides (Fig. 7).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

# dtypes npz can't store natively: persist as a same-width integer view
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, *, extra: dict | None = None,
         keep: int = 3, blocking: bool = True):
    """Atomically persist a pytree of arrays."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        dtypes.append(str(a.dtype))
        if str(a.dtype) in _VIEW_AS:
            a = a.view(_VIEW_AS[str(a.dtype)])
        arrays[f"a{i}"] = a

    def _write():
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(leaves),
                       "dtypes": dtypes, "extra": extra or {}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "meta.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str):
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template):
    """Restore into the structure of ``template`` (shapes must match)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(template)
    if meta["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, template {len(leaves)}")
    new = []
    for i, ref in enumerate(leaves):
        arr = data[f"a{i}"]
        dt = meta.get("dtypes", [None] * len(leaves))[i]
        if dt in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, dt))
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {np.shape(ref)}")
        new.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, new), meta["extra"]


def restore_latest(directory: str, template):
    step = latest_step(directory)
    if step is None:
        return None, None, None
    tree, extra = restore(directory, step, template)
    return step, tree, extra
