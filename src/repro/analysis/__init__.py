"""Static analysis layer: jaxpr auditor + AST contract linter
(DESIGN.md Sec. 10).

Machine-checks the engine's compile-time invariants — dtype compactness,
scatter discipline, donation de-aliasing, no host round-trips in the hot
tick, one-compile-per-grid — plus source-level contracts (kernel
ref/kernel signature parity, seeded randomness, numpy-only Consts
building).  Run the whole battery with::

    python -m repro.analysis

This ``__init__`` stays import-light on purpose: ``engine``/``state``
import :mod:`repro.analysis.trace_guard` for their trace counters, so
pulling the auditor (which imports the netsim) in here would be a cycle.
Import ``repro.analysis.audit`` / ``repro.analysis.lint`` explicitly.
"""

from repro.analysis.trace_guard import TraceCounter, counter, trace_guard

__all__ = ["TraceCounter", "counter", "trace_guard"]
