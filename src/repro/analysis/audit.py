"""Jaxpr auditor: trace the engine (never compile it) and machine-check
its compile-time invariants (DESIGN.md Sec. 10).

For every registered scenario, on each backend, the auditor traces

  * ``state.init`` (the tick-0 build),
  * each of the six tick phases (read off ``Sim.phases`` — the exact
    closures ``engine.build`` composes, so the audit can never drift
    from the real tick),
  * the composed step, and
  * the leap horizon reduction,

then walks the resulting ``ClosedJaxpr``s (recursing into control-flow
and ``pallas_call`` sub-jaxprs) and applies the ``JX00x`` rules from
``analysis/rules.py``: wide-dtype leaks, convert churn, host callbacks,
per-phase scatter/gather budgets.  Donation aliasing (JX004) is checked
eagerly on a real init state — buffer identity, not tracing.  JX006
perturbs every scalar ``SimConfig`` field through ``derive`` and
cross-checks the empirical Dims-impact against ``api.apply_point``'s
accept/reject sets.

Everything here is trace-only: no XLA compile, no device run — auditing
the full catalogue including the 1024-node paper-scale scenarios costs
seconds per scenario, not minutes.
"""

from __future__ import annotations

import dataclasses
import inspect

import jax
import numpy as np

from repro.analysis.rules import (CALLBACK_PRIMITIVES, GATHER_PRIMITIVES,
                                  PHASE_BUDGETS, SCATTER_PRIMITIVES,
                                  WIDE_DTYPES, Finding, finding)

try:  # jax >= 0.4.x
    from jax.extend import core as jex_core
    Jaxpr, ClosedJaxpr = jex_core.Jaxpr, jex_core.ClosedJaxpr
except ImportError:  # pragma: no cover - older jax
    from jax import core as jex_core
    Jaxpr, ClosedJaxpr = jex_core.Jaxpr, jex_core.ClosedJaxpr


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------


def _sub_jaxprs(params: dict):
    """Every Jaxpr/ClosedJaxpr reachable from one eqn's params (cond
    branches arrive as tuples, pallas_call as a bare Jaxpr)."""
    def from_value(v):
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from from_value(item)
    for v in params.values():
        yield from from_value(v)


def walk_eqns(jaxpr):
    """All equations of ``jaxpr``, depth-first through sub-jaxprs."""
    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from walk_eqns(sub)


def _aval(atom):
    return getattr(atom, "aval", None)


@dataclasses.dataclass
class OpStats:
    """Aggregate trace facts of one program (sub-jaxprs included)."""

    eqns: int = 0
    scatter: int = 0
    gather: int = 0
    convert: int = 0
    est_bytes: int = 0            # sum of eqn-output aval bytes: an upper
                                  # bound on un-fused intermediate traffic
    prims: dict = dataclasses.field(default_factory=dict)

    def row(self) -> dict:
        return dict(eqns=self.eqns, scatter_ops=self.scatter,
                    gather_ops=self.gather, convert_ops=self.convert,
                    est_mb=round(self.est_bytes / 1e6, 3))


def op_stats(closed) -> OpStats:
    """Count the op families the budgets and the ledger track."""
    st = OpStats()
    for eqn in walk_eqns(closed):
        name = eqn.primitive.name
        st.eqns += 1
        st.prims[name] = st.prims.get(name, 0) + 1
        if name in SCATTER_PRIMITIVES:
            st.scatter += 1
        elif name in GATHER_PRIMITIVES:
            st.gather += 1
        elif name == "convert_element_type":
            st.convert += 1
        for ov in eqn.outvars:
            aval = _aval(ov)
            if aval is not None and hasattr(aval, "size"):
                st.est_bytes += int(aval.size) * aval.dtype.itemsize
    return st


# --------------------------------------------------------------------------
# JX001 / JX002 / JX003 — per-program jaxpr rules
# --------------------------------------------------------------------------


def _wide_dtype_findings(closed, site: str) -> list:
    """JX001: any 64-bit abstract value, deduped per (dtype, primitive)."""
    seen, out = set(), []
    for eqn in walk_eqns(closed):
        for atom in list(eqn.invars) + list(eqn.outvars):
            aval = _aval(atom)
            dt = str(getattr(aval, "dtype", ""))
            if dt in WIDE_DTYPES:
                token = f"{dt}@{eqn.primitive.name}"
                if token not in seen:
                    seen.add(token)
                    out.append(finding(
                        "JX001", site, token,
                        f"{dt} value at primitive {eqn.primitive.name!r} "
                        "(x32 contract: DESIGN.md Sec. 6)"))
    return out


def _float_kind(dt):
    return dt.kind == "f"


def _chain_redundant(a, b, c) -> bool:
    """Is convert a->b->c (middle used once) collapsible to a->c?

    Conservative: only when dropping b provably preserves values —
    b == c (second hop is a no-op), a round trip back to ``a`` through a
    wider-or-equal middle, or a same-kind widening then anything.
    """
    if b == c:
        return True
    if a.kind == "b":
        return True          # bool carries {0, 1}: any middle is lossless
    same_kind = a.kind == b.kind
    wider = b.itemsize >= a.itemsize
    if same_kind and wider:
        return True          # a -> wider(a) -> c  ==  a -> c
    return False


def _convert_findings(closed, site: str) -> list:
    """JX002: self-converts and collapsible convert chains."""
    out = []
    if isinstance(closed, ClosedJaxpr):
        jaxprs = [closed.jaxpr]
    else:
        jaxprs = [closed]
    # walk each (sub-)jaxpr independently: var identity is scoped
    stack = list(jaxprs)
    while stack:
        jx = stack.pop()
        consumers: dict = {}
        escaping = {id(v) for v in jx.outvars}
        for eqn in jx.eqns:
            for iv in eqn.invars:
                if _aval(iv) is not None and not hasattr(iv, "val"):
                    consumers.setdefault(id(iv), []).append(eqn)
            for sub in _sub_jaxprs(eqn.params):
                stack.append(sub)
        for eqn in jx.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            (iv,), (ov,) = eqn.invars, eqn.outvars
            src_aval, dst_aval = _aval(iv), _aval(ov)
            if src_aval is None or dst_aval is None:
                continue
            src, dst = src_aval.dtype, dst_aval.dtype
            src_weak = bool(getattr(src_aval, "weak_type", False))
            dst_weak = bool(getattr(dst_aval, "weak_type", False))
            if src == dst and src_weak == dst_weak:
                out.append(finding(
                    "JX002", site, f"{src}->{dst}",
                    f"self-convert {src}->{dst} (no-op cast materialized)"))
                continue
            uses = consumers.get(id(ov), [])
            if (id(ov) not in escaping and len(uses) == 1
                    and uses[0].primitive.name == "convert_element_type"):
                final = _aval(uses[0].outvars[0]).dtype
                if _chain_redundant(src, dst, final):
                    out.append(finding(
                        "JX002", site, f"{src}->{dst}->{final}",
                        f"convert chain {src}->{dst}->{final} collapses "
                        f"to {src}->{final}"))
    return out


def _callback_findings(closed, site: str) -> list:
    """JX003: host callback primitives anywhere in the program."""
    out, seen = [], set()
    for eqn in walk_eqns(closed):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMITIVES and name not in seen:
            seen.add(name)
            out.append(finding(
                "JX003", site, name,
                f"host callback {name!r} inside a traced engine program "
                "(serializes the superstep loop on host round-trips)"))
    return out


def check_jaxpr(closed, site: str, budgets: dict | None = None) -> list:
    """All per-program jaxpr rules (JX001/JX002/JX003, and JX005 when a
    ``{"scatter": n, "gather": n}`` budget is supplied)."""
    out = (_wide_dtype_findings(closed, site)
           + _convert_findings(closed, site)
           + _callback_findings(closed, site))
    if budgets:
        st = op_stats(closed)
        for fam, have in (("scatter", st.scatter), ("gather", st.gather)):
            cap = budgets.get(fam)
            if cap is not None and have > cap:
                out.append(finding(
                    "JX005", site, f"{fam}={have}",
                    f"{fam} op count {have} exceeds budget {cap} "
                    "(rules.PHASE_BUDGETS)"))
    return out


# --------------------------------------------------------------------------
# JX004 — donation aliasing (eager; buffer identity, not tracing)
# --------------------------------------------------------------------------


def check_donation(pytree, site: str) -> list:
    """JX004: two leaves of a to-be-donated pytree sharing one buffer."""
    out = []
    leaves_paths = jax.tree_util.tree_flatten_with_path(pytree)[0]
    seen: dict = {}
    for path, leaf in leaves_paths:
        try:
            ptr = leaf.unsafe_buffer_pointer()
        except Exception:   # non-device leaf, or a backend without the API
            continue
        label = jax.tree_util.keystr(path)
        if ptr in seen:
            out.append(finding(
                "JX004", site, label,
                f"donated leaf {label} aliases {seen[ptr]} (one buffer, "
                f"two leaves — use-after-donate under donate_argnums)"))
        else:
            seen[ptr] = label
    return out


# --------------------------------------------------------------------------
# JX006 — SimConfig sweepability classification
# --------------------------------------------------------------------------


def _perturb(value):
    """A nearby-but-different value of the same scalar type."""
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 1
    if isinstance(value, float):
        return value * 1.5 + 0.25
    return None


def _aval_sig(consts):
    leaves, treedef = jax.tree_util.tree_flatten(consts)
    return treedef, [(np.shape(x), np.asarray(x).dtype) for x in leaves]


def classify_config(site: str = "simconfig") -> list:
    """JX006: derive the empirical Dims/aval impact of every scalar
    SimConfig field and cross-check ``api.apply_point``'s sets."""
    from repro.netsim import api, state
    from repro.netsim.scenarios import scenario

    out = []
    sc = scenario("tiny_3t")
    base_cfg = sc.cfg
    _, _, dims0, consts0 = state.derive(base_cfg, sc.wl)
    sig0 = _aval_sig(consts0)

    for field in dataclasses.fields(state.SimConfig):
        name = field.name
        value = getattr(base_cfg, name)
        new = _perturb(value)
        if new is None:
            # structural field (configs, strings, tuples): must be
            # rejected by apply_point -> STATIC_KEYS, or a backend
            # selector is silently unclassified
            if name not in api.STATIC_KEYS:
                out.append(finding(
                    "JX006", site, name,
                    f"structural field {name!r} is not in api.STATIC_KEYS "
                    f"— apply_point rejects it only via the generic "
                    f"unknown-key branch, with a misleading message"))
            continue
        try:
            _, _, dims2, consts2 = state.derive(
                dataclasses.replace(base_cfg, **{name: new}), sc.wl)
        except Exception as e:    # perturbation hit a validation wall
            out.append(finding(
                "JX006", site, name,
                f"cannot classify {name!r}: derive({value!r}->{new!r}) "
                f"raised {type(e).__name__}: {e}"))
            continue
        retraces = (dims2 != dims0) or (_aval_sig(consts2) != sig0)
        if retraces and name in api.CFG_KEYS:
            out.append(finding(
                "JX006", site, name,
                f"field {name!r} is listed sweepable (CFG_KEYS) but "
                f"changing it retraces (Dims or Consts avals change)"))
        if retraces and name not in api.STATIC_KEYS:
            out.append(finding(
                "JX006", site, name,
                f"field {name!r} changes Dims/avals but is not in "
                f"api.STATIC_KEYS — apply_point would not name it as "
                f"Dims-changing"))
        if not retraces and name not in (api.CFG_KEYS | api.STATIC_KEYS):
            out.append(finding(
                "JX006", site, name,
                f"field {name!r} is unclassified: neither sweepable "
                f"(CFG_KEYS) nor static (STATIC_KEYS)"))

    # apply_point must actually reject every static key...
    for key in sorted(api.STATIC_KEYS):
        try:
            api.apply_point(base_cfg, {key: getattr(base_cfg, key, 0)})
        except KeyError:
            pass
        else:
            out.append(finding(
                "JX006", site, key,
                f"api.apply_point accepted static key {key!r}"))
    # ...and every CC tuning key must exist on make_cc_params
    from repro.core.types import make_cc_params
    params = set(inspect.signature(make_cc_params).parameters)
    for key in sorted(api.CC_PARAM_KEYS - params):
        out.append(finding(
            "JX006", site, key,
            f"CC_PARAM_KEYS entry {key!r} is not a make_cc_params kwarg"))
    return out


# --------------------------------------------------------------------------
# scenario audits
# --------------------------------------------------------------------------


def _backend_cfg(cfg, backend: str):
    """The scenario's config with all hot-loop backends set to
    ``backend`` (CC falls back to jnp where no pallas kernel exists)."""
    from repro.core import registry
    cc = backend if (backend == "jnp"
                     or cfg.algo in registry.PALLAS_ALGORITHMS) else "jnp"
    return dataclasses.replace(cfg, cc_backend=cc, fabric_backend=backend,
                               transport_backend=backend)


def audit_scenario(sc, backends=("jnp", "pallas"), per_phase: bool = True):
    """Trace and rule-check one scenario on each backend.

    Returns ``(findings, rows)``: findings from JX001/002/003/005 over
    init, the six phases, the step, and the horizon; plus JX004 on a
    real init state.  ``rows`` are analysis-ledger rows (op counts and
    bytes per program).
    """
    from repro.netsim import engine

    findings: list[Finding] = []
    rows: list[dict] = []
    for backend in backends:
        sim = engine.build(_backend_cfg(sc.cfg, backend), sc.wl)
        site_base = f"{sc.name}/{backend}"
        st_struct = jax.eval_shape(sim.init)
        consts = sim.consts

        programs = {"init": jax.make_jaxpr(sim.init)()}
        for pname, pfn in sim.phases:
            programs[pname] = jax.make_jaxpr(
                lambda s, _f=pfn: _f(consts, s))(st_struct)
        programs["step"] = jax.make_jaxpr(sim.step)(st_struct)
        programs["horizon"] = jax.make_jaxpr(sim.horizon)(st_struct)

        for pname, closed in programs.items():
            site = f"{site_base}/{pname}"
            budgets = (PHASE_BUDGETS.get(pname)
                       if backend == "jnp" else None)
            findings.extend(check_jaxpr(closed, site, budgets=budgets))
            if per_phase or pname == "step":
                stats = op_stats(closed)
                rows.append(dict(name=site, scenario=sc.name,
                                 backend=backend, program=pname,
                                 **stats.row()))
    # donation aliasing: one eager init state (backend-independent)
    findings.extend(check_donation(
        engine.build(sc.cfg, sc.wl).init(), f"{sc.name}/init"))
    return findings, rows


# per-phase ledger rows are recorded for these scenarios (the tiered
# paper-scale set); everything else contributes step-level rows only,
# keeping the analysis section a few hundred rows, not thousands
PER_PHASE_SCENARIOS = ("tiny_3t", "perm_512n_3t", "perm_1024n_3t")


def audit_catalogue(names=None, backends=("jnp", "pallas"),
                    progress=None):
    """Audit every registered scenario (aliases deduped) + JX006.

    Returns ``(findings, rows)`` over the whole catalogue.
    """
    from repro.netsim import scenarios

    if names is None:
        names = scenarios.names()
    seen, resolved = set(), []
    for name in names:
        sc = scenarios.scenario(name)
        if sc.name not in seen:      # aliases resolve to one canonical name
            seen.add(sc.name)
            resolved.append(sc)

    findings, rows = [], []
    for sc in resolved:
        if progress:
            progress(sc.name)
        f, r = audit_scenario(sc, backends=backends,
                              per_phase=sc.name in PER_PHASE_SCENARIOS)
        findings.extend(f)
        rows.extend(r)
    findings.extend(classify_config())
    return findings, rows
