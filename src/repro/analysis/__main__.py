"""CLI: run the jaxpr auditor + AST contract linter over the repository.

``python -m repro.analysis`` audits every registered scenario on both
backends (trace-only — no XLA compile), lints the source contracts, and
prints every finding.  Allowlisted findings (``rules.ALLOWLIST``) are
reported with their justification but do not fail the run; any
unallowlisted finding exits nonzero, which is the CI gate.

With ``--json-path`` the op-count/bytes rows and per-rule summaries land
in the ``analysis`` section of the benchmark ledger (via
``benchmarks.common.write_bench_json``), where CI compares them against
the committed ledger::

  python -m repro.analysis --json-path analysis_fresh.json
  python -m benchmarks.check_regression --fresh analysis_fresh.json \\
      --ledger BENCH_netsim.json --section analysis \\
      --metric scatter_ops --direction down --threshold 0.0 \\
      --require perm_512n_3t/jnp

Usage:
  PYTHONPATH=src python -m repro.analysis [--audit-only | --lint-only]
      [--scenarios a,b,...] [--backends jnp,pallas] [--quick]
      [--json-path PATH] [--list-rules]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.analysis import audit, lint, rules

QUICK_SCENARIOS = ("tiny_3t", "tiny_perm4", "tiny_incast3")


def _rule_rows(findings) -> list:
    """Per-rule ledger summary rows (``findings`` is the gated metric)."""
    by_rule: dict = {r: [0, 0] for r in rules.RULES}
    for f in findings:
        row = by_rule.setdefault(f.rule, [0, 0])
        row[0] += 1
        if f.allowlisted:
            row[1] += 1
    return [dict(name=f"rule/{rid}", rule=rid, findings=n,
                 allowlisted=n_allowed, unallowlisted=n - n_allowed,
                 description=rules.RULES.get(rid, ""))
            for rid, (n, n_allowed) in sorted(by_rule.items())]


def _write_ledger(rows, path, meta) -> str:
    """The ``analysis`` section, through the shared ledger writer when
    the benchmarks package is importable (repo-root cwd), else a plain
    single-section document at ``path``."""
    try:
        from benchmarks.common import write_bench_json
    except ImportError:
        doc = {"schema": 1,
               "sections": {"analysis": {"meta": meta, "rows": rows}}}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        return path
    return write_bench_json("analysis", rows, path=path, meta=meta)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr auditor + AST contract linter (DESIGN.md "
                    "Sec. 10)")
    p.add_argument("--scenarios", default=None, metavar="A,B",
                   help="comma-separated scenario names (default: the "
                        "whole registry, aliases deduped)")
    p.add_argument("--backends", default="jnp,pallas", metavar="B,B")
    p.add_argument("--quick", action="store_true",
                   help=f"audit only {', '.join(QUICK_SCENARIOS)}")
    p.add_argument("--audit-only", action="store_true")
    p.add_argument("--lint-only", action="store_true")
    p.add_argument("--json-path", default=None, metavar="PATH",
                   help="write the 'analysis' ledger section here "
                        "(BENCH_netsim.json to update the committed one)")
    p.add_argument("--list-rules", action="store_true")
    p.add_argument("--show-allowlisted", action="store_true",
                   help="print allowlisted findings too (always counted)")
    args = p.parse_args(argv)

    if args.list_rules:
        for rid, desc in sorted(rules.RULES.items()):
            print(f"{rid}  {desc}")
        return 0

    t0 = time.time()
    findings, rows = [], []

    if not args.audit_only:
        findings.extend(lint.lint_repo())

    if not args.lint_only:
        names = (args.scenarios.split(",") if args.scenarios
                 else QUICK_SCENARIOS if args.quick else None)
        backends = tuple(b for b in args.backends.split(",") if b)
        f, r = audit.audit_catalogue(
            names=names, backends=backends,
            progress=lambda n: print(f"# auditing {n}", flush=True))
        findings.extend(f)
        rows.extend(r)

    bad = [f for f in findings if not f.allowlisted]
    allowed = [f for f in findings if f.allowlisted]
    for f in bad:
        print(f"FAIL {f}")
    for f in allowed:
        if args.show_allowlisted:
            print(f"ok   {f}")

    if args.json_path:
        import jax
        meta = dict(jax=jax.__version__,
                    findings=len(findings), allowlisted=len(allowed),
                    unallowlisted=len(bad),
                    wall_s=round(time.time() - t0, 1))
        path = _write_ledger(rows + _rule_rows(findings),
                             args.json_path, meta)
        print(f"# {len(rows)} op-count rows + "
              f"{len(rules.RULES)} rule rows -> {path}")

    print(f"# {len(findings)} finding(s): {len(bad)} unallowlisted, "
          f"{len(allowed)} allowlisted intentional "
          f"({time.time() - t0:.1f}s)")
    if bad:
        print("# FAILED: fix the findings above or allowlist them in "
              "src/repro/analysis/rules.py with a justification")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
