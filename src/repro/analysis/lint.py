"""AST contract linter: source-level invariants the jaxpr auditor cannot
see (DESIGN.md Sec. 10).

Stdlib ``ast`` only — no third-party linter dependency.  Four contract
families (rule docs in ``analysis/rules.py``):

  JX101  ``kernels/*/ref.py`` vs ``kernel.py`` signature parity — the
         ``ops.py`` dispatchers assume the pair is call-compatible.
  JX102  ledger rows in ``BENCH_netsim.json`` must reference registered
         scenario names (the registry doubles as the ledger key space).
  JX103  no unseeded legacy ``np.random.*`` calls in simulator code.
  JX104  no Python truthiness on traced values in the tick phase
         modules.
  JX105  no ``jax``/``jax.numpy`` in the host-side Consts-building
         modules (the traced trio in ``faults.py`` is exempt).

Suppress a line-anchored finding with ``# noqa: JX1xx`` (or a bare
``# noqa``); intentional cross-file deviations go in
``rules.ALLOWLIST`` instead.
"""

from __future__ import annotations

import ast
import json
import re
from pathlib import Path

from repro.analysis.rules import Finding, finding

REPO_ROOT = Path(__file__).resolve().parents[3]

# the six-phase tick modules: everything traced, truthiness is a bug
PHASE_MODULES = ("src/repro/netsim/fabric.py",
                 "src/repro/netsim/transport.py",
                 "src/repro/netsim/sender.py",
                 "src/repro/netsim/metrics.py")

# host-side Consts-building modules: numpy-only by design (device math
# here would run per sweep point, defeating the traced-Consts design)
HOST_MODULES = ("src/repro/netsim/topology.py",
                "src/repro/netsim/units.py",
                "src/repro/netsim/workloads.py",
                "src/repro/netsim/collectives.py",
                "src/repro/netsim/scenarios.py")
# faults.py is split: tables build on host, but these three are traced
# per tick by the fabric and legitimately use jnp
HOST_SPLIT_MODULES = {
    "src/repro/netsim/faults.py":
        ("port_period", "fault_active", "transition_horizon"),
}

# modules where unseeded randomness would silently decorrelate runs
RANDOM_SCOPE = ("src/repro",)

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?",
                      re.IGNORECASE)


def _noqa(source: str) -> dict:
    """line number -> set of suppressed rule ids ({'*'} for bare noqa)."""
    out = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _NOQA_RE.search(line)
        if m:
            codes = m.group("codes")
            out[i] = ({c.strip().upper() for c in codes.split(",")}
                      if codes else {"*"})
    return out


def _suppressed(noqa: dict, line: int, rule: str) -> bool:
    codes = noqa.get(line, ())
    return "*" in codes or rule in codes


def _parse(path: Path):
    source = path.read_text()
    return ast.parse(source, filename=str(path)), _noqa(source)


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


# --------------------------------------------------------------------------
# JX101 — kernel trio signature parity
# --------------------------------------------------------------------------


def _public_functions(tree: ast.Module) -> dict:
    return {node.name: node for node in tree.body
            if isinstance(node, ast.FunctionDef)
            and not node.name.startswith("_")}


def _positional(fn: ast.FunctionDef) -> tuple:
    """Positional parameter names (kw-only params — block sizes,
    ``interpret`` flags — are dispatch detail, not call contract)."""
    args = fn.args
    return tuple(a.arg for a in (args.posonlyargs + args.args))


def _pair_kernels(refs: dict, kernels: dict) -> list:
    """Match ref entry points to kernel entry points: by ``_ref`` suffix
    first, else the sole-public-function convention."""
    pairs = []
    for rname, rfn in refs.items():
        base = rname[:-4] if rname.endswith("_ref") else rname
        for kname in (base, base + "_kernel"):
            if kname in kernels:
                pairs.append((rfn, kernels[kname]))
                break
    if not pairs and len(refs) == 1 and len(kernels) == 1:
        pairs.append((next(iter(refs.values())),
                      next(iter(kernels.values()))))
    return pairs


def check_kernel_parity(kernels_dir: Path | None = None) -> list:
    """JX101 over every ``kernels/<name>/`` trio directory."""
    if kernels_dir is None:
        kernels_dir = REPO_ROOT / "src" / "repro" / "kernels"
    out = []
    for kdir in sorted(p for p in kernels_dir.iterdir() if p.is_dir()):
        ref_py, kernel_py = kdir / "ref.py", kdir / "kernel.py"
        if not (ref_py.exists() and kernel_py.exists()):
            continue
        site = f"kernels/{kdir.name}"
        refs = _public_functions(_parse(ref_py)[0])
        kernels = _public_functions(_parse(kernel_py)[0])
        pairs = _pair_kernels(refs, kernels)
        if not pairs:
            out.append(finding(
                "JX101", site, "unpaired",
                "no ref/kernel entry-point pairing found "
                f"(ref: {sorted(refs)}, kernel: {sorted(kernels)})"))
            continue
        for rfn, kfn in pairs:
            rp, kp = _positional(rfn), _positional(kfn)
            kw = {a.arg for a in kfn.args.kwonlyargs}
            # contract: the pair agrees on the positional prefix; a
            # ref's trailing positionals may become kernel kw-only
            # statics (block shapes, capacities), and the kernel may
            # append defaulted positionals — either direction is a
            # call-compatible refinement, anything else is drift
            shared = min(len(rp), len(kp))
            prefix_ok = rp[:shared] == kp[:shared]
            tail_ok = set(rp[shared:]) <= kw or not rp[shared:]
            if not (prefix_ok and tail_ok):
                out.append(finding(
                    "JX101", site, f"{rfn.name}|{kfn.name}",
                    f"signature drift: {rfn.name}{rp} vs "
                    f"{kfn.name}{kp} (ops.py dispatches blind)"))
    return out


# --------------------------------------------------------------------------
# JX102 — ledger keys reference registered scenarios
# --------------------------------------------------------------------------

# sections whose row names are `scenario/...` when no explicit
# ``scenario`` field is present; other sections are skipped
_NAME_PREFIX_SECTIONS = ("perf", "studies", "studies_quick", "failover",
                         "phase_profile", "study_throughput", "collectives")


def check_ledger_keys(bench_json: Path | None = None) -> list:
    """JX102: every ledger row's scenario must be in the registry."""
    from repro.netsim import scenarios

    if bench_json is None:
        bench_json = REPO_ROOT / "BENCH_netsim.json"
    if not bench_json.exists():
        return []
    registered = set(scenarios.names())
    # aliases resolve; also accept the canonical names they map to
    out, seen = [], set()
    data = json.loads(bench_json.read_text())
    for section, body in data.get("sections", {}).items():
        for row in body.get("rows", []):
            cand = row.get("scenario")
            if cand is None:
                if section not in _NAME_PREFIX_SECTIONS:
                    continue
                cand = str(row.get("name", "")).split("/", 1)[0]
            # strip variant ("+recovery") and algo ("scenario/algo")
            # decorations some sections fold into the scenario key
            cand = cand.split("+", 1)[0].split("/", 1)[0]
            if not cand or cand in registered or cand in seen:
                continue
            seen.add(cand)
            out.append(finding(
                "JX102", f"BENCH_netsim.json:{section}", cand,
                f"ledger section {section!r} references scenario "
                f"{cand!r}, which is not in the scenario registry"))
    return out


# --------------------------------------------------------------------------
# JX103 — unseeded legacy np.random
# --------------------------------------------------------------------------

_SEEDED_RANDOM_OK = {"default_rng", "Generator", "SeedSequence",
                     "PCG64", "Philox"}


def _attr_chain(node) -> list:
    """``a.b.c`` -> ["a", "b", "c"] (empty when not a pure chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def check_random(path: Path) -> list:
    """JX103 over one file."""
    tree, noqa = _parse(path)
    rel, out = _rel(path), []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if (len(chain) >= 3 and chain[0] in ("np", "numpy")
                and chain[1] == "random"
                and chain[2] not in _SEEDED_RANDOM_OK
                and not _suppressed(noqa, node.lineno, "JX103")):
            out.append(finding(
                "JX103", f"{rel}:{node.lineno}", ".".join(chain),
                f"unseeded legacy {'.'.join(chain)}() — use a seeded "
                "np.random.default_rng(seed) generator"))
    return out


# --------------------------------------------------------------------------
# JX104 — truthiness on traced values in phase modules
# --------------------------------------------------------------------------

# names bound to traced values in phase-function signatures; ``dims`` is
# deliberately absent (static Python scalars — branching on it is the
# intended specialization mechanism)
_TRACED_ROOTS = {"st", "state", "consts"}


def _mentions_traced(expr: ast.AST) -> str | None:
    """The first traced-value mention inside ``expr``, or None."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if chain and chain[0] in _TRACED_ROOTS:
                return ".".join(chain)
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain and chain[0] == "jnp":
                return ".".join(chain) + "(...)"
    return None


def check_truthiness(path: Path) -> list:
    """JX104 over one phase module."""
    tree, noqa = _parse(path)
    rel, out = _rel(path), []
    tests = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.If, ast.While)):
            tests.append(node.test)
        elif isinstance(node, ast.Assert):
            tests.append(node.test)
        elif isinstance(node, ast.IfExp):
            tests.append(node.test)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Name)
              and node.func.id == "bool" and node.args):
            tests.append(node.args[0])
    for test in tests:
        hit = _mentions_traced(test)
        if hit and not _suppressed(noqa, test.lineno, "JX104"):
            out.append(finding(
                "JX104", f"{rel}:{test.lineno}", hit,
                f"Python truthiness on traced value {hit} — this either "
                "raises TracerBoolConversionError or freezes a branch "
                "at trace time; use lax.cond/jnp.where"))
    return out


# --------------------------------------------------------------------------
# JX105 — host-path purity
# --------------------------------------------------------------------------


def _function_ranges(tree: ast.Module) -> list:
    """[(name, first_line, last_line)] for every top-level function."""
    out = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append((node.name, node.lineno, node.end_lineno))
    return out


def check_host_purity(path: Path, traced_functions=()) -> list:
    """JX105 over one host module; ``traced_functions`` are exempt."""
    tree, noqa = _parse(path)
    rel, out = _rel(path), []
    ranges = [(n, lo, hi) for n, lo, hi in _function_ranges(tree)
              if n in traced_functions]

    def in_traced(line: int) -> bool:
        return any(lo <= line <= hi for _, lo, hi in ranges)

    seen_lines = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Name) and node.id in ("jnp", "jax")
                and isinstance(node.ctx, ast.Load)
                and not in_traced(node.lineno)
                and node.lineno not in seen_lines
                and not _suppressed(noqa, node.lineno, "JX105")):
            seen_lines.add(node.lineno)
            out.append(finding(
                "JX105", f"{rel}:{node.lineno}", node.id,
                f"{node.id} used in host-side Consts-building module — "
                "these paths run per sweep point and must stay numpy"))
    return out


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------


def lint_repo(root: Path | None = None) -> list:
    """Run the full JX1xx contract suite over the repository."""
    root = Path(root) if root else REPO_ROOT
    out: list[Finding] = []
    out.extend(check_kernel_parity(root / "src" / "repro" / "kernels"))
    out.extend(check_ledger_keys(root / "BENCH_netsim.json"))
    for scope in RANDOM_SCOPE:
        for path in sorted((root / scope).rglob("*.py")):
            out.extend(check_random(path))
    for mod in PHASE_MODULES:
        out.extend(check_truthiness(root / mod))
    for mod in HOST_MODULES:
        out.extend(check_host_purity(root / mod))
    for mod, traced in HOST_SPLIT_MODULES.items():
        out.extend(check_host_purity(root / mod, traced_functions=traced))
    return out
