"""Rule catalogue for the static-analysis layer (DESIGN.md Sec. 10).

Two rule families share one :class:`Finding` currency and one allowlist:

``JX0xx`` — *jaxpr rules*, applied by ``analysis/audit.py`` to traced
(never compiled) engine programs:

  JX001  64-bit leak: a float64/int64/uint64 abstract value inside a
         traced engine program.  The simulator is an x32 program by
         contract (DESIGN.md Sec. 6); any wide dtype doubles memory
         traffic on the hot tick and silently changes CC arithmetic.
  JX002  convert churn: a ``convert_element_type`` whose output feeds
         only another ``convert_element_type`` (an A->B->C chain whose
         middle dtype is never used), or one that converts a value to
         its own dtype.  Either way XLA materializes a useless pass.
  JX003  host callback: ``pure_callback`` / ``io_callback`` /
         ``debug_callback`` inside the step or init.  A callback inside
         the tick serializes the superstep loop on host round-trips.
  JX004  aliased donation: two leaves of a donated pytree share one
         buffer.  ``donate_argnums`` hands each buffer to XLA exactly
         once; an aliased leaf is a use-after-donate.
  JX005  scatter/gather budget: a tick phase exceeds its budgeted
         scatter/gather op count (:data:`PHASE_BUDGETS`).  Scatter count
         is the tick's dominant cost at paper scale (DESIGN.md Sec.
         6.4); a silent regression here is a perf bug.
  JX006  retrace guard: the empirically Dims-changing ``SimConfig``
         fields must be rejected by ``api.apply_point`` (i.e. disjoint
         from ``api.CFG_KEYS``), every ``CFG_KEYS`` field must be
         sweep-safe (same Dims, same Consts avals), and every config
         field must be classified at all.

``JX1xx`` — *AST contract rules*, applied by ``analysis/lint.py`` to
source files (stdlib ``ast``; suppress a line with ``# noqa: JX1xx``):

  JX101  kernel trio parity: ``kernels/*/ref.py`` and ``kernel.py``
         public entry points must agree on positional parameter names
         and order (``ops.py`` dispatches between them blind).
  JX102  ledger key drift: a ``BENCH_netsim.json`` row references a
         scenario name that is not in the scenario registry.
  JX103  unseeded randomness: legacy ``np.random.*`` module calls in
         simulator code (only seeded ``np.random.default_rng`` is
         reproducible across processes).
  JX104  traced truthiness: Python ``if``/``while``/``assert``/bool()
         on ``SimState``/``Consts`` values inside a tick phase module —
         a guaranteed ``TracerBoolConversionError`` at trace time, or
         worse, a silently config-frozen branch.
  JX105  host-path purity: ``jax.numpy`` use in the host-side
         Consts-building modules (topology/units/workloads/scenarios
         and the host half of faults.py).  Those paths run per sweep
         point; device math there re-introduces the per-point dispatch
         cost the Consts design exists to avoid.

Intentional deviations are allowlisted in :data:`ALLOWLIST`, keyed
``"RULE:site:token"`` (``fnmatch`` patterns) -> one-line justification.
An allowlisted finding is reported (with its justification) but does not
fail ``python -m repro.analysis``.
"""

from __future__ import annotations

import dataclasses
from fnmatch import fnmatch

# --------------------------------------------------------------------------
# findings
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site.

    ``site``  where: ``scenario/backend/phase`` for jaxpr rules,
              ``path:line`` for lint rules, ``kernels/<name>`` for
              kernel-parity.
    ``token`` the specific offender (a dtype, a primitive, a parameter
              list, a scenario key) — the allowlist matches on it.
    """

    rule: str
    site: str
    token: str
    message: str
    allowed_by: str | None = None   # matching ALLOWLIST key, if any

    @property
    def allowlisted(self) -> bool:
        return self.allowed_by is not None

    def __str__(self) -> str:
        tag = f" [allowed: {ALLOWLIST[self.allowed_by]}]" \
            if self.allowlisted else ""
        return f"{self.rule} {self.site} :: {self.message}{tag}"


RULES = {
    "JX001": "64-bit dtype inside a traced engine program",
    "JX002": "redundant convert_element_type (chain or self-convert)",
    "JX003": "host callback primitive inside step/init",
    "JX004": "aliased leaves in a donated pytree",
    "JX005": "per-phase scatter/gather op count over budget",
    "JX006": "SimConfig sweepability classification drift",
    "JX101": "kernel ref/kernel signature parity",
    "JX102": "ledger row references an unregistered scenario",
    "JX103": "unseeded legacy np.random call",
    "JX104": "Python truthiness on traced state in a phase module",
    "JX105": "jax.numpy use in a host-side Consts-building path",
}


# --------------------------------------------------------------------------
# allowlist — every entry is an *intentional* deviation with a reason
# --------------------------------------------------------------------------

ALLOWLIST: dict[str, str] = {
    # cc_update's kernel takes `now` right after the param vector so the
    # scalar-prefetch operands are contiguous; ops.py adapts the order.
    "JX101:kernels/cc_update:*":
        "kernel hoists `now` next to param_vec for scalar prefetch; "
        "ops.py owns the adaptation",
    # perm_32n_flat is built inline by benchmarks/profile_tick.py (the
    # N=32 profiling point below the smallest registered 3-tier tree).
    "JX102:*:perm_32n_flat":
        "ad-hoc profiling scenario built in benchmarks/profile_tick.py",
}


def allowed_by(rule: str, site: str, token: str) -> str | None:
    """The first ALLOWLIST key matching (rule, site, token), else None."""
    for key in ALLOWLIST:
        krule, ksite, ktoken = key.split(":", 2)
        if krule == rule and fnmatch(site, ksite) and fnmatch(token, ktoken):
            return key
    return None


def finding(rule: str, site: str, token: str, message: str) -> Finding:
    """Build a Finding, resolving its allowlist status."""
    return Finding(rule=rule, site=site, token=token, message=message,
                   allowed_by=allowed_by(rule, site, token))


# --------------------------------------------------------------------------
# jaxpr rule constants
# --------------------------------------------------------------------------

WIDE_DTYPES = ("float64", "int64", "uint64", "complex128")

CALLBACK_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback")

SCATTER_PRIMITIVES = ("scatter", "scatter-add", "scatter_add",
                      "scatter-mul", "scatter_mul", "scatter-min",
                      "scatter_min", "scatter-max", "scatter_max",
                      "scatter-apply", "scatter_apply",
                      "dynamic_update_slice")

GATHER_PRIMITIVES = ("gather", "dynamic_slice")


# --------------------------------------------------------------------------
# JX005 scatter/gather budgets
# --------------------------------------------------------------------------
#
# Budgets are per (phase, op family) *trace-time op counts* on the jnp
# backend, scenario-independent (op count is shape-independent; only
# Dims branches change it, and the audit covers every registered
# scenario, so the widest branch set is exercised).  Measured maxima
# across the catalogue at PR 9 (departures 4/3, arrivals 7/12, control
# 4/20 — the fault/sparse scenarios' table lookups dominate — grants
# 2/2 with a credit-based CC, sends 2/10, metrics 0/0, horizon 0/4)
# plus ~25% headroom: a breach means someone added
# scatters to a hot phase, which is exactly the regression this rule
# exists to catch.  Raise a budget deliberately — with a ledger diff —
# not by accident.

PHASE_BUDGETS: dict[str, dict[str, int]] = {
    "departures": {"scatter": 5, "gather": 4},
    "arrivals":   {"scatter": 9, "gather": 15},
    "control":    {"scatter": 6, "gather": 25},
    "grants":     {"scatter": 4, "gather": 4},
    "sends":      {"scatter": 3, "gather": 13},
    "metrics":    {"scatter": 1, "gather": 3},
    "horizon":    {"scatter": 1, "gather": 5},
}
