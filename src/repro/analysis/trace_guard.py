"""Named trace counters + the ``trace_guard`` context manager.

The engine's one-compile contracts ("a whole parameter grid costs exactly
one step trace", "``run_batch`` builds one init state and broadcasts it")
used to be enforced through ad-hoc module-level mutable lists
(``engine.STEP_TRACE_COUNT``, ``state.INIT_TRACE_COUNT``) that every test
snapshotted by hand.  This module replaces them with one mechanism:

* :func:`counter` returns a process-global named :class:`TraceCounter`;
  the *traced* code path calls ``.hit()`` once per trace (the call sits
  inside the traced function body, so it runs at trace time only — a
  compiled execution never re-enters Python).
* :class:`trace_guard` is a context manager that snapshots a counter on
  entry and exposes the delta as ``.count``; with ``expect=`` it raises
  ``AssertionError`` on exit when the block traced a different number of
  times::

      with trace_guard("engine.step", expect=1):
          study.run()            # the whole grid must cost ONE step trace

The jaxpr auditor (``repro.analysis.audit``) uses the same guard to
machine-check the retrace contract: folding any ``api.CFG_KEYS`` sweep
point into a config must reuse the compiled step.

This module is dependency-free (no jax, no netsim imports) so the engine
can import it without cycles.
"""

from __future__ import annotations


class TraceCounter:
    """A process-global named counter; ``hit()`` from inside the traced
    function body counts traces, not executions."""

    __slots__ = ("name", "count")

    def __init__(self, name: str):
        self.name = name
        self.count = 0

    def hit(self) -> None:
        self.count += 1

    def __repr__(self) -> str:
        return f"TraceCounter({self.name!r}, count={self.count})"


_COUNTERS: dict[str, TraceCounter] = {}


def counter(name: str) -> TraceCounter:
    """Get-or-create the global counter ``name`` (e.g. ``"engine.step"``)."""
    c = _COUNTERS.get(name)
    if c is None:
        c = _COUNTERS[name] = TraceCounter(name)
    return c


class trace_guard:
    """Snapshot counter ``name`` for a ``with`` block.

    ``.count`` is the number of traces since entry; ``expect=`` turns the
    guard into an assertion (checked on clean exit only — an exception
    inside the block propagates untouched)::

        with trace_guard("engine.step") as g:
            sweep.run()
        assert g.count == 1           # or: trace_guard(..., expect=1)
    """

    def __init__(self, name: str, expect: int | None = None):
        self._counter = counter(name)
        self._start = self._counter.count
        self.expect = expect

    def __enter__(self) -> "trace_guard":
        self._start = self._counter.count
        return self

    @property
    def count(self) -> int:
        return self._counter.count - self._start

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None and self.expect is not None \
                and self.count != self.expect:
            raise AssertionError(
                f"trace_guard({self._counter.name!r}): expected "
                f"{self.expect} trace(s) inside the block, saw {self.count}")
        return False
