"""Training loop with checkpoint/restart fault tolerance.

Restart semantics: on start, the loop resumes from the newest complete
checkpoint (params + optimizer + data-iterator state), so a preempted or
crashed job continues exactly where it left off — combined with the atomic
checkpointer this survives kill -9 at any point.
"""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.train.step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 2
    seed: int = 0


def train(model_cfg, tcfg: TrainConfig, lcfg: LoopConfig, dcfg: DataConfig,
          sh=None, log=print):
    key = jax.random.key(lcfg.seed)
    params = lm.init_params(model_cfg, key)
    from repro.optim import adamw
    opt = adamw.init(tcfg.adam, params)
    data = SyntheticLM(dcfg)
    start_step = 0

    if lcfg.ckpt_dir:
        step0, tree, extra = ckpt.restore_latest(lcfg.ckpt_dir, (params, opt))
        if step0 is not None:
            params, opt = tree
            data.restore(extra["data"])
            start_step = step0
            log(f"[resume] restored step {step0}")

    step_fn = jax.jit(make_train_step(model_cfg, tcfg, sh),
                      donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    tokens_per_step = dcfg.global_batch * dcfg.seq_len
    for step in range(start_step, lcfg.steps):
        batch = next(data)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, opt, stats = step_fn(params, opt, batch)
        loss = float(stats["loss"])
        losses.append(loss)
        if (step + 1) % lcfg.log_every == 0:
            dt = time.time() - t0
            tps = tokens_per_step * lcfg.log_every / max(dt, 1e-9)
            log(f"step {step+1:5d} loss {loss:.4f} "
                f"gnorm {float(stats['grad_norm']):.3f} "
                f"lr {float(stats['lr']):.2e} tok/s {tps:,.0f}")
            t0 = time.time()
        if lcfg.ckpt_dir and (step + 1) % lcfg.ckpt_every == 0:
            ckpt.save(lcfg.ckpt_dir, step + 1, (params, opt),
                      extra={"data": data.state()}, keep=lcfg.keep)
    if lcfg.ckpt_dir:
        ckpt.save(lcfg.ckpt_dir, lcfg.steps, (params, opt),
                  extra={"data": data.state()}, keep=lcfg.keep)
    return params, opt, losses
