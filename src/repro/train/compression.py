"""Gradient compression: int8 block-quantized all-reduce with error
feedback.

Wire cost per gradient element: 2 bytes (reduce-scatter of int8 chunks via
all_to_all + all_gather of the int8 result) versus 8 bytes for a ring
all-reduce in f32 — a 4x reduction of the DP collective, which is exactly
the traffic the paper's transport carries (bulk-synchronous all-reduce,
Sec. 1).  Error feedback carries the quantization residual into the next
step, preserving convergence (1-bit-Adam-style).

Implemented with ``shard_map`` over the data axis; validated in
``tests/test_compression.py`` on a fake 8-device mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x, block: int = BLOCK):
    """f32[N] (N % block == 0) -> (int8[N], f32[N/block] scales)."""
    xb = x.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize(q, scale, block: int = BLOCK):
    return (q.astype(jnp.float32).reshape(-1, block)
            * scale[:, None]).reshape(-1)


def compressed_psum_mean(g, err, axis_name: str, world: int):
    """Inside shard_map: mean-all-reduce g (f32[N]) in int8.

    Returns (g_mean f32[N], new_err f32[N]).  N must be divisible by
    world * BLOCK.
    """
    g_fb = g + err                      # error feedback
    q, scale = quantize(g_fb)
    residual = g_fb - dequantize(q, scale)

    # reduce-scatter: exchange int8 chunks, each rank sums its chunk
    n = g.shape[0]
    chunk = n // world
    qs = q.reshape(world, chunk)
    ss = scale.reshape(world, chunk // BLOCK)
    q_x = jax.lax.all_to_all(qs, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)          # [world, chunk] others' data
    s_x = jax.lax.all_to_all(ss, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)
    part = jnp.sum(jax.vmap(dequantize)(q_x, s_x), axis=0) / world  # f32[chunk]

    # all-gather the (re-quantized) reduced chunks
    pq, pscale = quantize(part)
    res2 = part - dequantize(pq, pscale)
    gq = jax.lax.all_gather(pq, axis_name)          # [world, chunk] int8
    gs = jax.lax.all_gather(pscale, axis_name)
    out = jax.vmap(dequantize)(gq, gs).reshape(-1)

    # local residual of stage-2 re-quantization also folds into feedback
    idx = jax.lax.axis_index(axis_name)
    cur = jax.lax.dynamic_slice(residual, (idx * chunk,), (chunk,))
    err_new = jax.lax.dynamic_update_slice(residual, cur + res2,
                                           (idx * chunk,))
    return out, err_new


def make_compressed_allreduce(mesh, axis_name: str = "data"):
    """Returns fn(g, err) -> (mean_g, err').

    ``g``/``err`` are [world, N]: row r is replica r's full (distinct)
    gradient vector — exactly what per-replica backward passes produce.
    The result rows all equal the int8-compressed mean.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    world = dict(zip(mesh.axis_names, mesh.devices.shape))[axis_name]

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(axis_name, None), P(axis_name, None)),
                       out_specs=(P(axis_name, None), P(axis_name, None)),
                       check_rep=False)
    def _run(g_local, err_local):
        out, err = compressed_psum_mean(g_local[0], err_local[0],
                                        axis_name, world)
        return out[None], err[None]

    return _run, world
