"""Training step factory: loss -> grad (f32 accumulation, optional
microbatch gradient accumulation) -> AdamW update.

Gradient accumulation reshapes the global batch into ``microbatches``
slices consumed by ``lax.scan`` — the standard fit-100B-on-16GB trick: the
live activation set belongs to one microbatch while gradients accumulate
in (ZeRO-sharded) f32.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adam: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1
    remat: bool = True
    aux_weight: float = 0.01


def make_train_step(model_cfg, tcfg: TrainConfig, sh=None):
    """Returns train_step(params, opt_state, batch) -> (params', opt', stats)."""

    def loss_for(params, batch):
        loss, metrics = lm.loss_fn(params, model_cfg, batch, sh,
                                   remat=tcfg.remat,
                                   aux_weight=tcfg.aux_weight)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(params, opt_state, batch):
        m = tcfg.microbatches
        if m == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(m, b // m, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, met), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), met

            (g_sum, l_sum), _ = jax.lax.scan(body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / m, g_sum)
            loss = l_sum / m
            metrics = {}

        params2, opt2, stats = adamw.update(tcfg.adam, opt_state, params, grads)
        stats = dict(stats, loss=loss, **{k: v for k, v in metrics.items()})
        return params2, opt2, stats

    return train_step


def init_state(model_cfg, tcfg: TrainConfig, key):
    params = lm.init_params(model_cfg, key)
    opt = adamw.init(tcfg.adam, params)
    return params, opt
