"""PartitionSpec trees for parameters, optimizer state and step inputs.

Rules (DESIGN.md Sec. 8), all with divisibility fallback:

* Megatron TP on the model axis: column-parallel in-projections
  (wq/wk/wv/wuq/gate/up/wz/wx/wdt), row-parallel out-projections
  (wo/down/out); vocab-sharded embedding + head.
* Optional FSDP: the *other* matrix dim additionally shards over
  (pod, data) — required for >=90B params on 16 GB chips.
* MoE: expert-parallel P(model, ...) when n_experts divides the axis
  (dbrx, jamba), else TP-in-expert on d_ff (mixtral).
* KV caches shard batch over data and kv-heads (or head_dim) over model.
* ZeRO-1 optimizer state via repro.optim.adamw.zero1_state_specs.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.sharding import Shardings

# leaves sharded on their LAST dim over `model`
_COL = {"wq", "wk", "wv", "wuq", "wukv", "wdq", "wdkv", "wz", "wx", "wdt",
        "gate", "up", "bq", "bk", "bv", "conv_x"}
# leaves sharded on their FIRST (matrix) dim over `model`
_ROW = {"wo", "down", "out"}
# 1-D mamba per-head/inner vectors
_VEC = {"A_log", "Dskip", "dt_bias", "norm"}
# always replicated
_REP = {"ln", "ln2", "q_ln", "kv_ln", "q_norm", "k_norm", "final_norm",
        "router", "wkr", "wB", "wC", "conv_B", "conv_C"}


def _path_names(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def param_specs(cfg, sh: Shardings, param_shapes, *, fsdp: bool = False,
                decode2d: bool = False):
    """Spec tree mirroring ``lm.init_params`` output.

    ``decode2d`` (hillclimb, EXPERIMENTS.md Sec. Perf): weights become
    fully *output-sharded* over the combined (pod, data, model) axes with
    the contracting dim replicated — at decode the activations are tiny, so
    gathering them (MBs) beats gathering FSDP weight shards (GBs/step).
    """
    if not sh.enabled:
        return jax.tree.map(lambda _: P(), param_shapes)

    combined = tuple([*(sh.batch_axes or ()), sh.model]) if decode2d else None

    def out_axis(dim, name):
        if decode2d and combined is not None:
            ax = sh.maybe(combined, dim, name)
            if ax is not None:
                return ax
        return sh.maybe(sh.model, dim, name)

    def fs(dim):
        """FSDP axis for the non-TP matrix dim."""
        if not fsdp or decode2d:
            return None
        return sh.maybe(sh.batch_axes, dim, "fsdp")

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        in_groups = names and names[0] == "groups"
        in_moe = "ffn" in names and cfg.n_experts > 0
        shp = list(leaf.shape)
        lead = []
        if in_groups:          # stacked [G, ...]
            lead = [None]
            shp = shp[1:]

        if name == "embed":
            if decode2d:
                return P(None, out_axis(shp[1], name))
            return P(sh.maybe(sh.model, shp[0], name), fs(shp[1]))
        if name == "lm_head":
            return P(fs(shp[0]), out_axis(shp[1], name))

        # MoE expert tensors are [E, d_in, d_out]; dense swiglu shares the
        # leaf names but is rank-2 (after stripping the G stack) — jamba
        # mixes both in one pattern, so discriminate by rank.
        if in_moe and name in ("gate", "up", "down") and len(shp) == 3:
            if cfg.moe_ep and shp[0] % sh.axis_size(sh.model) == 0:
                if decode2d:
                    # experts over model; col weights output-shard F over
                    # data, row weight (down) contract-shards F over data
                    if name in ("gate", "up"):
                        return P(*lead, sh.model, None,
                                 sh.maybe(sh.batch_axes, shp[2], name))
                    return P(*lead, sh.model,
                             sh.maybe(sh.batch_axes, shp[1], name), None)
                return P(*lead, sh.model, fs(shp[1]), None)
            if name in ("gate", "up"):
                return P(*lead, None, fs(shp[1]), out_axis(shp[2], name))
            if decode2d:
                return P(*lead, None, out_axis(shp[1], name), None)
            return P(*lead, None, sh.maybe(sh.model, shp[1], name), fs(shp[2]))

        if name in _REP:
            return P(*lead, *([None] * len(shp)))
        if name in _VEC:
            return P(*lead, sh.maybe(sh.model, shp[0], name))
        if name in _COL:
            if len(shp) == 1:   # bias
                return P(*lead, out_axis(shp[0], name))
            return P(*lead, fs(shp[0]), out_axis(shp[1], name))
        if name in _ROW:
            if decode2d:
                # contract-dim sharded over the combined axes: the matmul
                # partial-sums locally and all-reduces the tiny [B,1,D] out
                return P(*lead, out_axis(shp[0], name), None)
            return P(*lead, sh.maybe(sh.model, shp[0], name), fs(shp[1]))
        # default: replicate
        return P(*lead, *([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(rule, param_shapes)


def batch_specs(cfg, sh: Shardings, batch_shapes):
    """Specs for a step's ``batch`` dict."""
    if not sh.enabled:
        return jax.tree.map(lambda _: P(), batch_shapes)

    def rule(path, leaf):
        b = leaf.shape[0]
        ba = sh.maybe(sh.batch_axes, b, "batch")
        return P(ba, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_specs(cfg, sh: Shardings, cache_shapes):
    """Decode caches: list per pattern position of stacked [G, ...] trees."""
    if not sh.enabled:
        return jax.tree.map(lambda _: P(), cache_shapes)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shp = leaf.shape     # [G, B, ...]
        ba = sh.maybe(sh.batch_axes, shp[1], "cache batch")
        if name in ("k", "v"):
            # [G, B, S, Hkv, Dh]
            if sh.decode_replicate:
                # decode2d: shard the *sequence* — contractions against the
                # cache partial-sum with tiny per-head stat reductions, and
                # no tensor larger than the per-token activations moves
                s = sh.maybe(sh.model, shp[2], "cache seq")
                return P(None, ba, s, None, None)
            h = sh.maybe(sh.model, shp[3], "cache kv heads")
            d = None if h else sh.maybe(sh.model, shp[4], "cache head_dim")
            return P(None, ba, None, h, d)
        if name == "ckv":
            if sh.decode_replicate:
                return P(None, ba, sh.maybe(sh.model, shp[2], "latent seq"), None)
            return P(None, ba, None, sh.maybe(sh.model, shp[3], "latent"))
        if name == "kr":
            if sh.decode_replicate:
                return P(None, ba, sh.maybe(sh.model, shp[2], "rope seq"), None)
            return P(None, ba, None, None)
        if name == "ssm":
            # [G, B, H, Pdim, N]
            return P(None, ba, sh.maybe(sh.model, shp[2], "ssm heads"),
                     None, None)
        if name.startswith("conv"):
            return P(None, ba, None, sh.maybe(sh.model, shp[3], "conv"))
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
