"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
partitions and compiles against the production meshes, and extract the
roofline inputs (FLOPs, bytes, collective traffic, per-device memory).

Run (one cell):
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--out out.json]
Run everything:
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

# The dry-run needs 512 placeholder devices so jax.make_mesh can build the
# production meshes.  jax locks the device count at first init, so this MUST
# precede every other import (including repro.*, which import jax).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable_shapes, input_specs
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.sharding import Shardings
from repro.train.step import TrainConfig, make_train_step

# Per-arch execution knobs (sized by the napkin math in DESIGN.md Sec. 8:
# microbatching + FSDP + sequence sharding + bf16 moments for the >=90B
# models so everything fits 16 GB/chip).
ARCH_RUN = {
    "llama-3.2-vision-90b": dict(micro=16, fsdp=True, sp=True, adam="bfloat16"),
    "qwen2-0.5b": dict(micro=1, fsdp=False, sp=False, adam="float32"),
    "qwen3-0.6b": dict(micro=1, fsdp=False, sp=False, adam="float32"),
    "minicpm3-4b": dict(micro=8, fsdp=False, sp=True, adam="float32"),
    "phi3-mini-3.8b": dict(micro=4, fsdp=False, sp=True, adam="float32"),
    "musicgen-large": dict(micro=4, fsdp=False, sp=True, adam="float32"),
    "mamba2-780m": dict(micro=4, fsdp=False, sp=False, adam="float32"),
    "dbrx-132b": dict(micro=16, fsdp=True, sp=True, adam="bfloat16"),
    "mixtral-8x22b": dict(micro=16, fsdp=True, sp=True, adam="bfloat16"),
    "jamba-1.5-large-398b": dict(micro=16, fsdp=True, sp=True, adam="bfloat16"),
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}


def _shaped(sds, spec_tree, mesh):
    return jax.tree.map(
        lambda sd, sp: jax.ShapeDtypeStruct(
            sd.shape, sd.dtype, sharding=NamedSharding(mesh, sp)),
        sds, spec_tree)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in partitioned HLO."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    # e.g.  %all-reduce.5 = f32[16,1024]{1,0} all-reduce(...)
    pat = re.compile(
        r"= \(?([a-z0-9]+)\[([0-9,]*)\][^ ]* ("
        + "|".join(COLLECTIVES) + r")[\.\( ]")
    for m in pat.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[op] += nbytes
        counts[op] += 1
    out["counts"] = counts
    return out


def per_device_bytes(tree_sds, spec_tree, mesh) -> int:
    """Analytic bytes/device for a sharded pytree."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(sd, sp):
        n = int(np.prod(sd.shape)) * jnp.dtype(sd.dtype).itemsize
        denom = 1
        for entry in sp:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                denom *= sizes.get(ax, 1)
        return n // max(denom, 1)

    return sum(jax.tree.leaves(jax.tree.map(one, tree_sds, spec_tree)))


def _cost_dict(cost) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions: older
    releases return ``[dict]`` (one per computation), newer a bare dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost or {}


def build_cell(arch: str, shape_name: str, mesh, *, reduced: bool = False,
               run_overrides: dict | None = None):
    """Returns (fn, example_args_with_shardings, meta)."""
    cfg = get_config(arch, reduced=reduced)
    run = dict(ARCH_RUN[arch])
    if run_overrides:
        run.update(run_overrides)
    return _build_with_cfg(cfg, arch, shape_name, mesh, run)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             reduced: bool = False, verbose: bool = True,
             run_overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, meta = build_cell(arch, shape_name, mesh, reduced=reduced,
                                run_overrides=run_overrides)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    res = dict(
        meta,
        mesh="2x16x16" if multi_pod else "16x16",
        ok=True,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops=float(cost.get("flops", -1.0)) if cost else -1.0,
        bytes_accessed=float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        collectives={k: v for k, v in coll.items()},
        hlo_bytes=len(hlo),
    )
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                res[attr] = int(v)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {res['mesh']}: OK "
              f"(lower {res['lower_s']}s, compile {res['compile_s']}s, "
              f"flops {res['flops']:.3e}, "
              f"state/device {meta.get('state_bytes_per_device', 0)/2**30:.2f} GiB)")
        print(f"  collectives: { {k: f'{v/2**20:.1f}MiB' for k, v in res['collectives'].items() if k != 'counts'} }")
    return res


def _nonembed_params(cfg) -> int:
    shapes = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.key(0)))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
        if "embed" in names or "lm_head" in names:
            continue
        n = int(np.prod(leaf.shape))
        if "experts" not in names and cfg.n_experts and any(
                w in names for w in ("gate", "up", "down")) and len(leaf.shape) >= 3:
            pass
        total += n
    return total


def roofline_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                  q_chunk: int = 8192, verbose: bool = True,
                  run_overrides: dict | None = None) -> dict:
    """Exact per-step cost extraction via depth differencing.

    XLA's cost_analysis counts loop bodies once, so we lower *unrolled*
    variants at repeats=1 and repeats=2 (full width, microbatches=1) and
    linearly extrapolate: total = c1 + (G-1) * (c2 - c1).  The difference
    isolates one pattern-repetition exactly; embed/head/optimizer overhead
    lives in c1.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg_full = get_config(arch)
    G = cfg_full.repeats
    run = dict(ARCH_RUN[arch])
    run["micro"] = 1
    if run_overrides:
        run.update(run_overrides)

    costs = []
    for reps in (1, 2):
        cfg = dataclasses.replace(
            cfg_full, n_layers=len(cfg_full.pattern) * reps, unroll=True,
            q_chunk=q_chunk, k_chunk=q_chunk)
        fn, args, _ = _build_with_cfg(cfg, arch, shape_name, mesh, run)
        with mesh:
            compiled = jax.jit(fn).lower(*args).compile()
            cost = _cost_dict(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
        costs.append(dict(
            flops=float(cost.get("flops", 0.0)),
            bytes=float(cost.get("bytes accessed", 0.0)),
            coll={k: v for k, v in coll.items() if k != "counts"},
        ))

    def extrap(key):
        if isinstance(costs[0][key], dict):
            return {k: costs[0][key][k] + (G - 1) *
                    (costs[1][key][k] - costs[0][key][k])
                    for k in costs[0][key]}
        return costs[0][key] + (G - 1) * (costs[1][key] - costs[0][key])

    shape = SHAPES[shape_name]
    n_all = cfg_full.param_count()
    n_act = cfg_full.active_param_count()
    res = dict(
        arch=arch, shape=shape_name, kind=shape.kind,
        mesh="2x16x16" if multi_pod else "16x16",
        chips=512 if multi_pod else 256,
        flops_per_device=extrap("flops"),
        bytes_per_device=extrap("bytes"),
        collectives_per_device=extrap("coll"),
        params=n_all, params_active=n_act,
        tokens=shape.global_batch * (shape.seq if shape.kind != "decode" else 1),
        ok=True,
    )
    if verbose:
        print(f"[roofline] {arch} x {shape_name} x {res['mesh']}: "
              f"flops/dev {res['flops_per_device']:.3e} "
              f"bytes/dev {res['bytes_per_device']:.3e}")
    return res


def _build_with_cfg(cfg, arch, shape_name, mesh, run):
    """build_cell with an explicit (possibly depth-reduced) config."""
    shape = SHAPES[shape_name]
    sh = Shardings(mesh, seq_shard=run["sp"],
                   decode_replicate=bool(run.get("dec2d", False)))
    if run.get("moe_sorted"):
        cfg = dataclasses.replace(cfg, moe_sorted=True)
    if run.get("moe_bf16"):
        cfg = dataclasses.replace(cfg, moe_bf16=True)
    if run.get("attn_bf16"):
        cfg = dataclasses.replace(cfg, attn_bf16=True)
    if run.get("moe_local"):
        cfg = dataclasses.replace(cfg, moe_local_chunks=16)
    key = jax.random.key(0)
    dec2d = bool(run.get("dec2d")) and shape.kind == "decode"
    params_sds = jax.eval_shape(lambda: lm.init_params(cfg, key))
    pspecs = S.param_specs(cfg, sh, params_sds, fsdp=run["fsdp"],
                           decode2d=dec2d)
    params_in = _shaped(params_sds, pspecs, mesh)
    cell = input_specs(cfg, shape)
    bspecs = S.batch_specs(cfg, sh, cell["batch"])
    batch_in = _shaped(cell["batch"], bspecs, mesh)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind}

    if shape.kind == "train":
        acfg = adamw.AdamWConfig(moment_dtype=run["adam"])
        tcfg = TrainConfig(adam=acfg, microbatches=run["micro"])
        opt_sds = jax.eval_shape(lambda: adamw.init(acfg, params_sds))
        ospecs = adamw.zero1_state_specs(acfg, pspecs, params_sds, sh)
        opt_in = _shaped(opt_sds, ospecs, mesh)
        fn = make_train_step(cfg, tcfg, sh)
        args = (params_in, opt_in, batch_in)
        meta["state_bytes_per_device"] = (
            per_device_bytes(params_sds, pspecs, mesh)
            + per_device_bytes(opt_sds, jax.tree.map(lambda x: x, ospecs),
                               mesh))
    elif shape.kind == "prefill":
        def fn(params, batch):
            return lm.prefill(params, cfg, batch, max_len=cell["max_len"],
                              sh=sh)
        args = (params_in, batch_in)
        meta["state_bytes_per_device"] = per_device_bytes(params_sds, pspecs, mesh)
    else:
        def fn(params, batch, caches, cache_len):
            return lm.decode_step(params, cfg, batch, caches, cache_len, sh=sh)
        cspecs = S.cache_specs(cfg, sh, cell["caches"])
        caches_in = _shaped(cell["caches"], cspecs, mesh)
        cl_in = jax.ShapeDtypeStruct(
            cell["cache_len"].shape, cell["cache_len"].dtype,
            sharding=NamedSharding(mesh, P(sh.maybe(
                sh.batch_axes, cell["cache_len"].shape[0], "cache_len"))))
        args = (params_in, batch_in, caches_in, cl_in)
        meta["state_bytes_per_device"] = (
            per_device_bytes(params_sds, pspecs, mesh)
            + per_device_bytes(cell["caches"], cspecs, mesh))
    return fn, args, meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="use reduced configs (CI smoke)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="depth-differencing cost extraction instead of the "
                         "full-depth compile")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    metavar="K=V", help="run-knob overrides, e.g. "
                    "--set dec2d=1 --set micro=8 (hillclimb experiments)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.sets:
        k, v = kv.split("=", 1)
        if k == "micro":
            overrides[k] = int(v)
        elif k == "adam":
            overrides[k] = v
        else:
            overrides[k] = v.lower() in ("1", "true", "yes")

    runner = roofline_cell if args.roofline else run_cell
    kw = {"run_overrides": overrides} if args.roofline else \
        {"reduced": args.reduced, "run_overrides": overrides}
    results = []
    if args.all:
        meshes = (False,) if args.roofline else (False, True)
        for arch in ARCH_IDS:
            cfg = get_config(arch, reduced=args.reduced)
            for shape in applicable_shapes(cfg):
                for mp in meshes:
                    try:
                        results.append(runner(arch, shape.name,
                                              multi_pod=mp, **kw))
                    except Exception as e:  # noqa: BLE001
                        print(f"[dryrun] {arch} x {shape.name} "
                              f"mp={mp}: FAIL {type(e).__name__}: {e}")
                        results.append({"arch": arch, "shape": shape.name,
                                        "mesh": "2x16x16" if mp else "16x16",
                                        "ok": False, "error": str(e)[:500]})
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all)")
        results.append(runner(args.arch, args.shape,
                              multi_pod=args.multi_pod, **kw))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    ok = all(r.get("ok") for r in results)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
