"""Production meshes.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
extends data parallelism across the inter-pod network (DCN/Ethernet — the
fabric the paper's transport runs on); gradient all-reduce becomes
hierarchical: reduce-scatter over ICI inside the pod, then the small
cross-pod exchange rides SMaRTT.

Defined as a *function* so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
