"""REPS — Recycled Entropy Packet Spraying (paper Alg. 4) and the baseline
load balancers it is evaluated against (Sec. 4.1): oblivious per-packet
spraying, per-flow ECMP, and PLB.

The *entropy* is the header field ECMP hashes on (e.g. IPv6 flow label);
switches need nothing beyond standard ECMP.  REPS state per flow is two
small integers — matching the paper's "minimal complexity" claim.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.netsim import hashing

# load-balancer ids (static at trace time)
LB_REPS = 0
LB_SPRAY = 1
LB_ECMP = 2
LB_PLB = 3

LB_NAMES = {"reps": LB_REPS, "spray": LB_SPRAY, "ecmp": LB_ECMP, "plb": LB_PLB}


class LBState(NamedTuple):
    """Per-flow load-balancing state, arrays [F]."""

    next_entropy: jnp.ndarray     # i32 (REPS Alg. 4 l. 2)
    cached_entropy: jnp.ndarray   # i32 (REPS Alg. 4 l. 3)
    explore_sent: jnp.ndarray     # i32 packets sent in the explore phase
    spray_ctr: jnp.ndarray        # i32 oblivious-spray counter
    plb_entropy: jnp.ndarray      # i32 current PLB path
    plb_marked: jnp.ndarray       # f32 marked ACKs in current round
    plb_total: jnp.ndarray        # f32 ACKs in current round
    plb_congested: jnp.ndarray    # i32 consecutive congested rounds
    plb_round_end: jnp.ndarray    # f32 tick


class LBParams(NamedTuple):
    num_entropies: jnp.ndarray    # i32 (Alg. 4: 256)
    bdp_pkts: jnp.ndarray         # i32 explore-phase length (first bdp of packets)
    plb_k: jnp.ndarray            # i32 congested rounds before repathing
    plb_frac: jnp.ndarray         # f32 marked fraction that flags a round congested


def make_lb_params(num_entropies: int = 256, bdp_pkts: int = 32,
                   plb_k: int = 3, plb_frac: float = 0.5) -> LBParams:
    return LBParams(
        num_entropies=jnp.asarray(num_entropies, jnp.int32),
        bdp_pkts=jnp.asarray(bdp_pkts, jnp.int32),
        plb_k=jnp.asarray(plb_k, jnp.int32),
        plb_frac=jnp.asarray(plb_frac, jnp.float32),
    )


def init_lb_state(n_flows: int, params: LBParams, seed: int = 0) -> LBState:
    flow_ids = jnp.arange(n_flows, dtype=jnp.int32)
    rand = (hashing.hash2(flow_ids, jnp.int32(seed)) % params.num_entropies.astype(jnp.uint32)).astype(jnp.int32)
    # Every field gets its own buffer: the engine's run loops donate the
    # whole SimState to XLA, and donating one buffer through two pytree
    # leaves is a runtime error.
    z32 = lambda: jnp.zeros((n_flows,), jnp.int32)
    zf = lambda: jnp.zeros((n_flows,), jnp.float32)
    return LBState(
        next_entropy=rand,           # start exploration at a random offset
        cached_entropy=jnp.copy(rand),
        explore_sent=z32(),
        spray_ctr=z32(),
        plb_entropy=jnp.copy(rand),
        plb_marked=zf(),
        plb_total=zf(),
        plb_congested=z32(),
        plb_round_end=zf(),
    )


def on_send(lb_mode: int, p: LBParams, s: LBState, flow_mask, seq_pkt, flow_ids, now):
    """Entropy for the packet each flow in `flow_mask` emits this tick.
    Returns (state', entropy[F])."""
    n = p.num_entropies
    if lb_mode == LB_REPS:
        # Alg. 4 l. 5-9: explore the first bdp of packets, then recycle.
        explore = flow_mask & (seq_pkt < p.bdp_pkts) & (s.explore_sent < n)
        entropy = jnp.where(explore, s.next_entropy % n, s.cached_entropy % n)
        s = s._replace(
            next_entropy=s.next_entropy + explore.astype(jnp.int32),
            explore_sent=s.explore_sent + explore.astype(jnp.int32),
        )
        return s, entropy
    if lb_mode == LB_SPRAY:
        h = hashing.hash3(flow_ids, s.spray_ctr, jnp.int32(0x5E4A))
        entropy = (h % n.astype(jnp.uint32)).astype(jnp.int32)
        return s._replace(spray_ctr=s.spray_ctr + flow_mask.astype(jnp.int32)), entropy
    if lb_mode == LB_ECMP:
        return s, flow_ids % n
    if lb_mode == LB_PLB:
        return s, s.plb_entropy % n
    raise ValueError(f"unknown lb mode {lb_mode}")


def on_timeout(lb_mode: int, p: LBParams, s: LBState, timed_out):
    """Timeout-side update (failure recovery, ISSUE 8): REPS evicts the
    cached entropy of a flow that just fired an RTO and replaces it with
    a fresh one, so the retransmission explores a different equal-cost
    path instead of re-firing forever into a dead link.  Gated behind
    ``SimConfig.evict_on_timeout`` (Dims.evict) — a no-op for the other
    balancers, whose path choice is not cached per flow."""
    if lb_mode == LB_REPS:
        n = p.num_entropies
        cached = jnp.where(timed_out, s.next_entropy % n, s.cached_entropy)
        return s._replace(
            cached_entropy=cached,
            next_entropy=s.next_entropy + timed_out.astype(jnp.int32),
        )
    return s


def on_ack(lb_mode: int, p: LBParams, s: LBState, has_ack, ecn, ack_entropy, flow_ids, now):
    """ACK-side load-balancer update."""
    now = jnp.asarray(now, jnp.float32)
    n = p.num_entropies
    if lb_mode == LB_REPS:
        # Alg. 4 l. 12-17: marked ACK -> fresh entropy; clean ACK -> recycle.
        marked = has_ack & ecn
        clean = has_ack & ~ecn
        cached = jnp.where(marked, s.next_entropy % n,
                           jnp.where(clean, ack_entropy, s.cached_entropy))
        return s._replace(
            cached_entropy=cached,
            next_entropy=s.next_entropy + marked.astype(jnp.int32),
        )
    if lb_mode == LB_PLB:
        # PLB [48]: after plb_k consecutive congested rounds (>= plb_frac of
        # ACKs marked within a round), pick a new random path.
        marked = s.plb_marked + (has_ack & ecn).astype(jnp.float32)
        total = s.plb_total + has_ack.astype(jnp.float32)
        boundary = now >= s.plb_round_end
        congested_round = boundary & (marked >= p.plb_frac * jnp.maximum(total, 1.0)) & (total > 0)
        clean_round = boundary & ~congested_round
        congested = jnp.where(congested_round, s.plb_congested + 1,
                              jnp.where(clean_round, 0, s.plb_congested))
        repath = congested >= p.plb_k
        new_entropy = (hashing.hash3(flow_ids, now.astype(jnp.int32), jnp.int32(0x9187))
                       % p.num_entropies.astype(jnp.uint32)).astype(jnp.int32)
        return s._replace(
            plb_marked=jnp.where(boundary, 0.0, marked),
            plb_total=jnp.where(boundary, 0.0, total),
            plb_round_end=jnp.where(boundary, now + 32.0, s.plb_round_end),
            plb_congested=jnp.where(repath, 0, congested),
            plb_entropy=jnp.where(repath, new_entropy, s.plb_entropy),
        )
    return s  # spray/ecmp: stateless on ACK
