"""SMaRTT congestion control — faithful vectorized form of the paper's
Algorithms 1 (main loop), 2 (QuickAdapt) and 3 (FastIncrease).

Every equation/constant maps 1:1 onto the paper:

  Fair Decrease            Eq. 1   cwnd -= cwnd/bdp * fd * p.size
  Multiplicative Decrease  Eq. 2   cwnd -= min(p.size, (rtt-trtt)/rtt * md * p.size)  [+ FD]
  Fair Increase            Eq. 3   cwnd += p.size/cwnd * mtu * fi
  Multiplicative Increase  Eq. 4   cwnd += min(p.size, (trtt-rtt)/rtt * p.size/cwnd * mtu * mi) [+ FI]
  QuickAdapt               Alg. 2  cwnd  = max(acked_last_trtt, mtu) * qa_scaling
  FastIncrease             Alg. 3  cwnd += k * mtu per uncongested ACK
  Wait-to-Decrease         3.6.1   no decrease while EWMA(ecn) < 0.25
  clamp                    l. 36   cwnd in [mtu, 1.25*bdp]

The functions are shape-polymorphic over the flow dimension and free of
data-dependent control flow, so the same code serves as (a) the engine's
per-tick update, (b) the pure-jnp oracle for the ``kernels/cc_update``
Pallas kernel (see ``kernels/cc_update/ref.py``).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import CCEvent, CCParams, CCState
from repro.netsim.units import HDR_BYTES


def quick_adapt(p: CCParams, s: CCState, unacked, now, gate):
    """Alg. 2.  ``gate`` masks flows for which quick_adapt() is invoked this
    tick (l. 13 on ACKs; l. 33 on trims when outside the ignore phase).
    Returns (state', adapted)."""
    now = jnp.asarray(now, jnp.float32)
    boundary = gate & (now >= s.qa_end)
    fire = boundary & s.trigger_qa & (s.qa_end != 0.0)
    cwnd = jnp.where(fire, jnp.maximum(s.acked, p.mtu) * p.qa_scaling, s.cwnd)
    bytes_to_ignore = jnp.where(fire, unacked, s.bytes_to_ignore)
    bytes_ignored = jnp.where(fire, 0.0, s.bytes_ignored)
    trigger_qa = jnp.where(fire, False, s.trigger_qa)
    qa_end = jnp.where(boundary, now + p.trtt, s.qa_end)
    acked = jnp.where(boundary, 0.0, s.acked)
    s = s._replace(
        cwnd=cwnd,
        bytes_to_ignore=bytes_to_ignore,
        bytes_ignored=bytes_ignored,
        trigger_qa=trigger_qa,
        qa_end=qa_end,
        acked=acked,
    )
    return s, fire


def fast_increase(p: CCParams, s: CCState, ecn, rtt, size, gate):
    """Alg. 3.  Returns (state', increase_active)."""
    near_base = gate & (~ecn) & (rtt <= p.brtt * p.fi_rtt_tol + 1.0)
    count = jnp.where(near_base, s.fi_count + size, 0.0)
    active = near_base & ((count > s.cwnd) | s.fi_active)
    cwnd = jnp.where(active, s.cwnd + p.k_fast * p.mtu, s.cwnd)
    fi_active = jnp.where(gate, active, s.fi_active)
    fi_count = jnp.where(gate, count, s.fi_count)
    return s._replace(cwnd=cwnd, fi_active=fi_active, fi_count=fi_count), active


def smartt_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """One tick of Alg. 1 for every flow.

    Event composition order inside a tick: the (single) ACK first, then
    trim/timeout notifications — mirroring distinct events in an
    event-driven simulator; see DESIGN.md Sec. 6.
    """
    now = jnp.asarray(now, jnp.float32)

    # ---------------- ACK branch (Alg. 1 l. 7-27) ----------------
    has = ev.has_ack
    size = jnp.where(has, ev.ack_bytes, 0.0)

    # l. 4-5: every received control packet counts toward `acked` and the
    # QuickAdapt ignore budget.
    s = s._replace(
        acked=s.acked + size,
        bytes_ignored=s.bytes_ignored + size,
    )
    # l. 8-10: swallow ACKs sent before QuickAdapt's adjustment propagated.
    ignoring = s.bytes_ignored < s.bytes_to_ignore
    act = has & ~ignoring

    # reaction granularity (Fig. 3b): CC reacts every `react_every` ACKs.
    ack_count = s.ack_count + act.astype(jnp.int32)
    react = act & (ack_count % jnp.maximum(p.react_every, 1) == 0)
    s = s._replace(ack_count=ack_count)

    # l. 11: Wait-to-Decrease (Sec. 3.6.1)
    ecn_f = ev.ecn.astype(jnp.float32)
    avg_wtd = jnp.where(act, p.wtd_alpha * ecn_f + (1.0 - p.wtd_alpha) * s.avg_wtd, s.avg_wtd)
    s = s._replace(avg_wtd=avg_wtd)
    can_decrease = avg_wtd >= p.wtd_thresh

    # l. 13-14: QuickAdapt & FastIncrease
    s, adp = quick_adapt(p, s, ev.unacked, now, gate=act)
    s, finc = fast_increase(p, s, ev.ecn, ev.rtt, size, gate=act)

    # l. 19-27: the four window actions
    go = react & ~(adp | finc)
    rtt = jnp.maximum(ev.rtt, 1e-6)
    cwnd = jnp.maximum(s.cwnd, 1.0)

    fd_amt = cwnd / p.bdp * p.fd * size                              # Eq. 1
    md_amt = jnp.minimum(size, (rtt - p.trtt) / rtt * p.md * size)   # Eq. 2
    fi_amt = size / cwnd * p.mtu * p.fi                              # Eq. 3
    mi_amt = jnp.minimum(size, (p.trtt - rtt) / rtt * size / cwnd * p.mtu * p.mi)  # Eq. 4

    is_fd = go & ev.ecn & (rtt <= p.trtt) & can_decrease
    is_md = go & ev.ecn & (rtt > p.trtt) & can_decrease
    is_fi = go & ~ev.ecn & (rtt > p.trtt)
    is_mi = go & ~ev.ecn & (rtt <= p.trtt)

    delta = (
        -fd_amt * is_fd
        - (md_amt + fd_amt) * is_md          # MD additionally applies FD (Sec. 3.2.2)
        + fi_amt * is_fi
        + (mi_amt + fi_amt) * is_mi          # MI additionally applies FI (Sec. 3.2.4)
    )
    s = s._replace(cwnd=s.cwnd + delta)

    # ---------------- trim / timeout branch (Alg. 1 l. 28-35) ----------------
    n_loss = ev.n_trims + ev.n_timeouts
    lost = n_loss > 0
    lost_bytes = ev.trim_bytes + ev.to_bytes
    # trimmed *headers* are received packets -> l. 4-5 bookkeeping
    hdr_bytes = HDR_BYTES * ev.n_trims.astype(jnp.float32)
    s = s._replace(
        acked=s.acked + hdr_bytes,
        bytes_ignored=s.bytes_ignored + hdr_bytes,
        cwnd=s.cwnd - jnp.where(lost, lost_bytes, 0.0),     # l. 29
        trigger_qa=s.trigger_qa | lost,                      # l. 30
    )
    # l. 32-34: QuickAdapt unless still ignoring post-QA feedback
    qa_gate = lost & (s.bytes_ignored >= s.bytes_to_ignore)
    s, _ = quick_adapt(p, s, ev.unacked, now, gate=qa_gate)

    # l. 36: clamp
    s = s._replace(cwnd=jnp.clip(s.cwnd, p.mincwnd, p.maxcwnd))
    return s
