"""Dispatch table: algorithm name -> per-tick CC update function.

The algorithm choice is static at trace time (each algorithm owns its jit
specialization); all numeric parameters stay traced so tuning never
recompiles.
"""

from __future__ import annotations

from repro.core import baselines
from repro.core.smartt import smartt_update

ALGORITHMS = {
    "smartt": smartt_update,
    "swift": baselines.swift_update,
    "mprdma": baselines.mprdma_update,
    "bbr": baselines.bbr_update,
    "eqds": baselines.eqds_update,
    "eqds_smartt": baselines.eqds_smartt_update,
    "ecn_only": baselines.ecn_only_update,
    "delay_only": baselines.delay_only_update,
}

# algorithms whose transmission is gated by receiver credits
CREDIT_BASED = {"eqds", "eqds_smartt"}
# algorithms that pace by rate rather than window alone
PACED = {"bbr"}


def get(name: str):
    if name not in ALGORITHMS:
        raise KeyError(f"unknown CC algorithm {name!r}; have {sorted(ALGORITHMS)}")
    return ALGORITHMS[name]
