"""Dispatch table: (algorithm name, backend) -> per-tick CC update function.

The algorithm *and backend* choice is static at trace time (each owns its
jit specialization); all numeric parameters stay traced so tuning never
recompiles.

Backends:
  ``jnp``    — the pure-jnp reference update (every algorithm).
  ``pallas`` — the blocked ``kernels/cc_update`` Pallas kernel streaming
               the flow table through VMEM tiles (SMaRTT only; interpret
               mode off-TPU, so it runs — and bit-matches the jnp backend —
               everywhere).
"""

from __future__ import annotations

from repro.core import baselines
from repro.core.smartt import smartt_update

ALGORITHMS = {
    "smartt": smartt_update,
    "swift": baselines.swift_update,
    "mprdma": baselines.mprdma_update,
    "bbr": baselines.bbr_update,
    "eqds": baselines.eqds_update,
    "eqds_smartt": baselines.eqds_smartt_update,
    "ecn_only": baselines.ecn_only_update,
    "delay_only": baselines.delay_only_update,
}

# algorithms whose transmission is gated by receiver credits
CREDIT_BASED = {"eqds", "eqds_smartt"}
# algorithms that pace by rate rather than window alone
PACED = {"bbr"}

BACKENDS = ("jnp", "pallas")


def _smartt_pallas_update(p, s, ev, now):
    # deferred import: keeps core importable without the kernels package
    import jax

    from repro.kernels.cc_update.ops import smartt_update_pallas

    return smartt_update_pallas(
        p, s, ev, now, interpret=jax.default_backend() != "tpu")


PALLAS_ALGORITHMS = {
    "smartt": _smartt_pallas_update,
}


def get(name: str, cc_backend: str = "jnp"):
    if name not in ALGORITHMS:
        raise KeyError(f"unknown CC algorithm {name!r}; have {sorted(ALGORITHMS)}")
    if cc_backend == "jnp":
        return ALGORITHMS[name]
    if cc_backend == "pallas":
        if name not in PALLAS_ALGORITHMS:
            raise KeyError(
                f"CC algorithm {name!r} has no 'pallas' backend; "
                f"have {sorted(PALLAS_ALGORITHMS)}")
        return PALLAS_ALGORITHMS[name]
    raise KeyError(f"unknown cc backend {cc_backend!r}; have {BACKENDS}")
