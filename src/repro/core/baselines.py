"""Baseline congestion-control algorithms the paper compares against
(Sec. 4): Swift, MPRDMA, BBR, EQDS — plus the single-signal strawmen of
Fig. 2/3 (ECN-only, delay-only) and the EQDS+SMaRTT hybrid of Sec. 5.1.

These are deliberately compact, faithful-in-spirit re-implementations (the
paper itself uses htsim's versions): each reproduces the property the paper
leans on — Swift's once-per-RTT delay MD, MPRDMA's per-packet ECN reaction
and its unfairness, BBR's slow bandwidth-probe convergence, EQDS's
receiver-credit pacing with no fabric CC.  Simplifications are listed in
DESIGN.md Sec. 2.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import CCEvent, CCParams, CCState


def _loss_event(ev: CCEvent):
    return (ev.n_trims + ev.n_timeouts) > 0


def swift_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """Swift [37]: delay-based AIMD with per-RTT multiplicative decrease.

    target delay = trtt (flow-scaled terms elided); additive increase
    sw_ai MTU per RTT; decrease factor 1 - beta*(rtt-t)/rtt clamped to
    sw_max_mdf, at most once per RTT.
    """
    now = jnp.asarray(now, jnp.float32)
    rtt = jnp.maximum(ev.rtt, 1e-6)
    cwnd = jnp.maximum(s.cwnd, 1.0)
    can_dec = (now - s.last_dec) >= rtt

    inc = p.sw_ai * p.mtu * ev.ack_bytes / cwnd
    mdf = jnp.maximum(1.0 - p.sw_beta * (rtt - p.trtt) / rtt, 1.0 - p.sw_max_mdf)

    slow = ev.rtt > p.trtt
    new_cwnd = jnp.where(
        ev.has_ack & ~slow, s.cwnd + inc,
        jnp.where(ev.has_ack & slow & can_dec, s.cwnd * mdf, s.cwnd))
    dec_fired = ev.has_ack & slow & can_dec

    # loss (trim/timeout): halve once per RTT
    lost = _loss_event(ev)
    loss_dec = lost & ((now - s.last_dec) >= rtt)
    new_cwnd = jnp.where(loss_dec, new_cwnd * 0.5, new_cwnd)
    last_dec = jnp.where(dec_fired | loss_dec, now, s.last_dec)

    return s._replace(cwnd=jnp.clip(new_cwnd, p.mincwnd, p.maxcwnd), last_dec=last_dec)


def mprdma_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """MPRDMA [40]: per-packet ECN (DCTCP-flavored): marked ACK -> cwnd -=
    mtu/2; unmarked -> +mtu per RTT.  No fairness shaping — the unfairness
    the paper observes for small messages emerges from exactly this rule."""
    now = jnp.asarray(now, jnp.float32)
    cwnd = jnp.maximum(s.cwnd, 1.0)
    inc = p.mtu * ev.ack_bytes / cwnd
    dec = 0.5 * ev.ack_bytes
    new_cwnd = jnp.where(ev.has_ack, jnp.where(ev.ecn, s.cwnd - dec, s.cwnd + inc), s.cwnd)

    lost = _loss_event(ev)
    can_dec = (now - s.last_dec) >= jnp.maximum(ev.rtt, p.brtt)
    loss_dec = lost & can_dec
    new_cwnd = jnp.where(loss_dec, new_cwnd * 0.5, new_cwnd)
    last_dec = jnp.where(loss_dec, now, s.last_dec)
    return s._replace(cwnd=jnp.clip(new_cwnd, p.mincwnd, p.maxcwnd), last_dec=last_dec)


def bbr_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """BBR-lite [12]: windowed-max bottleneck-bandwidth estimate, 8-phase
    pacing-gain cycle, cwnd = cwnd_gain * BDP_est.  Captures BBR's defining
    slowness: rate converges only as the probe cycle advances (the paper
    observed ~7 RTTs)."""
    now = jnp.asarray(now, jnp.float32)
    rtprop = jnp.where(ev.has_ack, jnp.minimum(s.rtprop, ev.rtt), s.rtprop)
    delivered = s.win_delivered + jnp.where(ev.has_ack, ev.ack_bytes, 0.0)

    # close the estimation window every rtprop ticks
    boundary = now >= s.win_end
    win_len = jnp.maximum(rtprop, 1.0)
    sample = delivered / win_len
    # windowed max with decay — new samples take over within a few windows
    bw_est = jnp.where(boundary, jnp.maximum(sample, s.bw_est * 0.9), s.bw_est)
    delivered = jnp.where(boundary, 0.0, delivered)
    win_end = jnp.where(boundary, now + win_len, s.win_end)

    # pacing-gain cycle: probe, drain, cruise x6
    phase = (now / jnp.maximum(rtprop, 1.0)).astype(jnp.int32) % 8
    gain = jnp.where(phase == 0, p.bbr_probe_gain, jnp.where(phase == 1, p.bbr_drain_gain, 1.0))
    pacing_rate = bw_est * gain
    cwnd = p.bbr_cwnd_gain * bw_est * rtprop

    return s._replace(
        cwnd=jnp.clip(cwnd, p.mincwnd, p.maxcwnd),
        rtprop=rtprop,
        win_delivered=delivered,
        win_end=win_end,
        bw_est=bw_est,
        pacing_rate=pacing_rate,
    )


def eqds_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """EQDS [46] (vanilla, receiver-driven): the *receiver* paces via pull
    credits (granted in the fabric model); the sender has no window logic —
    cwnd stays at the speculative cap and `credits` gate transmission."""
    credits = s.credits + ev.credit_grant
    return s._replace(credits=credits, cwnd=jnp.broadcast_to(p.maxcwnd, s.cwnd.shape))


def eqds_smartt_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """Sec. 5.1: EQDS augmented with SMaRTT — receiver credits still pace,
    but the sender additionally runs the full SMaRTT window to cap its rate
    under fabric congestion."""
    from repro.core.smartt import smartt_update

    s = s._replace(credits=s.credits + ev.credit_grant)
    return smartt_update(p, s, ev, now)


def ecn_only_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """Fig. 2/3 strawman: decrease by at most half an MTU per marked ACK,
    additive increase otherwise (paper: 'we decrease the congestion window
    by half an MTU per packet at most in response to ... ECN marking')."""
    cwnd = jnp.maximum(s.cwnd, 1.0)
    delta = jnp.where(ev.ecn, -0.5 * ev.ack_bytes, p.mtu * ev.ack_bytes / cwnd)
    new_cwnd = jnp.where(ev.has_ack, s.cwnd + delta, s.cwnd)
    lost = _loss_event(ev)
    new_cwnd = jnp.where(lost, new_cwnd - ev.trim_bytes - ev.to_bytes, new_cwnd)
    return s._replace(cwnd=jnp.clip(new_cwnd, p.mincwnd, p.maxcwnd))


def delay_only_update(p: CCParams, s: CCState, ev: CCEvent, now) -> CCState:
    """Fig. 2/3 strawman: same rule keyed on rtt > trtt instead of ECN."""
    cwnd = jnp.maximum(s.cwnd, 1.0)
    slow = ev.rtt > p.trtt
    delta = jnp.where(slow, -0.5 * ev.ack_bytes, p.mtu * ev.ack_bytes / cwnd)
    new_cwnd = jnp.where(ev.has_ack, s.cwnd + delta, s.cwnd)
    lost = _loss_event(ev)
    new_cwnd = jnp.where(lost, new_cwnd - ev.trim_bytes - ev.to_bytes, new_cwnd)
    return s._replace(cwnd=jnp.clip(new_cwnd, p.mincwnd, p.maxcwnd))
