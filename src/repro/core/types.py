"""Shared congestion-control state/parameter containers.

All per-flow state is struct-of-arrays (one array per field, flow-major) so
the update rules vectorize across flows — on TPU this is the layout the
``kernels/cc_update`` Pallas kernel consumes directly.

The paper stresses SMaRTT's footprint: 19 B per flow + 28 B global (Sec.
3.2.5).  Our unified ``CCState`` carries the union of all algorithms' fields
for engine simplicity; `SMARTT_FIELDS` documents the subset the paper's
algorithm actually needs (which matches the 19-byte budget).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# Fields required by SMaRTT itself (paper Sec. 3.2.5 memory budget):
#   cwnd(4) acked(4) qa_end(4) bytes_to_ignore(4) bytes_ignored(~2)
#   fi_count(~2) avg_wtd(1) trigger_qa/fi_active(bits)  ~= 19 B/flow.
SMARTT_FIELDS = (
    "cwnd",
    "acked",
    "qa_end",
    "trigger_qa",
    "bytes_to_ignore",
    "bytes_ignored",
    "fi_count",
    "fi_active",
    "avg_wtd",
)


class CCParams(NamedTuple):
    """Algorithm constants (traced scalars — retuning never recompiles).

    fi/mi arrive pre-multiplied by the bandwidth scaling factor
    gamma = bdp / reference_bdp (paper Sec. 3.5 "Scaling"); md arrives
    pre-doubled when trimming is disabled (Sec. 3.3).
    """

    mtu: jnp.ndarray            # bytes
    bdp: jnp.ndarray            # bytes (base, inter-rack)
    maxcwnd: jnp.ndarray        # 1.25 * bdp
    mincwnd: jnp.ndarray        # 1 MTU
    brtt: jnp.ndarray           # ticks, per-flow [F] (hop-count specific)
    trtt: jnp.ndarray           # ticks, per-flow [F] = 1.5 * brtt
    fd: jnp.ndarray             # fair-decrease constant (0.8)
    md: jnp.ndarray             # multiplicative-decrease constant (2; 4 w/o trim)
    fi: jnp.ndarray             # fair-increase constant (0.25 * gamma)
    mi: jnp.ndarray             # mult-increase constant (brtt/(trtt-brtt) * gamma)
    k_fast: jnp.ndarray         # FastIncrease MTUs per ACK (2)
    qa_scaling: jnp.ndarray     # 0.8
    wtd_alpha: jnp.ndarray      # EWMA weight for Wait-to-Decrease
    wtd_thresh: jnp.ndarray     # 0.25
    fi_rtt_tol: jnp.ndarray     # "rtt ~= brtt" multiplier for FastIncrease
    react_every: jnp.ndarray    # CC reaction granularity in ACKs (Fig. 3b), 1 = per packet
    # baseline parameters
    sw_ai: jnp.ndarray          # swift additive increase (MTUs per RTT)
    sw_beta: jnp.ndarray        # swift multiplicative-decrease slope
    sw_max_mdf: jnp.ndarray     # swift max decrease factor per RTT
    bbr_probe_gain: jnp.ndarray
    bbr_drain_gain: jnp.ndarray
    bbr_cwnd_gain: jnp.ndarray


class CCState(NamedTuple):
    """Per-flow congestion state (union across algorithms), arrays [F]."""

    cwnd: jnp.ndarray           # f32 bytes
    # --- SMaRTT (Alg. 1-3) ---
    acked: jnp.ndarray          # f32 bytes received in current trtt window
    qa_end: jnp.ndarray         # f32 tick: end of current QuickAdapt window
    trigger_qa: jnp.ndarray     # bool
    bytes_to_ignore: jnp.ndarray  # f32
    bytes_ignored: jnp.ndarray  # f32
    fi_count: jnp.ndarray       # f32 FastIncrease byte counter
    fi_active: jnp.ndarray      # bool
    avg_wtd: jnp.ndarray        # f32 Wait-to-Decrease EWMA of ECN marks
    ack_count: jnp.ndarray      # i32 ACK counter (reaction granularity, Fig. 3b)
    # --- Swift / MPRDMA ---
    last_dec: jnp.ndarray       # f32 tick of last multiplicative decrease
    # --- BBR-lite ---
    bw_est: jnp.ndarray         # f32 bytes/tick bottleneck estimate
    rtprop: jnp.ndarray         # f32 min RTT seen
    win_delivered: jnp.ndarray  # f32 bytes delivered in current estimation window
    win_end: jnp.ndarray        # f32 tick
    pacing_rate: jnp.ndarray    # f32 bytes/tick (0 = unpaced)
    # --- EQDS (receiver-credit) ---
    credits: jnp.ndarray        # f32 bytes of unspent pull credit
    spec_budget: jnp.ndarray    # f32 speculative first-window budget


class CCEvent(NamedTuple):
    """Per-flow control-plane events aggregated for one tick, arrays [F].

    The slotted fabric delivers at most one ACK per flow per tick (one
    delivery per receiver NIC per tick); trims/timeouts can batch.
    """

    has_ack: jnp.ndarray        # bool
    ack_bytes: jnp.ndarray      # f32 data bytes covered by the ACK (p.size)
    ecn: jnp.ndarray            # bool echoed ECN mark
    rtt: jnp.ndarray            # f32 ticks measured from echoed timestamp
    ack_entropy: jnp.ndarray    # i32 echoed path entropy (for REPS)
    n_trims: jnp.ndarray        # i32 trimmed-header notifications this tick
    trim_bytes: jnp.ndarray     # f32 original data bytes those trims covered
    n_timeouts: jnp.ndarray     # i32 retransmission timeouts fired this tick
    to_bytes: jnp.ndarray       # f32 data bytes declared lost by timeout
    unacked: jnp.ndarray        # f32 bytes currently in flight (transport view)
    credit_grant: jnp.ndarray   # f32 bytes of receiver credit arriving (EQDS)


def init_cc_state(n_flows: int, params: CCParams, start_cwnd=None) -> CCState:
    f32 = lambda v: jnp.full((n_flows,), v, jnp.float32)
    if start_cwnd is None:
        start_cwnd = params.maxcwnd
    return CCState(
        cwnd=jnp.broadcast_to(jnp.asarray(start_cwnd, jnp.float32), (n_flows,)).astype(jnp.float32),
        acked=f32(0.0),
        qa_end=f32(0.0),
        trigger_qa=jnp.zeros((n_flows,), bool),
        bytes_to_ignore=f32(0.0),
        bytes_ignored=f32(0.0),
        fi_count=f32(0.0),
        fi_active=jnp.zeros((n_flows,), bool),
        avg_wtd=f32(0.0),
        ack_count=jnp.zeros((n_flows,), jnp.int32),
        last_dec=f32(-1e9),
        bw_est=f32(0.0) + params.mtu,   # line rate: 1 MTU per tick
        rtprop=jnp.asarray(params.brtt, jnp.float32) * jnp.ones((n_flows,), jnp.float32),
        win_delivered=f32(0.0),
        win_end=f32(0.0),
        pacing_rate=f32(0.0),
        credits=f32(0.0),
        spec_budget=jnp.broadcast_to(jnp.asarray(params.bdp, jnp.float32), (n_flows,)).astype(jnp.float32),
    )


def make_cc_params(
    *,
    mtu: float,
    bdp: float,
    brtt,                      # scalar or per-flow [F] ticks
    target_mult: float = 1.5,  # trtt = 1.5 * brtt (paper Sec. 3)
    fd: float = 0.8,
    md: float = 2.0,
    fi: float = 0.25,
    k_fast: float = 2.0,
    qa_scaling: float = 0.8,
    wtd_alpha: float = 1.0 / 32.0,   # paper omits alpha; see DESIGN.md Sec. 2
    wtd_thresh: float = 0.25,
    fi_rtt_tol: float = 1.1,
    react_every: int = 1,
    gamma: float = 1.0,
    use_trimming: bool = True,
    maxcwnd_mult: float = 1.25,
    sw_ai: float = 1.0,
    sw_beta: float = 0.8,
    sw_max_mdf: float = 0.5,
) -> CCParams:
    brtt = jnp.asarray(brtt, jnp.float32)
    trtt = brtt * target_mult
    # mi chosen so the window grows by at most one MTU per RTT (Sec. 3.2.4):
    # mi = brtt / (trtt - brtt); with trtt = 1.5*brtt this is 2.
    mi = brtt / jnp.maximum(trtt - brtt, 1e-6)
    a = lambda v: jnp.asarray(v, jnp.float32)
    return CCParams(
        mtu=a(mtu),
        bdp=a(bdp),
        maxcwnd=a(maxcwnd_mult * bdp),
        mincwnd=a(mtu),
        brtt=brtt,
        trtt=trtt,
        fd=a(fd),
        md=a(md * (1.0 if use_trimming else 2.0)),  # double md w/o trimming (Sec. 3.3)
        fi=a(fi * gamma),
        mi=mi * a(gamma),
        k_fast=a(k_fast),
        qa_scaling=a(qa_scaling),
        wtd_alpha=a(wtd_alpha),
        wtd_thresh=a(wtd_thresh),
        fi_rtt_tol=a(fi_rtt_tol),
        react_every=jnp.asarray(react_every, jnp.int32),
        sw_ai=a(sw_ai),
        sw_beta=a(sw_beta),
        sw_max_mdf=a(sw_max_mdf),
        bbr_probe_gain=a(1.25),
        bbr_drain_gain=a(0.75),
        bbr_cwnd_gain=a(2.0),
    )
