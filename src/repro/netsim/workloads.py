"""Traffic patterns from the paper's evaluation (Sec. 4): incast,
permutation (including multi-permutation and uneven-size variants), and
windowed alltoall — plus the sparse large-message patterns
(``heavy_tailed``, ``staggered_large``) that exercise the engine's
event-horizon time leaping (DESIGN.md Sec. 6.3): heavy-tailed message
sizes and spread-out arrivals keep the fabric quiescent for most of the
simulated span.

A workload is a static flow table.  ``window`` implements the paper's
windowed alltoall (Sec. 4.5): a sender's flow with per-sender order index j
becomes eligible only while fewer than ``window`` of its predecessors are
unfinished, keeping k flows active per node at all times.

Dependency-driven traffic (collectives — DESIGN.md Sec. 11) rides on the
optional ``dep_par``/``dep_thr`` table: flow ``f`` activates only once
``t >= t_start[f]`` *and* every parent ``dep_par[f, j]`` has delivered at
least ``dep_thr[f, j]`` bytes to its receiver (slot sentinel ``-1`` =
unused).  ``coll_id`` groups flows into collectives for the CCT metric;
it never reaches the device.  ``netsim/collectives.py`` emits these
tables for ring/tree allreduce, all-gather, and pipeline patterns.

``Workload.validate()`` sanity-checks a table (self-flows, sizes, start
ticks, node bounds, window/order consistency, dependency shape/range/
threshold bounds and DAG acyclicity via Kahn's algorithm) with actionable
errors; ``state.derive`` calls it before any shape math, so hand-built
tables fail fast instead of deep inside tracing.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.units import FatTreeConfig


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    src: np.ndarray          # [F] i32 sender node
    dst: np.ndarray          # [F] i32 receiver node
    size: np.ndarray         # [F] i32 bytes
    t_start: np.ndarray      # [F] i32 tick
    order: np.ndarray        # [F] i32 per-sender flow ordinal (alltoall windowing)
    window: int = 1 << 30    # flows eligible per sender at once
    # -- optional dependency table (collectives; None = legacy t_start-only)
    dep_par: np.ndarray | None = None   # [F, D] i32 parent flow id (-1 = free)
    dep_thr: np.ndarray | None = None   # [F, D] i32 parent bytes that must
                                        #   have landed before this flow starts
    coll_id: np.ndarray | None = None   # [F] i32 collective group (-1 = none);
                                        #   host-only — drives the CCT metric

    @property
    def n_flows(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_deps(self) -> int:
        """Dependency-table width D (0 = no table)."""
        return 0 if self.dep_par is None else int(self.dep_par.shape[1])

    def validate(self, n_nodes: int | None = None) -> "Workload":
        """Check the flow table before it reaches tracing.

        ``state.derive`` calls this with the topology's node count; call
        it directly after hand-building a table.  Raises ``ValueError``
        with the offending flow indices — a bad table otherwise fails
        deep inside jit tracing with a shape or gather error.  Returns
        ``self`` so construction can chain.
        """
        fields = {"src": self.src, "dst": self.dst, "size": self.size,
                  "t_start": self.t_start, "order": self.order}
        for key, arr in fields.items():
            a = np.asarray(arr)
            if a.ndim != 1:
                raise ValueError(
                    f"workload {self.name!r}: field {key!r} must be 1-D "
                    f"[n_flows], got shape {a.shape}")
            if a.shape[0] != self.src.shape[0]:
                raise ValueError(
                    f"workload {self.name!r}: field {key!r} has "
                    f"{a.shape[0]} entries but src has {self.src.shape[0]}; "
                    f"all flow-table columns must align")
        if self.n_flows == 0:
            raise ValueError(
                f"workload {self.name!r}: empty flow table (the engine "
                f"needs at least one flow)")

        def _idx(mask):
            return np.flatnonzero(mask)[:8].tolist()

        self_talk = self.src == self.dst
        if np.any(self_talk):
            raise ValueError(
                f"workload {self.name!r}: flows {_idx(self_talk)} have "
                f"src == dst (a node cannot send to itself); fix the "
                f"traffic table")
        bad_size = self.size <= 0
        if np.any(bad_size):
            raise ValueError(
                f"workload {self.name!r}: flows {_idx(bad_size)} have "
                f"non-positive size; every flow must move >= 1 byte")
        bad_start = self.t_start < 0
        if np.any(bad_start):
            raise ValueError(
                f"workload {self.name!r}: flows {_idx(bad_start)} have "
                f"negative t_start; start ticks must be >= 0")
        oob = (self.src < 0) | (self.dst < 0)
        if n_nodes is not None:
            oob |= (self.src >= n_nodes) | (self.dst >= n_nodes)
        if np.any(oob):
            bound = f"[0, {n_nodes})" if n_nodes is not None else ">= 0"
            raise ValueError(
                f"workload {self.name!r}: flows {_idx(oob)} reference "
                f"nodes outside {bound}; the workload was built for a "
                f"different topology")
        self._validate_deps(_idx)
        # Windowing admits a sender's flows in `order`: a flow becomes
        # eligible once fewer than `window` of its order-predecessors are
        # unfinished.  If a window-gated flow (order index >= window —
        # earlier ones can never accumulate `window` unfinished
        # predecessors) starts *earlier* than a predecessor, the window
        # would hold it past its own start time — almost always a
        # mis-built table, so reject it for every sender the window can
        # actually gate (more flows than `window`).
        if self.window >= self.n_flows:      # windowing can't gate anyone
            return self
        senders, counts = np.unique(self.src, return_counts=True)
        for s in senders[counts > self.window]:
            f = np.flatnonzero(self.src == s)
            f = f[np.argsort(self.order[f], kind="stable")]
            drop = np.diff(self.t_start[f]) < 0
            drop[:max(self.window - 1, 0)] = False   # later flow ungated
            if np.any(drop):
                j = int(np.flatnonzero(drop)[0])
                raise ValueError(
                    f"workload {self.name!r}: windowed sender {int(s)} "
                    f"has t_start decreasing along its `order` (flow "
                    f"{int(f[j + 1])} starts at "
                    f"{int(self.t_start[f[j + 1]])} < flow {int(f[j])} "
                    f"at {int(self.t_start[f[j]])}); sort t_start to "
                    f"match `order` (or widen `window`) so the "
                    f"eligibility window never blocks a flow past its "
                    f"start tick")
        return self

    def _validate_deps(self, _idx) -> None:
        """Dependency-table checks: shape alignment, parent-id range,
        threshold bounds, and DAG acyclicity (Kahn's algorithm)."""
        F = self.n_flows
        if (self.dep_par is None) != (self.dep_thr is None):
            have = "dep_par" if self.dep_par is not None else "dep_thr"
            raise ValueError(
                f"workload {self.name!r}: {have} set without its partner; "
                f"dep_par and dep_thr must be given together ([F, D] each)")
        if self.coll_id is not None:
            cid = np.asarray(self.coll_id)
            if cid.ndim != 1 or cid.shape[0] != F:
                raise ValueError(
                    f"workload {self.name!r}: coll_id must be 1-D [n_flows],"
                    f" got shape {cid.shape}")
            bad = cid < -1
            if np.any(bad):
                raise ValueError(
                    f"workload {self.name!r}: flows {_idx(bad)} have "
                    f"coll_id < -1; use -1 for flows outside any collective")
        if self.dep_par is None:
            return
        par = np.asarray(self.dep_par)
        thr = np.asarray(self.dep_thr)
        if par.ndim != 2 or par.shape[0] != F or thr.shape != par.shape:
            raise ValueError(
                f"workload {self.name!r}: dependency table must be two "
                f"aligned [n_flows, D] arrays; got dep_par {par.shape}, "
                f"dep_thr {thr.shape} for {F} flows")
        if par.shape[1] == 0:
            return
        used = par >= 0
        oob = used & (par >= F)
        if np.any(oob):
            rows = np.flatnonzero(oob.any(axis=1))[:8].tolist()
            raise ValueError(
                f"workload {self.name!r}: flows {rows} reference parent "
                f"flow ids outside [0, {F}); dep_par must name flows of "
                f"this workload (-1 = unused slot)")
        self_dep = used & (par == np.arange(F, dtype=np.int64)[:, None])
        if np.any(self_dep):
            rows = np.flatnonzero(self_dep.any(axis=1))[:8].tolist()
            raise ValueError(
                f"workload {self.name!r}: flows {rows} depend on "
                f"themselves; a flow cannot gate its own start")
        parent_size = np.where(used, np.asarray(self.size)[
            np.clip(par, 0, F - 1)], 1)
        bad_thr = used & ((thr < 1) | (thr > parent_size))
        if np.any(bad_thr):
            rows = np.flatnonzero(bad_thr.any(axis=1))[:8].tolist()
            raise ValueError(
                f"workload {self.name!r}: flows {rows} have dependency "
                f"thresholds outside [1, parent size] bytes; a threshold "
                f"above the parent's size can never be met")
        # Kahn's algorithm over parent -> child edges: anything left with
        # unresolved parents after the peel sits on (or behind) a cycle.
        indeg = used.sum(axis=1).astype(np.int64)
        children: list[list[int]] = [[] for _ in range(F)]
        for f, p in zip(*np.nonzero(used)):
            children[int(par[f, p])].append(int(f))
        queue = list(np.flatnonzero(indeg == 0))
        done = 0
        while queue:
            p = queue.pop()
            done += 1
            for c in children[p]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    queue.append(c)
        if done < F:
            stuck = np.flatnonzero(indeg > 0)[:8].tolist()
            raise ValueError(
                f"workload {self.name!r}: dependency cycle — flows "
                f"{stuck} can never activate (Kahn's algorithm leaves "
                f"them with unresolved parents); break the cycle in "
                f"dep_par")


def incast(tree: FatTreeConfig, degree: int, size_bytes: int, receiver: int = 0,
           seed: int = 0, start: int = 0) -> Workload:
    """`degree`:1 incast onto `receiver`, senders spread across racks."""
    n = tree.n_nodes
    if degree > n - 1:
        raise ValueError("incast degree exceeds node count")
    rng = np.random.default_rng(seed)
    # spread senders round-robin over racks so the fan-in crosses the core
    order = np.argsort((np.arange(n) % tree.nodes_per_rack) * tree.racks
                       + np.arange(n) // tree.nodes_per_rack, kind="stable")
    candidates = np.array([x for x in order if x != receiver], np.int32)
    src = candidates[:degree]
    rng.shuffle(src)
    f = degree
    return Workload(
        name=f"incast_{degree}to1",
        src=src.astype(np.int32),
        dst=np.full(f, receiver, np.int32),
        size=np.full(f, size_bytes, np.int32),
        t_start=np.full(f, start, np.int32),
        order=np.zeros(f, np.int32),
    )


def permutation(tree: FatTreeConfig, size_bytes: int, seed: int = 0,
                cross_rack: bool = True, n_perms: int = 1,
                big_flow: tuple[int, int] | None = None) -> Workload:
    """Node-to-node permutation(s).  ``cross_rack`` forces every flow through
    the core (paper: 'selected so that each packet crosses the core
    switches').  ``n_perms`` > 1 runs several concurrent permutations
    (Fig. 11c); ``big_flow=(idx, size)`` makes one flow bigger (Fig. 11d)."""
    n = tree.n_nodes
    rng = np.random.default_rng(seed)
    srcs, dsts, orders = [], [], []
    for pi in range(n_perms):
        if cross_rack:
            shift = tree.nodes_per_rack * (1 + rng.integers(0, tree.racks - 1))
            dst = (np.arange(n) + shift) % n
        else:
            dst = rng.permutation(n)
            while np.any(dst == np.arange(n)):
                dst = rng.permutation(n)
        srcs.append(np.arange(n))
        dsts.append(dst)
        orders.append(np.full(n, pi))
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    size = np.full(src.shape[0], size_bytes, np.int32)
    if big_flow is not None:
        size[big_flow[0]] = big_flow[1]
    return Workload(
        name=f"permutation_x{n_perms}",
        src=src,
        dst=dst,
        size=size,
        t_start=np.zeros_like(src),
        order=np.concatenate(orders).astype(np.int32),
    )


def heavy_tailed(tree: FatTreeConfig, n_flows: int, *,
                 size_base: int = 16 * 1024, alpha: float = 1.3,
                 size_cap: int = 2 * 1024 * 1024,
                 gap_mean: float = 4000.0, seed: int = 0) -> Workload:
    """Sparse arrivals with Pareto(``alpha``)-tailed message sizes.

    Flow ``i`` starts after an Exp(``gap_mean``)-distributed gap beyond
    flow ``i-1``'s start and moves ``size_base * Pareto`` bytes (capped at
    ``size_cap``) between a random src/dst pair — mostly short messages
    with a heavy tail of multi-BDP ones, separated by idle stretches of
    many base RTTs.  The time-stepped engine burns a tick per MTU-time
    across those stretches; the leap-enabled engine skips them in closed
    form, which is exactly what `benchmarks/perf.py` measures on this
    pattern (UEC-style sparse/large-message regimes, arXiv 2508.08906).
    """
    n = tree.n_nodes
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, n_flows)
    dst = rng.integers(0, n - 1, n_flows)
    dst += (dst >= src).astype(dst.dtype)          # uniform over dst != src
    size = np.minimum(size_base * (1.0 + rng.pareto(alpha, n_flows)),
                      size_cap).astype(np.int64)
    t_start = np.floor(np.cumsum(rng.exponential(gap_mean, n_flows))
                       ).astype(np.int64)
    t_start -= t_start[0]                          # first flow starts at 0
    return Workload(
        name=f"heavy_tailed_{n_flows}f",
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        size=np.maximum(size, 1).astype(np.int32),
        t_start=t_start.astype(np.int32),
        order=np.zeros(n_flows, np.int32),
    )


def staggered_large(tree: FatTreeConfig, n_flows: int, size_bytes: int,
                    gap_ticks: int, seed: int = 0) -> Workload:
    """Few large messages, launched one every ``gap_ticks``.

    Every flow has its own sender and its own receiver (a node may still
    send one flow while receiving another), and every pair is cross-rack;
    with ``gap_ticks`` well above the per-message service time the fabric
    is idle between transfers — the timeout/large-message regime the leap
    engine targets."""
    n, m = tree.n_nodes, tree.nodes_per_rack
    if n_flows > n // 2:
        raise ValueError("staggered_large wants at most n_nodes/2 flows "
                         "(one sender and one receiver per flow)")
    rng = np.random.default_rng(seed)
    # pair node i with a node shifted one rack over; distinct flows use
    # distinct senders (FMAX stays 1) and distinct receivers
    perm = rng.permutation(n)
    src = perm[:n_flows]
    dst = (src + m) % n
    return Workload(
        name=f"staggered_{n_flows}x{size_bytes // 1024}K",
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        size=np.full(n_flows, size_bytes, np.int32),
        t_start=(gap_ticks * np.arange(n_flows)).astype(np.int32),
        order=np.zeros(n_flows, np.int32),
    )


def alltoall(tree: FatTreeConfig, size_bytes: int, window: int = 4,
             nodes: int | None = None, seed: int = 0,
             spread: bool = False) -> Workload:
    """Windowed alltoall among ``nodes`` hosts (Sec. 4.5).  Participants
    are the first ``nodes`` hosts, or — with ``spread`` — evenly strided
    across the whole fabric, so on a large multi-tier tree the collective
    actually crosses racks, pods, and the core instead of staying inside
    the first racks."""
    n = nodes or tree.n_nodes
    stride = tree.n_nodes // n if spread else 1
    ids = np.arange(n, dtype=np.int32) * stride
    srcs, dsts, orders = [], [], []
    for s in range(n):
        # classic shifted schedule: round j targets (s + j) mod n
        for j in range(1, n):
            srcs.append(ids[s])
            dsts.append(ids[(s + j) % n])
            orders.append(j - 1)
    f = len(srcs)
    return Workload(
        name=f"alltoall_{n}x{n}_w{window}",
        src=np.array(srcs, np.int32),
        dst=np.array(dsts, np.int32),
        size=np.full(f, size_bytes, np.int32),
        t_start=np.zeros(f, np.int32),
        order=np.array(orders, np.int32),
        window=window,
    )
