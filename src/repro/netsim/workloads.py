"""Traffic patterns from the paper's evaluation (Sec. 4): incast,
permutation (including multi-permutation and uneven-size variants), and
windowed alltoall.

A workload is a static flow table.  ``window`` implements the paper's
windowed alltoall (Sec. 4.5): a sender's flow with per-sender order index j
becomes eligible only while fewer than ``window`` of its predecessors are
unfinished, keeping k flows active per node at all times.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.units import FatTreeConfig


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    src: np.ndarray          # [F] i32 sender node
    dst: np.ndarray          # [F] i32 receiver node
    size: np.ndarray         # [F] i32 bytes
    t_start: np.ndarray      # [F] i32 tick
    order: np.ndarray        # [F] i32 per-sender flow ordinal (alltoall windowing)
    window: int = 1 << 30    # flows eligible per sender at once

    @property
    def n_flows(self) -> int:
        return int(self.src.shape[0])


def incast(tree: FatTreeConfig, degree: int, size_bytes: int, receiver: int = 0,
           seed: int = 0, start: int = 0) -> Workload:
    """`degree`:1 incast onto `receiver`, senders spread across racks."""
    n = tree.n_nodes
    if degree > n - 1:
        raise ValueError("incast degree exceeds node count")
    rng = np.random.default_rng(seed)
    # spread senders round-robin over racks so the fan-in crosses the core
    order = np.argsort((np.arange(n) % tree.nodes_per_rack) * tree.racks
                       + np.arange(n) // tree.nodes_per_rack, kind="stable")
    candidates = np.array([x for x in order if x != receiver], np.int32)
    src = candidates[:degree]
    rng.shuffle(src)
    f = degree
    return Workload(
        name=f"incast_{degree}to1",
        src=src.astype(np.int32),
        dst=np.full(f, receiver, np.int32),
        size=np.full(f, size_bytes, np.int32),
        t_start=np.full(f, start, np.int32),
        order=np.zeros(f, np.int32),
    )


def permutation(tree: FatTreeConfig, size_bytes: int, seed: int = 0,
                cross_rack: bool = True, n_perms: int = 1,
                big_flow: tuple[int, int] | None = None) -> Workload:
    """Node-to-node permutation(s).  ``cross_rack`` forces every flow through
    the core (paper: 'selected so that each packet crosses the core
    switches').  ``n_perms`` > 1 runs several concurrent permutations
    (Fig. 11c); ``big_flow=(idx, size)`` makes one flow bigger (Fig. 11d)."""
    n = tree.n_nodes
    rng = np.random.default_rng(seed)
    srcs, dsts, orders = [], [], []
    for pi in range(n_perms):
        if cross_rack:
            shift = tree.nodes_per_rack * (1 + rng.integers(0, tree.racks - 1))
            dst = (np.arange(n) + shift) % n
        else:
            dst = rng.permutation(n)
            while np.any(dst == np.arange(n)):
                dst = rng.permutation(n)
        srcs.append(np.arange(n))
        dsts.append(dst)
        orders.append(np.full(n, pi))
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    size = np.full(src.shape[0], size_bytes, np.int32)
    if big_flow is not None:
        size[big_flow[0]] = big_flow[1]
    return Workload(
        name=f"permutation_x{n_perms}",
        src=src,
        dst=dst,
        size=size,
        t_start=np.zeros_like(src),
        order=np.concatenate(orders).astype(np.int32),
    )


def alltoall(tree: FatTreeConfig, size_bytes: int, window: int = 4,
             nodes: int | None = None, seed: int = 0) -> Workload:
    """Windowed alltoall among the first ``nodes`` hosts (Sec. 4.5)."""
    n = nodes or tree.n_nodes
    srcs, dsts, orders = [], [], []
    for s in range(n):
        # classic shifted schedule: round j targets (s + j) mod n
        for j in range(1, n):
            srcs.append(s)
            dsts.append((s + j) % n)
            orders.append(j - 1)
    f = len(srcs)
    return Workload(
        name=f"alltoall_{n}x{n}_w{window}",
        src=np.array(srcs, np.int32),
        dst=np.array(dsts, np.int32),
        size=np.full(f, size_bytes, np.int32),
        t_start=np.zeros(f, np.int32),
        order=np.array(orders, np.int32),
        window=window,
    )
