"""Unit system for the slotted packet simulator.

One **tick** = serialization time of one MTU at line rate.  All links share a
single rate (as in the paper's setup), so every port forwards exactly one
data packet per tick; control packets (ACKs / trimmed headers / credits) are
~64 B and ride priority queues, i.e. effectively zero serialization time.

Handy invariant: BDP measured in packets == base RTT measured in ticks.
"""

from __future__ import annotations

import dataclasses
import math

HDR_BYTES = 64.0  # trimmed-header / ACK wire size (bytes)


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Physical constants. Defaults follow the paper (Sec. 4): 4 KiB MTU,
    600 ns links, 400 ns switch traversal.  100 Gb/s is the paper's reference
    bandwidth for parameter tuning (Sec. 3.5); the headline simulations use
    800 Gb/s, which simply rescales the tick."""

    rate_gbps: float = 100.0
    mtu_bytes: int = 4096
    link_latency_ns: float = 600.0
    switch_latency_ns: float = 400.0

    @property
    def tick_ns(self) -> float:
        return self.mtu_bytes * 8.0 / self.rate_gbps  # ns per MTU

    @property
    def link_lat_ticks(self) -> int:
        return max(1, round(self.link_latency_ns / self.tick_ns))

    @property
    def switch_lat_ticks(self) -> int:
        return max(1, round(self.switch_latency_ns / self.tick_ns))

    @property
    def hop_ticks(self) -> int:
        """Store-and-forward hop: 1 tick serialization + link + switch."""
        return 1 + self.link_lat_ticks + self.switch_lat_ticks


@dataclasses.dataclass(frozen=True)
class FatTreeConfig:
    """Fat tree, two- or three-tier.

    Two-tier (``pods == 0``, the default): ``racks`` T0 switches x
    ``nodes_per_rack`` hosts, each T0 wired with one uplink to each of
    ``uplinks`` spines (T1).  T0 oversubscription = nodes_per_rack /
    uplinks.

    Three-tier (``pods > 0``): the racks are grouped into ``pods`` pods of
    ``racks // pods`` racks.  Each pod has ``uplinks`` T1 aggregation
    switches (every rack wires one uplink to each), and each T1 switch has
    ``core_uplinks`` uplinks into the T2 core.  Core plane: ``uplinks *
    core_uplinks`` T2 switches, where core ``(a, j)`` connects to T1
    switch ``a`` of *every* pod — the standard fat-tree wiring, giving
    ``uplinks * core_uplinks`` equal-cost core paths between pods.
    Per-tier oversubscription: T0 = nodes_per_rack / uplinks, T1 =
    racks_per_pod / core_uplinks."""

    racks: int = 8
    nodes_per_rack: int = 16
    uplinks: int = 4     # T0 uplinks per rack (== spines when two-tier,
                         # == T1 aggs per pod when three-tier)
    pods: int = 0        # 0 = two-tier; > 0 = three-tier pod count
    core_uplinks: int = 0  # T1 -> T2 uplinks per agg (three-tier only)

    def __post_init__(self):
        if self.pods < 0 or self.core_uplinks < 0:
            raise ValueError("pods / core_uplinks must be >= 0")
        if self.pods == 0 and self.core_uplinks:
            raise ValueError(
                "core_uplinks requires a three-tier tree (set pods > 0)")
        if self.pods:
            if self.core_uplinks < 1:
                raise ValueError(
                    "a three-tier tree (pods > 0) needs core_uplinks >= 1")
            if self.racks % self.pods:
                raise ValueError(
                    f"racks ({self.racks}) must divide evenly into pods "
                    f"({self.pods})")

    @property
    def tiers(self) -> int:
        return 3 if self.pods else 2

    @property
    def n_nodes(self) -> int:
        return self.racks * self.nodes_per_rack

    @property
    def racks_per_pod(self) -> int:
        """Racks under one T1 subtree (the whole fabric when two-tier)."""
        return self.racks // self.pods if self.pods else self.racks

    @property
    def n_t1(self) -> int:
        """T1 switches: spines (two-tier) or aggs over all pods."""
        return self.pods * self.uplinks if self.pods else self.uplinks

    @property
    def n_cores(self) -> int:
        return self.uplinks * self.core_uplinks if self.pods else 0

    @property
    def n_spines(self) -> int:
        return self.uplinks

    @property
    def n_switches(self) -> int:
        return self.racks + self.n_t1 + self.n_cores

    @property
    def oversubscription(self) -> float:
        return self.nodes_per_rack / self.uplinks

    @property
    def core_oversubscription(self) -> float:
        """T1-tier oversubscription (1.0 for two-tier trees)."""
        if not self.pods:
            return 1.0
        return self.racks_per_pod / self.core_uplinks


@dataclasses.dataclass(frozen=True)
class Timing:
    """Derived tick-domain latencies.  ``*_inter`` is the longest path in
    the fabric (cross-core when three-tier, cross-rack when two-tier) —
    ring/buffer sizing and the reference BDP key off it.  ``*_pod`` is the
    cross-rack-within-a-pod path (== ``*_inter`` on two-tier trees)."""

    hop: int            # per store-and-forward hop (data path)
    ret_inter: int      # priority-path return latency, longest path
    ret_pod: int        # priority-path return latency, intra-pod cross-rack
    ret_intra: int      # priority-path return latency, same rack
    fwd_inter: int      # empty-network one-way data latency, longest path
    fwd_pod: int
    fwd_intra: int
    brtt_inter: int     # base RTT (ticks == BDP in packets)
    brtt_pod: int
    brtt_intra: int
    trim_delay: int     # trim event -> sender notification latency


def path_queues(tree: FatTreeConfig | None) -> tuple[int, int, int]:
    """Queues traversed per path class (intra-rack, intra-pod cross-rack,
    longest): the hop counts the timing model is parameterized by."""
    h_inter = 5 if (tree is not None and tree.tiers == 3) else 3
    return 1, 3, h_inter


def derive_timing(link: LinkConfig, tree: FatTreeConfig | None = None) -> Timing:
    l, s = link.link_lat_ticks, link.switch_lat_ticks
    hop = link.hop_ticks
    # A data path through h queues: NIC emission (+1+l+s, landing in the
    # first queue), h-1 store-and-forward switch hops (+1+l+s each), and the
    # final host link off the t0_down port (+1+l, no switch at the host).
    # h = 1 intra-rack (t0_down only), 3 cross-rack via T1 (t0_up, t1_down,
    # t0_down), 5 cross-pod via the core (t0_up, t1_up, t2_down, t1_down,
    # t0_down).  Control returns ride priority queues: no serialization.
    h_intra, h_pod, h_inter = path_queues(tree)

    def fwd(h):
        return (1 + l + s) * h + (1 + l)

    def ret(h):
        return (l + s) * h + l

    # trimmed header: forwarded (priority) from mid-path to receiver, then
    # NACK back -- approximately one priority-path RTT from the trim point.
    trim_delay = ret(h_inter) + (1 + l + s)
    return Timing(
        hop=hop,
        ret_inter=ret(h_inter),
        ret_pod=ret(h_pod),
        ret_intra=ret(h_intra),
        fwd_inter=fwd(h_inter),
        fwd_pod=fwd(h_pod),
        fwd_intra=fwd(h_intra),
        brtt_inter=fwd(h_inter) + ret(h_inter),
        brtt_pod=fwd(h_pod) + ret(h_pod),
        brtt_intra=fwd(h_intra) + ret(h_intra),
        trim_delay=trim_delay,
    )


def bdp_bytes(link: LinkConfig, timing: Timing) -> float:
    return float(timing.brtt_inter * link.mtu_bytes)


def reference_bdp_bytes() -> float:
    """Paper Sec. 3.5: reference bdp = 100 Gb/s network with 12 us RTT."""
    return 100e9 / 8.0 * 12e-6  # = 150_000 bytes


def gamma(link: LinkConfig, timing: Timing) -> float:
    """fi/mi bandwidth-latency scaling factor (paper Sec. 3.5)."""
    return bdp_bytes(link, timing) / reference_bdp_bytes()


def ns_to_ticks(ns: float, link: LinkConfig) -> int:
    return int(math.ceil(ns / link.tick_ns))


def ticks_to_us(ticks, link: LinkConfig) -> float:
    return ticks * link.tick_ns * 1e-3
