"""Unit system for the slotted packet simulator.

One **tick** = serialization time of one MTU at line rate.  All links share a
single rate (as in the paper's setup), so every port forwards exactly one
data packet per tick; control packets (ACKs / trimmed headers / credits) are
~64 B and ride priority queues, i.e. effectively zero serialization time.

Handy invariant: BDP measured in packets == base RTT measured in ticks.
"""

from __future__ import annotations

import dataclasses
import math

HDR_BYTES = 64.0  # trimmed-header / ACK wire size (bytes)


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Physical constants. Defaults follow the paper (Sec. 4): 4 KiB MTU,
    600 ns links, 400 ns switch traversal.  100 Gb/s is the paper's reference
    bandwidth for parameter tuning (Sec. 3.5); the headline simulations use
    800 Gb/s, which simply rescales the tick."""

    rate_gbps: float = 100.0
    mtu_bytes: int = 4096
    link_latency_ns: float = 600.0
    switch_latency_ns: float = 400.0

    @property
    def tick_ns(self) -> float:
        return self.mtu_bytes * 8.0 / self.rate_gbps  # ns per MTU

    @property
    def link_lat_ticks(self) -> int:
        return max(1, round(self.link_latency_ns / self.tick_ns))

    @property
    def switch_lat_ticks(self) -> int:
        return max(1, round(self.switch_latency_ns / self.tick_ns))

    @property
    def hop_ticks(self) -> int:
        """Store-and-forward hop: 1 tick serialization + link + switch."""
        return 1 + self.link_lat_ticks + self.switch_lat_ticks


@dataclasses.dataclass(frozen=True)
class FatTreeConfig:
    """Two-tier fat tree: ``racks`` T0 switches x ``nodes_per_rack`` hosts,
    each T0 wired with one uplink to each of ``uplinks`` spines (T1).
    Oversubscription ratio = nodes_per_rack / uplinks."""

    racks: int = 8
    nodes_per_rack: int = 16
    uplinks: int = 4  # == number of spines

    @property
    def n_nodes(self) -> int:
        return self.racks * self.nodes_per_rack

    @property
    def n_spines(self) -> int:
        return self.uplinks

    @property
    def oversubscription(self) -> float:
        return self.nodes_per_rack / self.uplinks


@dataclasses.dataclass(frozen=True)
class Timing:
    """Derived tick-domain latencies for the 2-tier tree."""

    hop: int            # per store-and-forward hop (data path)
    ret_inter: int      # priority-path return latency, cross-rack
    ret_intra: int      # priority-path return latency, same rack
    fwd_inter: int      # empty-network one-way data latency, cross-rack
    fwd_intra: int
    brtt_inter: int     # base RTT (ticks == BDP in packets)
    brtt_intra: int
    trim_delay: int     # trim event -> sender notification latency


def derive_timing(link: LinkConfig) -> Timing:
    l, s = link.link_lat_ticks, link.switch_lat_ticks
    hop = link.hop_ticks
    # data path inter-rack: sender -> t0_up q -> t1_down q -> t0_down q -> rx
    #   emission(+1+l+s) then 2 switch hops (+1+l+s each) then final link(+1+l)
    fwd_inter = (1 + l + s) * 3 + (1 + l)
    fwd_intra = (1 + l + s) * 1 + (1 + l)
    # control return path: priority queues, negligible serialization
    ret_inter = (l + s) * 3 + l
    ret_intra = (l + s) * 1 + l
    brtt_inter = fwd_inter + ret_inter
    brtt_intra = fwd_intra + ret_intra
    # trimmed header: forwarded (priority) from mid-path to receiver, then
    # NACK back -- approximately one priority-path RTT from the trim point.
    trim_delay = ret_inter + (1 + l + s)
    return Timing(
        hop=hop,
        ret_inter=ret_inter,
        ret_intra=ret_intra,
        fwd_inter=fwd_inter,
        fwd_intra=fwd_intra,
        brtt_inter=brtt_inter,
        brtt_intra=brtt_intra,
        trim_delay=trim_delay,
    )


def bdp_bytes(link: LinkConfig, timing: Timing) -> float:
    return float(timing.brtt_inter * link.mtu_bytes)


def reference_bdp_bytes() -> float:
    """Paper Sec. 3.5: reference bdp = 100 Gb/s network with 12 us RTT."""
    return 100e9 / 8.0 * 12e-6  # = 150_000 bytes


def gamma(link: LinkConfig, timing: Timing) -> float:
    """fi/mi bandwidth-latency scaling factor (paper Sec. 3.5)."""
    return bdp_bytes(link, timing) / reference_bdp_bytes()


def ns_to_ticks(ns: float, link: LinkConfig) -> int:
    return int(math.ceil(ns / link.tick_ns))


def ticks_to_us(ticks, link: LinkConfig) -> float:
    return ticks * link.tick_ns * 1e-3
