"""Dependency-driven collective traffic generators (DESIGN.md Sec. 11).

AI-datacenter traffic is collectives — ring/tree allreduce, all-gather,
pipeline stages — not independent flow lists: each transfer starts only
when the chunk it consumes has landed (PAPER.md; Hoefler et al. 2025,
"Ultra Ethernet's Design Principles").  This module emits plain
:class:`Workload` tables whose ``dep_par``/``dep_thr`` columns encode
that chunk DAG; the engine's ``sender.activated`` predicate releases each
flow the tick its last prerequisite byte is delivered, and the ``coll_id``
column groups flows so ``api.RunResult`` can report collective completion
time (CCT) next to FCT.

Host-side numpy only (the JX105 contract): these run per scenario build,
never on device.

Generators:

  ``ring_allreduce``  bucket algorithm: N-1 reduce-scatter steps then
                      N-1 all-gather steps around a ring; every node
                      forwards one chunk per step, each send gated on the
                      previous step's chunk landing from the ring
                      predecessor (D = 1).
  ``all_gather``      the ring all-gather phase alone (N-1 steps).
  ``tree_allreduce``  reduce up a ``branching``-ary tree (a node's upward
                      send waits on all children's chunks, D = branching)
                      then broadcast back down.
  ``pipeline``        M microbatches through S linearly-chained stages;
                      stage s of microbatch m waits on stage s-1 of the
                      same microbatch.
"""

from __future__ import annotations

import numpy as np

from repro.netsim.units import FatTreeConfig
from repro.netsim.workloads import Workload


def _participants(tree: FatTreeConfig, nodes: int | None,
                  spread: bool) -> np.ndarray:
    """The first ``nodes`` hosts, or — with ``spread`` — evenly strided
    across the fabric so the collective crosses racks/pods/core."""
    n = nodes or tree.n_nodes
    if n < 2 or n > tree.n_nodes:
        raise ValueError(
            f"collective wants 2 <= nodes <= {tree.n_nodes}, got {n}")
    stride = tree.n_nodes // n if spread else 1
    return np.arange(n, dtype=np.int64) * stride


def _table(name: str, rows: list, coll: int = 0) -> Workload:
    """Assemble (src, dst, size, t_start, dep, order) rows into a
    Workload.  ``rows`` entries are (src, dst, size, t_start, deps) with
    ``deps`` a list of (parent_flow, threshold_bytes)."""
    F = len(rows)
    D = max((len(r[4]) for r in rows), default=0)
    src = np.fromiter((r[0] for r in rows), np.int32, F)
    dst = np.fromiter((r[1] for r in rows), np.int32, F)
    size = np.fromiter((r[2] for r in rows), np.int32, F)
    t_start = np.fromiter((r[3] for r in rows), np.int32, F)
    dep_par = np.full((F, D), -1, np.int32)
    dep_thr = np.zeros((F, D), np.int32)
    for f, r in enumerate(rows):
        for j, (p, thr) in enumerate(r[4]):
            dep_par[f, j] = p
            dep_thr[f, j] = thr
    # per-sender emission order follows flow id (the step/phase order the
    # generators emit in), so round-robin arbitration visits a sender's
    # earliest-releasable flow first
    order = np.zeros(F, np.int32)
    cnt: dict[int, int] = {}
    for f in range(F):
        s = int(src[f])
        order[f] = cnt.get(s, 0)
        cnt[s] = order[f] + 1
    return Workload(
        name=name, src=src, dst=dst, size=size, t_start=t_start,
        order=order, dep_par=dep_par, dep_thr=dep_thr,
        coll_id=np.full(F, coll, np.int32))


def ring_allreduce(tree: FatTreeConfig, chunk_bytes: int,
                   nodes: int | None = None, spread: bool = False,
                   start: int = 0) -> Workload:
    """Bucket ring allreduce over ``nodes`` participants.

    2(N-1) steps; at step s every node i sends one ``chunk_bytes`` chunk
    to its ring successor, gated (for s > 0) on the chunk it forwards
    having arrived from its ring predecessor at step s-1.  Steps
    [0, N-1) are the reduce-scatter phase, [N-1, 2(N-1)) the all-gather
    phase — same traffic pattern, one dependency chain."""
    ids = _participants(tree, nodes, spread)
    n = len(ids)
    steps = 2 * (n - 1)
    rows = []
    fid = {}                       # (i, s) -> flow id
    for s in range(steps):
        for i in range(n):
            deps = []
            if s > 0:
                deps.append((fid[(i - 1) % n, s - 1], chunk_bytes))
            fid[i, s] = len(rows)
            rows.append((ids[i], ids[(i + 1) % n], chunk_bytes, start, deps))
    return _table(f"allreduce_ring_{n}n", rows)


def all_gather(tree: FatTreeConfig, chunk_bytes: int,
               nodes: int | None = None, spread: bool = False,
               start: int = 0) -> Workload:
    """Ring all-gather: N-1 steps, each node forwarding the chunk it just
    received (step 0 sends its own shard, dependency-free)."""
    ids = _participants(tree, nodes, spread)
    n = len(ids)
    rows = []
    fid = {}
    for s in range(n - 1):
        for i in range(n):
            deps = []
            if s > 0:
                deps.append((fid[(i - 1) % n, s - 1], chunk_bytes))
            fid[i, s] = len(rows)
            rows.append((ids[i], ids[(i + 1) % n], chunk_bytes, start, deps))
    return _table(f"allgather_{n}n", rows)


def tree_allreduce(tree: FatTreeConfig, msg_bytes: int,
                   nodes: int | None = None, spread: bool = False,
                   branching: int = 2, start: int = 0) -> Workload:
    """Reduce-up + broadcast-down over a ``branching``-ary logical tree
    (heap layout: node k's children are ``branching*k + 1 ...``).

    Every non-root participant sends its reduced message to its tree
    parent once all of its own children's messages have landed
    (D = branching), then receives the broadcast copy gated on the
    parent's own inbound broadcast (the root's children instead wait on
    the root's reduction completing)."""
    if branching < 1:
        raise ValueError(f"branching must be >= 1, got {branching}")
    ids = _participants(tree, nodes, spread)
    n = len(ids)
    kids = [[c for c in range(branching * k + 1,
                              min(branching * k + 1 + branching, n))]
            for k in range(n)]
    rows = []
    red = {}                       # participant k -> its upward flow id
    # reduce phase: deepest-first so a flow's children exist before it —
    # emit in reverse heap order (children have larger heap indices)
    for k in range(n - 1, 0, -1):
        deps = [(red[c], msg_bytes) for c in kids[k]]
        red[k] = len(rows)
        rows.append((ids[k], ids[(k - 1) // branching], msg_bytes, start,
                     deps))
    # broadcast phase: top-down; child k's copy comes from its parent,
    # gated on the parent's inbound broadcast (root: on the reduction)
    bcast = {}
    for k in range(1, n):
        parent = (k - 1) // branching
        if parent == 0:
            deps = [(red[c], msg_bytes) for c in kids[0]]
        else:
            deps = [(bcast[parent], msg_bytes)]
        bcast[k] = len(rows)
        rows.append((ids[parent], ids[k], msg_bytes, start, deps))
    return _table(f"allreduce_tree_{n}n_b{branching}", rows)


def pipeline(tree: FatTreeConfig, stage_bytes: int, stages: int,
             microbatches: int, spread: bool = False,
             start: int = 0) -> Workload:
    """M microbatches through a linear chain of ``stages`` nodes.

    Flow (m, s) moves microbatch m's activations from stage node s to
    s+1 and waits on (m, s-1) landing (D = 1); the stage-0 flows are
    dependency-free and all start at ``start`` — the per-sender
    round-robin serializes them in microbatch order."""
    if stages < 2 or microbatches < 1:
        raise ValueError(
            f"pipeline wants stages >= 2 and microbatches >= 1, got "
            f"{stages} stages x {microbatches} microbatches")
    ids = _participants(tree, stages, spread)
    rows = []
    fid = {}
    for s in range(stages - 1):
        for m in range(microbatches):
            deps = []
            if s > 0:
                deps.append((fid[m, s - 1], stage_bytes))
            fid[m, s] = len(rows)
            rows.append((ids[s], ids[s + 1], stage_bytes, start, deps))
    return _table(f"pipeline_{stages}s_{microbatches}m", rows)
