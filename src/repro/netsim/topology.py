"""Tier-generic fat-tree topology: static port enumeration + routing tables.

Queue (output-port) layout, indexed contiguously; empty blocks vanish, so a
two-tier tree reproduces the historical layout exactly:

  t0_up[r, a]    : rack r's uplink to T1 switch a        (P * U1 ports)
  t1_up[s1, j]   : T1 switch s1's uplink to the core     (3-tier only)
  t2_down[c, g]  : core c's downlink to pod g            (3-tier only)
  t1_down[s1, i] : T1 switch s1's downlink to its i-th rack
  t0_down[node]  : rack's downlink to a host NIC         (last N queues)

Emitters (anything that can place one packet per tick onto a wire):
  ids [0, NQ)            : the queues above
  ids [NQ, NQ + N)       : host NICs (senders)

Every queue below the t0_down block faces a switch; the t0_down block faces
hosts — so wire latency stays uniform within three contiguous emitter
classes (switch-facing, host-facing, sender NICs), which the fabric's
dynamic-update-slice wire writes rely on.

Routing is table-driven and purely functional: each emitter names the
switch its wire feeds (``nbr_sw``), and each switch carries its subtree
interval ``[sw_lo, sw_hi)`` of host nodes, its closed-form down-port rule,
and its contiguous run of equal-cost up ports (``sw_up_base``/
``sw_up_cnt``).  A packet at a switch goes *down* when dst is in the
subtree, else *up* via an ECMP hash of the packet entropy with the
per-switch salt ``sw_salt`` — exactly like switch ECMP hashing a header
field (paper Sec. 3.6); on a three-tier tree the same hash selects among
core paths at the T1 tier.  ``fabric.route_switch`` is the (single) jax
consumer of these tables.

Down-routing is interval/run-length coded rather than a dense
``[NSW, N]`` table: at every tier the down ports of a switch cover its
subtree in runs of equal length (1 node per rack port, ``M`` nodes per T1
port, ``M * racks_per_pod`` nodes per core port), so the down port toward
node ``d`` is ``dn_base[sw] + d // dn_stride[sw]`` — two [NSW] vectors
replace the O(NSW * N) table the fabric used to gather through (the dense
``down_tbl`` is still materialized here, as numpy, for tests and tools).

Exactly the emitters with ``nbr_sw >= 0`` can ever enqueue (t0_down ports
deliver to hosts instead); ``enq_ids`` enumerates them in ascending id
order, and the whole enqueue path — ranking, queue writes, trim ledger —
runs on that compacted [EQ] axis rather than all ``n_emitters`` rows.
``in_tbl``/``in_pos`` give the inverse of ``nbr_sw`` over the compact
enumeration: ``in_tbl[sw]`` lists the compact indices of the emitters
feeding switch ``sw`` in ascending id order (padded with ``len(enq_ids)``),
and ``in_pos[j]`` is compact emitter ``j``'s flat slot in that table.
Emitters enqueueing to the same destination queue always feed the same
switch (a queue belongs to exactly one switch — ``sw_of_q``), so the
fabric's same-destination enqueue ranking only needs pairwise compares
*within* a switch's fan-in group — O(NSW * fan_max^2) instead of O(NE^2) —
and the per-queue accepted counts reduce over the owner's group instead of
a segment-sum scatter.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.units import FatTreeConfig

KIND_T0_UP = 0
KIND_T1_DOWN = 1
KIND_T0_DOWN = 2
KIND_SENDER = 3
KIND_T1_UP = 4
KIND_T2_DOWN = 5

HOST = -1  # nbr_sw sentinel: this port's wire ends at a host NIC

# the historical per-rack ECMP salt formula, now applied per switch id
# (rack switch ids equal rack indices, so two-tier hashes are unchanged)
SALT_MUL = 0x9E37
SALT_ADD = 0x1234


@dataclasses.dataclass(frozen=True)
class Topology:
    tree: FatTreeConfig
    n_queues: int
    n_emitters: int
    n_switches: int
    # per-emitter static arrays (numpy; moved to device by the engine)
    kind: np.ndarray        # [E] emitter kind
    rack: np.ndarray        # [E] rack (T0) / T1 index / core index
    aux: np.ndarray         # [E] uplink / local-rack / node auxiliary index
    nbr_sw: np.ndarray      # [E] switch this emitter's wire feeds (HOST = -1)
    # per-switch routing tables (switch ids: racks [0, P), T1 [P, P+n_t1),
    # cores [P+n_t1, P+n_t1+n_cores))
    sw_tier: np.ndarray     # [NSW] 0 = rack, 1 = T1, 2 = core
    sw_lo: np.ndarray       # [NSW] subtree host interval [lo, hi)
    sw_hi: np.ndarray
    sw_up_base: np.ndarray  # [NSW] first up-port queue id
    sw_up_cnt: np.ndarray   # [NSW] equal-cost up ports (0 at the top tier)
    sw_salt: np.ndarray     # [NSW] uint32 per-switch ECMP hash salt
    down_tbl: np.ndarray    # [NSW, N] down-port queue id toward each node
    #   (dense reference form; the fabric routes via dn_base/dn_stride)
    dn_base: np.ndarray     # [NSW] down port = dn_base + dst // dn_stride
    dn_stride: np.ndarray   # [NSW] nodes covered per down port
    sw_of_q: np.ndarray     # [NQ] switch owning each queue (output port)
    # compact enqueue-capable emitter enumeration + per-switch fan-in
    # (inverse of nbr_sw over that enumeration; enqueue-rank groups)
    enq_ids: np.ndarray     # [EQ] emitter ids with nbr_sw >= 0, ascending
    fan_max: int            # max emitters feeding one switch
    in_tbl: np.ndarray      # [NSW, fan_max] compact indices of feeding
    #   emitters, ascending, padded with EQ
    in_pos: np.ndarray      # [EQ] compact emitter's flat slot
    #   sw * fan_max + k in in_tbl

    # ---- queue-id helpers (block bases precomputed in build_topology) ----

    def t0_up(self, r: int, a: int) -> int:
        return r * self.tree.uplinks + a

    def t1_up(self, s1: int, j: int) -> int:
        """T1 switch ``s1`` (pod-major: g * uplinks + a), core uplink j."""
        t = self.tree
        if not t.pods:
            raise ValueError("t1_up ports exist only on three-tier trees")
        return t.racks * t.uplinks + s1 * t.core_uplinks + j

    def t2_down(self, c: int, g: int) -> int:
        """Core switch ``c`` (= a * core_uplinks + j), downlink to pod g."""
        t = self.tree
        if not t.pods:
            raise ValueError("t2_down ports exist only on three-tier trees")
        return (t.racks * t.uplinks + t.n_t1 * t.core_uplinks
                + c * t.pods + g)

    def t1_down(self, s1: int, i: int) -> int:
        """T1 switch ``s1``'s downlink to its i-th rack (two-tier: spine
        s1's downlink to rack i — the historical (k, r) layout)."""
        t = self.tree
        base = (t.racks * t.uplinks + t.n_t1 * t.core_uplinks
                + t.n_cores * t.pods)
        return base + s1 * t.racks_per_pod + i

    def t0_down(self, node: int) -> int:
        return self.n_queues - self.tree.n_nodes + node

    def sender(self, node: int) -> int:
        return self.n_queues + node

    # ---- switch-id helpers ----

    def rack_sw(self, r: int) -> int:
        return r

    def t1_sw(self, s1: int) -> int:
        return self.tree.racks + s1

    def core_sw(self, c: int) -> int:
        return self.tree.racks + self.tree.n_t1 + c


def build_topology(tree: FatTreeConfig) -> Topology:
    P, U1, M, N = tree.racks, tree.uplinks, tree.nodes_per_rack, tree.n_nodes
    three = tree.tiers == 3
    G = tree.pods if three else 1
    Pg = tree.racks_per_pod                  # racks per T1 subtree
    U2 = tree.core_uplinks
    NA = tree.n_t1                           # T1 switch count
    C = tree.n_cores

    b_t1up = P * U1
    b_t2dn = b_t1up + NA * U2
    b_t1dn = b_t2dn + C * G
    b_t0dn = b_t1dn + NA * Pg
    nq = b_t0dn + N
    ne = nq + N

    kind = np.zeros(ne, np.int32)
    rack = np.zeros(ne, np.int32)
    aux = np.zeros(ne, np.int32)
    nbr = np.full(ne, HOST, np.int32)

    nsw = P + NA + C
    sw_tier = np.zeros(nsw, np.int32)
    sw_lo = np.zeros(nsw, np.int32)
    sw_hi = np.zeros(nsw, np.int32)
    sw_up_base = np.zeros(nsw, np.int32)
    sw_up_cnt = np.zeros(nsw, np.int32)
    node_rack = np.arange(N, dtype=np.int32) // M

    # ---- switches ----
    for r in range(P):
        sw_tier[r] = 0
        sw_lo[r], sw_hi[r] = r * M, (r + 1) * M
        sw_up_base[r], sw_up_cnt[r] = r * U1, U1
    for s1 in range(NA):
        sw = P + s1
        sw_tier[sw] = 1
        if three:
            g = s1 // U1
            sw_lo[sw], sw_hi[sw] = g * Pg * M, (g + 1) * Pg * M
            sw_up_base[sw] = b_t1up + s1 * U2
            sw_up_cnt[sw] = U2
        else:
            sw_lo[sw], sw_hi[sw] = 0, N     # spine: whole fabric below
    for c in range(C):
        sw = P + NA + c
        sw_tier[sw] = 2
        sw_lo[sw], sw_hi[sw] = 0, N
    sw_salt = (np.arange(nsw, dtype=np.uint32) * np.uint32(SALT_MUL)
               + np.uint32(SALT_ADD))

    # ---- down-port rules ----
    # At every tier a switch's down ports cover its subtree in equal-length
    # runs of nodes, so the port toward node d is the run-length lookup
    # dn_base + d // dn_stride (exact for every d inside the subtree, which
    # is the only place routing ever goes down).  The dense table is kept,
    # numpy-only, as the reference form for tests/tools; rows are exact
    # inside the switch's subtree, entries outside it are never routed to.
    dn_base = np.zeros(nsw, np.int32)
    dn_stride = np.ones(nsw, np.int32)
    dn_base[:P] = b_t0dn                         # rack: one port per node
    for s1 in range(NA):
        g = s1 // U1 if three else 0             # subtree starts at rack g*Pg
        dn_base[P + s1] = b_t1dn + s1 * Pg - g * Pg
        dn_stride[P + s1] = M                    # one port per rack
    for c in range(C):
        dn_base[P + NA + c] = b_t2dn + c * G
        dn_stride[P + NA + c] = M * Pg           # one port per pod
    down_tbl = np.zeros((nsw, N), np.int32)
    down_tbl[:P] = b_t0dn + np.arange(N, dtype=np.int32)[None, :]
    for s1 in range(NA):
        if three:
            g = s1 // U1
            i = np.clip(node_rack - g * Pg, 0, Pg - 1)
        else:
            i = node_rack
        down_tbl[P + s1] = b_t1dn + s1 * Pg + i
    for c in range(C):
        down_tbl[P + NA + c] = b_t2dn + c * G + node_rack // Pg

    # ---- ports ----
    sw_of_q = np.zeros(nq, np.int32)
    for r in range(P):
        for a in range(U1):
            q = r * U1 + a
            kind[q], rack[q], aux[q] = KIND_T0_UP, r, a
            nbr[q] = P + ((r // Pg) * U1 + a if three else a)
            sw_of_q[q] = r
    for s1 in range(NA):
        for j in range(U2):
            q = b_t1up + s1 * U2 + j
            kind[q], rack[q], aux[q] = KIND_T1_UP, s1, j
            nbr[q] = P + NA + (s1 % U1) * U2 + j
            sw_of_q[q] = P + s1
    for c in range(C):
        for g in range(G):
            q = b_t2dn + c * G + g
            kind[q], rack[q], aux[q] = KIND_T2_DOWN, c, g
            nbr[q] = P + g * U1 + c // U2
            sw_of_q[q] = P + NA + c
    for s1 in range(NA):
        for i in range(Pg):
            q = b_t1dn + s1 * Pg + i
            r = (s1 // U1) * Pg + i if three else i
            kind[q], rack[q], aux[q] = KIND_T1_DOWN, r, s1
            nbr[q] = r
            sw_of_q[q] = P + s1
    for n in range(N):
        q = b_t0dn + n
        kind[q], rack[q], aux[q] = KIND_T0_DOWN, n // M, n
        sw_of_q[q] = n // M
    for n in range(N):
        e = nq + n
        kind[e], rack[e], aux[e] = KIND_SENDER, n // M, n
        nbr[e] = n // M

    # ---- compact enqueue emitters + per-switch fan-in groups ----
    # Ascending emitter order inside each group: the enqueue rank of an
    # emitter is the count of *smaller-id* emitters enqueueing to the same
    # queue, and same-queue emitters always share a feeding switch, so the
    # in-group slot order reproduces the global emitter order exactly.
    # Groups index the *compact* enumeration (also ascending, so the order
    # argument carries over verbatim): the whole enqueue path then runs on
    # EQ = ne - N rows instead of ne.
    enq_ids = np.where(nbr >= 0)[0].astype(np.int32)
    eq = len(enq_ids)
    compact = np.full(ne, eq, np.int32)
    compact[enq_ids] = np.arange(eq, dtype=np.int32)
    fan = [[] for _ in range(nsw)]
    for e in enq_ids:
        fan[nbr[e]].append(int(compact[e]))
    fan_max = max(len(g) for g in fan)
    in_tbl = np.full((nsw, fan_max), eq, np.int32)
    in_pos = np.zeros(eq, np.int32)
    for s, group in enumerate(fan):
        for k, j in enumerate(group):
            in_tbl[s, k] = j
            in_pos[j] = s * fan_max + k

    return Topology(tree=tree, n_queues=nq, n_emitters=ne, n_switches=nsw,
                    kind=kind, rack=rack, aux=aux, nbr_sw=nbr,
                    sw_tier=sw_tier, sw_lo=sw_lo, sw_hi=sw_hi,
                    sw_up_base=sw_up_base, sw_up_cnt=sw_up_cnt,
                    sw_salt=sw_salt, down_tbl=down_tbl,
                    dn_base=dn_base, dn_stride=dn_stride, sw_of_q=sw_of_q,
                    enq_ids=enq_ids, fan_max=fan_max, in_tbl=in_tbl,
                    in_pos=in_pos)
