"""Tier-generic fat-tree topology: static port enumeration + routing tables.

Queue (output-port) layout, indexed contiguously; empty blocks vanish, so a
two-tier tree reproduces the historical layout exactly:

  t0_up[r, a]    : rack r's uplink to T1 switch a        (P * U1 ports)
  t1_up[s1, j]   : T1 switch s1's uplink to the core     (3-tier only)
  t2_down[c, g]  : core c's downlink to pod g            (3-tier only)
  t1_down[s1, i] : T1 switch s1's downlink to its i-th rack
  t0_down[node]  : rack's downlink to a host NIC         (last N queues)

Emitters (anything that can place one packet per tick onto a wire):
  ids [0, NQ)            : the queues above
  ids [NQ, NQ + N)       : host NICs (senders)

Every queue below the t0_down block faces a switch; the t0_down block faces
hosts — so wire latency stays uniform within three contiguous emitter
classes (switch-facing, host-facing, sender NICs), which the fabric's
dynamic-update-slice wire writes rely on.

Routing is table-driven and purely functional: each emitter names the
switch its wire feeds (``nbr_sw``), and each switch carries its subtree
interval ``[sw_lo, sw_hi)`` of host nodes, a dense down-port table
``down_tbl[sw, dst]``, and its contiguous run of equal-cost up ports
(``sw_up_base``/``sw_up_cnt``).  A packet at a switch goes *down* via one
gather when dst is in the subtree, else *up* via an ECMP hash of the packet
entropy with the per-switch salt ``sw_salt`` — exactly like switch ECMP
hashing a header field (paper Sec. 3.6); on a three-tier tree the same hash
selects among core paths at the T1 tier.  ``fabric.route_switch`` is the
(single) jax consumer of these tables.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.units import FatTreeConfig

KIND_T0_UP = 0
KIND_T1_DOWN = 1
KIND_T0_DOWN = 2
KIND_SENDER = 3
KIND_T1_UP = 4
KIND_T2_DOWN = 5

HOST = -1  # nbr_sw sentinel: this port's wire ends at a host NIC

# the historical per-rack ECMP salt formula, now applied per switch id
# (rack switch ids equal rack indices, so two-tier hashes are unchanged)
SALT_MUL = 0x9E37
SALT_ADD = 0x1234


@dataclasses.dataclass(frozen=True)
class Topology:
    tree: FatTreeConfig
    n_queues: int
    n_emitters: int
    n_switches: int
    # per-emitter static arrays (numpy; moved to device by the engine)
    kind: np.ndarray        # [E] emitter kind
    rack: np.ndarray        # [E] rack (T0) / T1 index / core index
    aux: np.ndarray         # [E] uplink / local-rack / node auxiliary index
    nbr_sw: np.ndarray      # [E] switch this emitter's wire feeds (HOST = -1)
    # per-switch routing tables (switch ids: racks [0, P), T1 [P, P+n_t1),
    # cores [P+n_t1, P+n_t1+n_cores))
    sw_tier: np.ndarray     # [NSW] 0 = rack, 1 = T1, 2 = core
    sw_lo: np.ndarray       # [NSW] subtree host interval [lo, hi)
    sw_hi: np.ndarray
    sw_up_base: np.ndarray  # [NSW] first up-port queue id
    sw_up_cnt: np.ndarray   # [NSW] equal-cost up ports (0 at the top tier)
    sw_salt: np.ndarray     # [NSW] uint32 per-switch ECMP hash salt
    down_tbl: np.ndarray    # [NSW, N] down-port queue id toward each node

    # ---- queue-id helpers (block bases precomputed in build_topology) ----

    def t0_up(self, r: int, a: int) -> int:
        return r * self.tree.uplinks + a

    def t1_up(self, s1: int, j: int) -> int:
        """T1 switch ``s1`` (pod-major: g * uplinks + a), core uplink j."""
        t = self.tree
        if not t.pods:
            raise ValueError("t1_up ports exist only on three-tier trees")
        return t.racks * t.uplinks + s1 * t.core_uplinks + j

    def t2_down(self, c: int, g: int) -> int:
        """Core switch ``c`` (= a * core_uplinks + j), downlink to pod g."""
        t = self.tree
        if not t.pods:
            raise ValueError("t2_down ports exist only on three-tier trees")
        return (t.racks * t.uplinks + t.n_t1 * t.core_uplinks
                + c * t.pods + g)

    def t1_down(self, s1: int, i: int) -> int:
        """T1 switch ``s1``'s downlink to its i-th rack (two-tier: spine
        s1's downlink to rack i — the historical (k, r) layout)."""
        t = self.tree
        base = (t.racks * t.uplinks + t.n_t1 * t.core_uplinks
                + t.n_cores * t.pods)
        return base + s1 * t.racks_per_pod + i

    def t0_down(self, node: int) -> int:
        return self.n_queues - self.tree.n_nodes + node

    def sender(self, node: int) -> int:
        return self.n_queues + node

    # ---- switch-id helpers ----

    def rack_sw(self, r: int) -> int:
        return r

    def t1_sw(self, s1: int) -> int:
        return self.tree.racks + s1

    def core_sw(self, c: int) -> int:
        return self.tree.racks + self.tree.n_t1 + c


def build_topology(tree: FatTreeConfig) -> Topology:
    P, U1, M, N = tree.racks, tree.uplinks, tree.nodes_per_rack, tree.n_nodes
    three = tree.tiers == 3
    G = tree.pods if three else 1
    Pg = tree.racks_per_pod                  # racks per T1 subtree
    U2 = tree.core_uplinks
    NA = tree.n_t1                           # T1 switch count
    C = tree.n_cores

    b_t1up = P * U1
    b_t2dn = b_t1up + NA * U2
    b_t1dn = b_t2dn + C * G
    b_t0dn = b_t1dn + NA * Pg
    nq = b_t0dn + N
    ne = nq + N

    kind = np.zeros(ne, np.int32)
    rack = np.zeros(ne, np.int32)
    aux = np.zeros(ne, np.int32)
    nbr = np.full(ne, HOST, np.int32)

    nsw = P + NA + C
    sw_tier = np.zeros(nsw, np.int32)
    sw_lo = np.zeros(nsw, np.int32)
    sw_hi = np.zeros(nsw, np.int32)
    sw_up_base = np.zeros(nsw, np.int32)
    sw_up_cnt = np.zeros(nsw, np.int32)
    node_rack = np.arange(N, dtype=np.int32) // M

    # ---- switches ----
    for r in range(P):
        sw_tier[r] = 0
        sw_lo[r], sw_hi[r] = r * M, (r + 1) * M
        sw_up_base[r], sw_up_cnt[r] = r * U1, U1
    for s1 in range(NA):
        sw = P + s1
        sw_tier[sw] = 1
        if three:
            g = s1 // U1
            sw_lo[sw], sw_hi[sw] = g * Pg * M, (g + 1) * Pg * M
            sw_up_base[sw] = b_t1up + s1 * U2
            sw_up_cnt[sw] = U2
        else:
            sw_lo[sw], sw_hi[sw] = 0, N     # spine: whole fabric below
    for c in range(C):
        sw = P + NA + c
        sw_tier[sw] = 2
        sw_lo[sw], sw_hi[sw] = 0, N
    sw_salt = (np.arange(nsw, dtype=np.uint32) * np.uint32(SALT_MUL)
               + np.uint32(SALT_ADD))

    # ---- down-port tables (dense per switch; rows are exact inside the
    #      switch's subtree, entries outside it are never routed to) ----
    down_tbl = np.zeros((nsw, N), np.int32)
    down_tbl[:P] = b_t0dn + np.arange(N, dtype=np.int32)[None, :]
    for s1 in range(NA):
        if three:
            g = s1 // U1
            i = np.clip(node_rack - g * Pg, 0, Pg - 1)
        else:
            i = node_rack
        down_tbl[P + s1] = b_t1dn + s1 * Pg + i
    for c in range(C):
        down_tbl[P + NA + c] = b_t2dn + c * G + node_rack // Pg

    # ---- ports ----
    for r in range(P):
        for a in range(U1):
            q = r * U1 + a
            kind[q], rack[q], aux[q] = KIND_T0_UP, r, a
            nbr[q] = P + ((r // Pg) * U1 + a if three else a)
    for s1 in range(NA):
        for j in range(U2):
            q = b_t1up + s1 * U2 + j
            kind[q], rack[q], aux[q] = KIND_T1_UP, s1, j
            nbr[q] = P + NA + (s1 % U1) * U2 + j
    for c in range(C):
        for g in range(G):
            q = b_t2dn + c * G + g
            kind[q], rack[q], aux[q] = KIND_T2_DOWN, c, g
            nbr[q] = P + g * U1 + c // U2
    for s1 in range(NA):
        for i in range(Pg):
            q = b_t1dn + s1 * Pg + i
            r = (s1 // U1) * Pg + i if three else i
            kind[q], rack[q], aux[q] = KIND_T1_DOWN, r, s1
            nbr[q] = r
    for n in range(N):
        q = b_t0dn + n
        kind[q], rack[q], aux[q] = KIND_T0_DOWN, n // M, n
    for n in range(N):
        e = nq + n
        kind[e], rack[e], aux[e] = KIND_SENDER, n // M, n
        nbr[e] = n // M

    return Topology(tree=tree, n_queues=nq, n_emitters=ne, n_switches=nsw,
                    kind=kind, rack=rack, aux=aux, nbr_sw=nbr,
                    sw_tier=sw_tier, sw_lo=sw_lo, sw_hi=sw_hi,
                    sw_up_base=sw_up_base, sw_up_cnt=sw_up_cnt,
                    sw_salt=sw_salt, down_tbl=down_tbl)
