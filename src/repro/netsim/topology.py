"""Two-tier fat-tree topology: static port enumeration + routing constants.

Queue (output-port) layout, indexed contiguously:

  t0_up[r, k]   : rack r's uplink to spine k          ids [0, P*U)
  t1_down[k, r] : spine k's downlink to rack r        ids [P*U, 2*P*U)
  t0_down[node] : rack's downlink to a host NIC       ids [2*P*U, 2*P*U + N)

Emitters (anything that can place one packet per tick onto a wire):
  ids [0, NQ)            : the queues above
  ids [NQ, NQ + N)       : host NICs (senders)

Routing is purely functional: (emitter, dst_node, entropy) -> next queue id,
with negative ids encoding delivery to node (-(node+1)).  ECMP uplink choice
hashes the packet entropy with a per-rack salt, exactly like switch ECMP
hashing a header field (paper Sec. 3.6).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.units import FatTreeConfig

KIND_T0_UP = 0
KIND_T1_DOWN = 1
KIND_T0_DOWN = 2
KIND_SENDER = 3


@dataclasses.dataclass(frozen=True)
class Topology:
    tree: FatTreeConfig
    n_queues: int
    n_emitters: int
    # per-emitter static arrays (numpy; moved to device by the engine)
    kind: np.ndarray        # [E] emitter kind
    rack: np.ndarray        # [E] rack of the emitter (or spine for t1_down)
    aux: np.ndarray         # [E] spine index (t0_up), rack (t1_down), node (t0_down/sender)

    def t0_up(self, r: int, k: int) -> int:
        return r * self.tree.uplinks + k

    def t1_down(self, k: int, r: int) -> int:
        return self.tree.racks * self.tree.uplinks + k * self.tree.racks + r

    def t0_down(self, node: int) -> int:
        return 2 * self.tree.racks * self.tree.uplinks + node

    def sender(self, node: int) -> int:
        return self.n_queues + node


def build_topology(tree: FatTreeConfig) -> Topology:
    P, U, M, N = tree.racks, tree.uplinks, tree.nodes_per_rack, tree.n_nodes
    nq = 2 * P * U + N
    ne = nq + N
    kind = np.zeros(ne, np.int32)
    rack = np.zeros(ne, np.int32)
    aux = np.zeros(ne, np.int32)
    for r in range(P):
        for k in range(U):
            q = r * U + k
            kind[q], rack[q], aux[q] = KIND_T0_UP, r, k
    for k in range(U):
        for r in range(P):
            q = P * U + k * P + r
            kind[q], rack[q], aux[q] = KIND_T1_DOWN, r, k
    for n in range(N):
        q = 2 * P * U + n
        kind[q], rack[q], aux[q] = KIND_T0_DOWN, n // M, n
    for n in range(N):
        e = nq + n
        kind[e], rack[e], aux[e] = KIND_SENDER, n // M, n
    return Topology(tree=tree, n_queues=nq, n_emitters=ne, kind=kind, rack=rack, aux=aux)
