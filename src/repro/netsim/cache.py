"""Content-addressed result cache for Study lanes (DESIGN.md Sec. 7).

Re-running a sweep should only pay for what changed.  Each lane of a
Study — one ``(scenario, point, seed)`` cell — is keyed by

    lane_key = sha256(scenario_digest · normalized point · seed ·
                      code_digest)

where ``scenario_digest`` fingerprints everything the lane's trajectory
depends on (config repr, the full flow table bytes, the tick budget) and
``code_digest`` fingerprints the simulator source tree itself
(``repro/netsim`` + ``repro/kernels`` + ``repro/core``, every ``.py``
file's bytes) — so editing any engine/phase/kernel source invalidates
every cached lane, while editing tests, benchmarks, or docs does not.
The engine is deterministic (pure jit, fixed seeds), which is what makes
final states cacheable by input identity at all.

A hit returns the lane's **full final SimState** (host numpy, bit-exact
— ``tests/test_cache.py`` asserts digest equality against a fresh run)
plus the precomputed ``RunResult.row()``; the Study stitches hits and
fresh lanes into one ``StudyResult`` indistinguishable from an uncached
run.  Entries are written atomically (tmp + rename), one ``.npz`` (state
leaves) + ``.json`` (row, state digest, human-readable key fields) pair
per lane, so a killed grid resumes from every lane already finished
(``Study.run(chunk_lanes=...)`` flushes per completed chunk).

Stale entries are never wrong, only unused: a key mismatch (new code,
new point, new budget) simply misses.  ``ResultCache.prune()`` drops
entries whose recorded code digest is not the current one.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro.netsim.scenarios import Scenario

# cache format version — bump to orphan every existing entry
_VERSION = 1

# source trees whose bytes define the simulator's behavior (repro.core
# carries the CC algorithms; repro.kernels the pallas/jnp backend pairs)
_CODE_PACKAGES = ("repro.netsim", "repro.kernels", "repro.core")


# --------------------------------------------------------------------------
# digests
# --------------------------------------------------------------------------


def _hash_tree_files(roots) -> str:
    h = hashlib.sha256()
    for root in roots:
        root = Path(root)
        for p in sorted(root.rglob("*.py")):
            h.update(str(p.relative_to(root)).encode())
            h.update(b"\0")
            h.update(p.read_bytes())
            h.update(b"\0")
    return h.hexdigest()


@functools.lru_cache(maxsize=1)
def _default_code_digest() -> str:
    import importlib
    roots = []
    for mod in _CODE_PACKAGES:
        m = importlib.import_module(mod)
        # namespace packages (no __init__.py) carry __path__, not __file__
        roots.extend(Path(p) for p in getattr(m, "__path__", None)
                     or [Path(m.__file__).parent])
    return _hash_tree_files(roots)


def code_digest(roots=None) -> str:
    """sha256 over the simulator source tree (sorted relpath + bytes of
    every ``.py`` under ``repro/{netsim,kernels,core}``, or under the
    explicit ``roots``).  Any source edit — an algorithm tweak, a kernel
    fix — changes the digest and orphans every cached lane; the default
    digest is computed once per process."""
    if roots is None:
        return _default_code_digest()
    return _hash_tree_files(tuple(roots))


def _update_value(h, v):
    """Feed one digest component: arrays by dtype/shape/bytes, dataclasses
    by stable repr, scalars/strings by repr."""
    if isinstance(v, np.ndarray):
        a = np.ascontiguousarray(v)
        h.update(f"{a.dtype.str}{a.shape}".encode())
        h.update(a.tobytes())
    else:
        h.update(repr(v).encode())
    h.update(b"\0")


def scenario_digest(sc: Scenario, max_ticks: int) -> str:
    """Fingerprint of everything a lane's trajectory depends on besides
    (point, seed, code): the scenario name, the full ``SimConfig`` repr
    (frozen dataclass of primitives/tuples — stable), the workload's flow
    table bytes, and the effective tick budget."""
    h = hashlib.sha256()
    _update_value(h, ("netsim-scenario", _VERSION))
    _update_value(h, sc.name)
    _update_value(h, sc.cfg)
    wl = sc.wl
    _update_value(h, (wl.name, int(wl.window)))
    for arr in (wl.src, wl.dst, wl.size, wl.t_start, wl.order):
        _update_value(h, np.asarray(arr))
    # dependency table + collective grouping; the "none" marker keeps an
    # absent column distinguishable from any real array
    for arr in (wl.dep_par, wl.dep_thr, wl.coll_id):
        _update_value(h, "none" if arr is None else np.asarray(arr))
    _update_value(h, int(max_ticks))
    return h.hexdigest()


def lane_key(scenario_dig: str, point, seed: int,
             code_dig: str | None = None) -> str:
    """Content address of one Study lane.  ``point`` is the normalized
    ``((key, value), ...)`` tuple (``api._norm_point``)."""
    if code_dig is None:
        code_dig = code_digest()
    h = hashlib.sha256()
    _update_value(h, ("netsim-lane", _VERSION))
    _update_value(h, scenario_dig)
    _update_value(h, tuple(point))
    _update_value(h, int(seed))
    _update_value(h, code_dig)
    return h.hexdigest()


def state_digest(tree) -> str:
    """sha256 over a (host) state pytree — dtype/shape/bytes of every
    leaf.  The bit-for-bit equality currency of the parity tests and the
    cache-integrity check."""
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        _update_value(h, np.asarray(leaf))
    return h.hexdigest()


# --------------------------------------------------------------------------
# the cache
# --------------------------------------------------------------------------


DEFAULT_DIR_ENV = "NETSIM_CACHE_DIR"


def default_root() -> Path:
    """``$NETSIM_CACHE_DIR`` or ``.netsim_cache`` under the CWD."""
    return Path(os.environ.get(DEFAULT_DIR_ENV, ".netsim_cache"))


@dataclasses.dataclass(eq=False, repr=False)
class ResultCache:
    """Directory-backed lane cache: ``<key>.npz`` (final-state leaves, in
    treedef order) + ``<key>.json`` (row, state digest, key fields).

    Mutable counters ``hits``/``misses``/``puts`` account one ``Study.run``
    (reset per run by the Study) — surfaced on ``StudyResult`` and in the
    ``study_throughput`` bench section so the "repeated sweeps are free"
    claim is measured, not asserted."""

    root: Path
    hits: int = 0
    misses: int = 0
    puts: int = 0

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)

    def reset_counters(self):
        self.hits = self.misses = self.puts = 0

    def _paths(self, key: str) -> tuple[Path, Path]:
        return self.root / f"{key}.npz", self.root / f"{key}.json"

    def get(self, key: str, struct):
        """Look up one lane.  ``struct`` is the lane's ``SimState``
        shape/dtype skeleton (``jax.eval_shape`` of the init) — entries
        whose leaves don't match it exactly (layout drift the code digest
        didn't catch, e.g. partially-written legacy files) are treated as
        misses.  Returns ``(state, row)`` host-side, or ``None``."""
        npz_p, json_p = self._paths(key)
        if not (npz_p.exists() and json_p.exists()):
            self.misses += 1
            return None
        try:
            meta = json.loads(json_p.read_text())
            leaves_s, treedef = jax.tree_util.tree_flatten(struct)
            with np.load(npz_p) as z:
                leaves = [z[f"leaf_{i}"] for i in range(len(leaves_s))]
        except Exception:
            self.misses += 1
            return None
        for got, want in zip(leaves, leaves_s):
            if (got.shape != tuple(want.shape)
                    or got.dtype != np.dtype(want.dtype)):
                self.misses += 1
                return None
        self.hits += 1
        return jax.tree_util.tree_unflatten(treedef, leaves), meta["row"]

    def put(self, key: str, lane_state, row: dict, extra: dict | None = None):
        """Write one finished lane atomically (tmp + rename — a killed
        writer leaves no partial entry, so resume is always safe)."""
        npz_p, json_p = self._paths(key)
        leaves = [np.asarray(x) for x in
                  jax.tree_util.tree_leaves(lane_state)]
        meta = dict(version=_VERSION, row=row,
                    state_digest=state_digest(lane_state),
                    **(extra or {}))
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **{f"leaf_{i}": x for i, x in enumerate(leaves)})
            os.replace(tmp, npz_p)
        except BaseException:
            os.unlink(tmp)
            raise
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(meta, f)
            os.replace(tmp, json_p)
        except BaseException:
            os.unlink(tmp)
            raise
        self.puts += 1

    def prune(self, keep_code_dig: str | None = None) -> int:
        """Drop entries not written under ``keep_code_dig`` (default: the
        current code digest).  Returns the number of entries removed."""
        if keep_code_dig is None:
            keep_code_dig = code_digest()
        n = 0
        for json_p in self.root.glob("*.json"):
            try:
                meta = json.loads(json_p.read_text())
            except Exception:
                meta = {}
            if meta.get("code_digest") != keep_code_dig:
                json_p.unlink(missing_ok=True)
                json_p.with_suffix(".npz").unlink(missing_ok=True)
                n += 1
        return n

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.json"))

    def __repr__(self) -> str:
        return (f"ResultCache({self.root}: {len(self)} entries, "
                f"hits={self.hits} misses={self.misses} puts={self.puts})")


def resolve(cache) -> ResultCache | None:
    """Normalize ``Study.run``'s ``cache=`` argument: ``None`` -> no
    caching, ``True`` -> the default directory, a path -> that directory,
    a :class:`ResultCache` -> itself."""
    if cache is None or isinstance(cache, ResultCache):
        return cache
    if cache is True:
        return ResultCache(default_root())
    return ResultCache(Path(cache))
