"""Phase 6 — metrics accounting — plus the host-side result extraction.

``Metrics`` is the per-run counter bundle threaded through every phase;
``account`` is the end-of-tick occupancy accounting; ``summarize`` pulls a
finished run back to the host.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

I32 = jnp.int32
F32 = jnp.float32

HIST_BINS = 64     # RTT histogram bins, width = brtt/8
GOODPUT_BINS = 64  # delivered-bytes history bins (Consts.goodput_bin
                   # ticks wide; drives the recovery dip/TTR metrics)


class Metrics(NamedTuple):
    n_trim: jnp.ndarray
    n_drop: jnp.ndarray
    n_black: jnp.ndarray
    n_to: jnp.ndarray
    n_retx: jnp.ndarray
    n_ack: jnp.ndarray
    delivered_pkts: jnp.ndarray
    delivered_bytes: jnp.ndarray
    rtt_hist: jnp.ndarray        # [HIST_BINS]
    q_sum: jnp.ndarray           # sum over (ticks, ports) of occupancy
    q_max: jnp.ndarray
    spurious_retx: jnp.ndarray   # retransmitted packets that had been delivered
    # recovery metrics (only accrued when a fault schedule is present;
    # updated exclusively on delivery ticks, so leap-exact with no
    # leap_account term)
    delivered_bytes_fault: jnp.ndarray  # bytes delivered while fault-active
    goodput_hist: jnp.ndarray           # f32 [GOODPUT_BINS] binned bytes


def init_metrics() -> Metrics:
    i = lambda: jnp.zeros((), I32)
    f = lambda: jnp.zeros((), F32)
    return Metrics(
        n_trim=i(),
        n_drop=i(),
        n_black=i(),
        n_to=i(),
        n_retx=i(),
        n_ack=i(),
        delivered_pkts=i(),
        delivered_bytes=f(),
        rtt_hist=jnp.zeros((HIST_BINS,), I32),
        q_sum=f(),
        q_max=i(),
        spurious_retx=i(),
        delivered_bytes_fault=f(),
        goodput_hist=jnp.zeros((GOODPUT_BINS,), F32),
    )


def account(dims, consts, st):
    """Phase 6: per-tick occupancy accounting over the fabric queues."""
    del consts
    m = st.m
    q = st.q_size[:dims.NQ]
    m = m._replace(
        q_sum=m.q_sum + jnp.sum(q).astype(F32),
        q_max=jnp.maximum(m.q_max, jnp.max(q)),
    )
    return st._replace(m=m)


def leap_account(m: Metrics, dt, occupancy) -> Metrics:
    """Closed-form ``dt``-tick occupancy integral for a time leap
    (DESIGN.md Sec. 6.3): the linear form ``dt * occupancy`` replaces
    ``dt`` sequential executions of ``account``.

    Bitwise exact, not approximate: the leap predicate only yields
    ``dt > 0`` with every port empty (an occupied port departs every
    tick), so the integral contributes exactly 0.0 and ``q_max`` — the
    running max of an unchanged occupancy — needs no update.  Broadcasts
    over a leading batch axis (``occupancy`` per element, scalar ``dt``).
    """
    return m._replace(
        q_sum=m.q_sum + dt.astype(F32) * occupancy.astype(F32))


# --------------------------------------------------------------------------
# result extraction
# --------------------------------------------------------------------------


def summarize(sim, st) -> dict:
    """Pull host-side summary statistics from a finished run."""
    fct = np.asarray(st.fct)
    done = np.asarray(st.done)
    mtu = sim.dims.mtu
    m = st.m
    out = dict(
        ticks=int(st.now),
        all_done=bool(done.all()),
        n_done=int(done.sum()),
        fct_ticks=fct,
        fct_max=int(fct.max()) if done.any() else -1,
        fct_min=int(fct[done].min()) if done.any() else -1,
        fct_mean=float(fct[done].mean()) if done.any() else -1.0,
        fct_p99=float(np.percentile(fct[done], 99)) if done.any() else -1.0,
        spread=float(fct[done].max() - fct[done].min()) if done.any() else -1.0,
        trims=int(m.n_trim), drops=int(m.n_drop), blackholed=int(m.n_black),
        timeouts=int(m.n_to), retx=int(m.n_retx), acks=int(m.n_ack),
        delivered_bytes=float(m.delivered_bytes),
        delivered_bytes_fault=float(m.delivered_bytes_fault),
        goodput_hist=np.asarray(m.goodput_hist),
        spurious_retx=int(m.spurious_retx),
        rtt_hist=np.asarray(m.rtt_hist),
        q_mean=float(m.q_sum) / max(1, int(st.now)) / sim.dims.NQ,
        q_max=int(m.q_max),
        goodput_bytes=np.asarray(st.goodput),
    )
    total_pkts = max(1, int(m.delivered_pkts))
    out["spurious_frac"] = out["spurious_retx"] / total_pkts
    # ideal completion: bytes through the tightest static bottleneck
    out["mtu"] = mtu
    return out


def jain_fairness(values: np.ndarray) -> float:
    v = np.asarray(values, np.float64)
    if v.sum() == 0:
        return 1.0
    return float(v.sum() ** 2 / (len(v) * (v ** 2).sum()))
