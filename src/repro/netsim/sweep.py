"""Batched config-sweep runner — thin compatibility wrapper over the
experiment API (``netsim/api.py``, DESIGN.md Sec. 7).

Every numeric knob of the simulator is *traced* (it lives in ``Consts``,
not in the closed-over ``Dims``), so evaluating N parameter settings of
the same (topology, workload, algorithm) needs one compilation, not N.
New code should call ``api.study`` directly — it additionally crosses the
sweep with seed batches and returns typed results; ``build_sweep`` keeps
the historical shape::

    points = [{"start_cwnd_mult": a, "react_every": r}
              for a in (0.5, 1.25) for r in (1, 2, 4, 8)]
    sw = build_sweep(SimConfig(algo="smartt"), wl, points)
    states = sw.run(max_ticks=30000)        # [B]-batched SimState
    rows = sw.summaries(states)             # one summarize() dict per point

Sweepable keys are ``api.CFG_KEYS | api.CC_PARAM_KEYS`` (re-exported
here); anything per-point that would change ``Dims`` raises at build
time.  The run loop is the api lane loop: one compiled step per grid,
with each point gated on its own exit predicate and leaping by its own
event horizon — so every point's final state (``now`` and metrics
included) is bit-for-bit the standalone ``engine.build(...).run()`` of
that config (tests/test_api.py).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import numpy as np

from repro.netsim import api, engine, metrics, state
from repro.netsim.api import (CC_PARAM_KEYS, CFG_KEYS,  # noqa: F401 (re-export)
                              apply_point)
from repro.netsim.scenarios import Scenario


@dataclasses.dataclass(frozen=True, eq=False)
class Sweep:
    """A planned N-point grid (an ``api.Study`` with a single seed)."""

    study: api.Study

    @property
    def sim(self) -> engine.Sim:
        return self.study.sim

    @property
    def points(self) -> tuple:
        return tuple(dict(p) for p in self.study.points)

    @property
    def n_points(self) -> int:
        return self.study.n_points

    @property
    def consts_b(self) -> state.Consts:
        return self.study.consts_b

    @property
    def axes(self) -> state.Consts:
        return self.study.axes

    def init(self) -> state.SimState:
        return self.study.init()

    def run(self, max_ticks: int) -> state.SimState:
        """Run all points to completion; one step compilation total.
        The freshly built [B]-batched state is donated to the run loop."""
        return self.study.run_states(max_ticks=max_ticks)

    def summaries(self, states: state.SimState) -> list:
        """Per-point summaries.  Each point ran under its own exit gate,
        so per-point time fields (``ticks``, ``q_mean``) are exactly the
        standalone run's — directly comparable across points and against
        standalone runs."""
        return summarize_batch(self.sim, states)


def build_sweep(cfg: state.SimConfig, wl,
                points: Sequence[Mapping[str, float]]) -> Sweep:
    if not points:
        raise ValueError("empty sweep")
    sc = Scenario(name=getattr(wl, "name", "sweep"), cfg=cfg, wl=wl)
    return Sweep(study=api.study(sc, points=points))


def summarize_batch(sim: engine.Sim, states: state.SimState) -> list:
    """One host-side summarize() dict per sweep point."""
    b_dim = np.asarray(states.done).shape[0]
    return [metrics.summarize(sim, jax.tree.map(lambda x: x[b], states))
            for b in range(b_dim)]
