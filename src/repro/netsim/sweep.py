"""Batched config-sweep runner: an N-point parameter grid for one compile.

Every numeric knob of the simulator is *traced* (it lives in ``Consts``,
not in the closed-over ``Dims``), so evaluating N parameter settings of the
same (topology, workload, algorithm) does not need N compilations — it
needs one ``vmap`` of the already-composed step over a batch of ``Consts``
where only the swept leaves carry a leading [B] axis.

Sweepable keys (any mix per point):
  * CC algorithm constants — the ``make_cc_params`` tuning kwargs
    (``fd``, ``md``, ``fi``, ``k_fast``, ``qa_scaling``, ``wtd_alpha``,
    ``wtd_thresh``, ``fi_rtt_tol``, ``target_mult``, ``maxcwnd_mult``,
    ``sw_ai``, ``sw_beta``, ``sw_max_mdf``)
  * numeric ``SimConfig`` fields — ``start_cwnd_mult``, ``react_every``,
    ``rto_mult``, ``credit_window_mult``, ``kmin_frac``, ``kmax_frac``,
    ``num_entropies``, ``fault_start``

Usage::

    points = [{"start_cwnd_mult": a, "react_every": r}
              for a in (0.5, 1.25) for r in (1, 2, 4, 8)]
    sw = build_sweep(SimConfig(algo="smartt"), wl, points)
    states = sw.run(max_ticks=30000)        # [B]-batched SimState
    rows = sw.summaries(states)             # one summarize() dict per point

The static shape of the run (tree, workload, algorithm, backend, lb,
trimming) must agree across points; anything per-point that would change
``Dims`` raises at build time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim import engine, metrics, state

# make_cc_params tuning kwargs routable through SimConfig.cc_overrides
CC_PARAM_KEYS = frozenset({
    "target_mult", "fd", "md", "fi", "k_fast", "qa_scaling", "wtd_alpha",
    "wtd_thresh", "fi_rtt_tol", "maxcwnd_mult", "sw_ai", "sw_beta",
    "sw_max_mdf",
})
# numeric SimConfig fields that stay inside Consts (no Dims impact)
CFG_KEYS = frozenset({
    "rto_mult", "react_every", "credit_window_mult", "start_cwnd_mult",
    "kmin_frac", "kmax_frac", "num_entropies", "fault_start",
})


def apply_point(cfg: state.SimConfig, point: Mapping[str, float]) -> state.SimConfig:
    """Fold one sweep point into a SimConfig (cc keys -> cc_overrides)."""
    cfg_kw = {}
    cc = dict(cfg.cc_overrides)
    for k, v in point.items():
        if k in CFG_KEYS:
            cfg_kw[k] = v
        elif k in CC_PARAM_KEYS:
            cc[k] = v
        else:
            raise KeyError(
                f"unsweepable key {k!r}; numeric keys are "
                f"{sorted(CFG_KEYS | CC_PARAM_KEYS)}")
    return dataclasses.replace(cfg, cc_overrides=tuple(sorted(cc.items())),
                               **cfg_kw)


@dataclasses.dataclass(frozen=True)
class Sweep:
    """A compiled simulator plus a [B]-batched Consts bundle."""

    sim: engine.Sim
    points: tuple
    consts_b: state.Consts       # swept leaves carry a leading [B] axis
    axes: state.Consts           # matching vmap in_axes tree (0 / None)

    @property
    def n_points(self) -> int:
        return len(self.points)

    def init(self) -> state.SimState:
        dims = self.sim.dims
        return jax.vmap(lambda c: state.init_state(dims, c),
                        in_axes=(self.axes,),
                        axis_size=self.n_points)(self.consts_b)

    def run(self, max_ticks: int) -> state.SimState:
        """Run all points to completion; one step compilation total.
        The freshly built [B]-batched state is donated to the run loop."""
        horizon_fn = self.sim.horizon_fn if self.sim.dims.leap else None
        return _run_sweep(self.sim.step_fn, horizon_fn, self.axes, max_ticks,
                          self.sim.dims.superstep, self.consts_b, self.init())

    def summaries(self, states: state.SimState) -> list:
        """Per-point summaries.  Per-flow results (fct/goodput/trims) are
        exact; time-integral fields (``ticks``, ``q_mean``) reflect the
        grid's *shared* run length — all points tick until the slowest
        finishes — so compare those across points, not against standalone
        runs."""
        return summarize_batch(self.sim, states)


def build_sweep(cfg: state.SimConfig, wl,
                points: Sequence[Mapping[str, float]]) -> Sweep:
    if not points:
        raise ValueError("empty sweep")
    sim = engine.build(cfg, wl)
    # derive() is re-run per point: that repeats the O(NF) structural host
    # loops, but keeps a single source of truth for Consts derivation.
    # Host-side cost is negligible next to the device run; identical leaves
    # are deduplicated below.
    consts_list = [sim.consts if not pt else
                   state.derive(apply_point(cfg, pt), wl)[3] for pt in points]

    flats, treedef = zip(*[jax.tree_util.tree_flatten(c) for c in consts_list])
    if any(td != treedef[0] for td in treedef[1:]):
        raise ValueError("sweep points disagree on Consts structure")
    leaves, axes_leaves = [], []
    for slot in zip(*flats):
        x0 = np.asarray(slot[0])
        if all(np.array_equal(np.asarray(x), x0) for x in slot[1:]):
            leaves.append(slot[0])
            axes_leaves.append(None)
        else:
            leaves.append(jnp.stack([jnp.asarray(x) for x in slot]))
            axes_leaves.append(0)
    consts_b = jax.tree_util.tree_unflatten(treedef[0], leaves)
    axes = jax.tree_util.tree_unflatten(treedef[0], axes_leaves)
    return Sweep(sim=sim, points=tuple(dict(p) for p in points),
                 consts_b=consts_b, axes=axes)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4), donate_argnums=(6,))
def _run_sweep(step_fn, horizon_fn, axes, max_ticks, superstep, consts_b,
               states):
    """Superstep-fused sweep loop: the all-done exit reduction (over flows
    *and* grid points) runs once per ``superstep`` ticks; each fused tick
    is gated on the same scalar predicate so trajectories stay bit-for-bit
    identical to the per-tick loop (engine.py run-loop contract).  With
    ``horizon_fn`` the loop also time-leaps by the min next-event distance
    over the grid (each point's horizon is computed under its own swept
    ``Consts``), per the engine's batched-leap contract."""
    vstep = jax.vmap(step_fn, in_axes=(axes, 0))

    def cond(st):
        return (st.now[0] < max_ticks) & ~jnp.all(st.done)

    def body(st):
        return vstep(consts_b, st)

    leap = None
    if horizon_fn is not None:
        vhorizon = jax.vmap(horizon_fn, in_axes=(axes, 0))
        leap = engine._leap_batched(lambda st: vhorizon(consts_b, st),
                                    max_ticks)
    return engine._superstep_loop(body, cond, superstep, leap)(states)


def summarize_batch(sim: engine.Sim, states: state.SimState) -> list:
    """One host-side summarize() dict per sweep point."""
    b_dim = np.asarray(states.done).shape[0]
    return [metrics.summarize(sim, jax.tree.map(lambda x: x[b], states))
            for b in range(b_dim)]
