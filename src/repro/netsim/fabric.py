"""Phases 1-2 of the tick — the switching fabric.

  1. ``departures``: dequeue head per port, RED dequeue-marking, route,
     blackhole on failed links, place on the wire
  2. ``arrivals``:  packets landing now -> enqueue (trim/drop on overflow)
     or deliver (receiver dedupe, ACK generation)

Both are pure ``(Dims, Consts, SimState) -> SimState``; they communicate
with the rest of the pipeline only through ``SimState`` fields (the wire
ring ``infl``, the delayed control rings, and the receiver ledgers).
Routing is purely functional over the per-emitter constants in ``Consts``.

``horizon`` is the phases' next-event reduction for the engine's
event-horizon time leaping (DESIGN.md Sec. 6.3): every delay ring keeps the
invariant that a *valid* entry is a genuinely in-flight event (slots are
zeroed when read), so "ticks until this phase next does work" is a cheap
reduction over the live slots.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.netsim import faults, hashing
from repro.netsim.metrics import GOODPUT_BINS
from repro.netsim.state import HORIZON_INF, Consts, Dims, SimState, pkt_size

I32 = jnp.int32
F32 = jnp.float32


def route_switch(dims: Dims, consts: Consts, sw, d, ent):
    """Table-driven next hop at switch ``sw`` for a packet to node ``d``
    carrying path entropy ``ent`` (all broadcastable arrays).

    *Down* when ``d`` lies in the switch's subtree interval: the
    run-length lookup ``dn_base[sw] + d // dn_stride[sw]`` (every tier's
    down ports cover the subtree in equal-length node runs — see
    ``topology.build_topology`` — so two [NSW] vectors replace the dense
    ``[NSW, N]`` table this used to gather through).  *Up* otherwise: an
    ECMP hash of the entropy with the per-switch salt selects among the
    switch's contiguous run of equal-cost up ports — at the T0 tier that
    picks the spine/agg, at the T1 tier of a three-tier tree the same
    hash (a different salt) picks the core path (paper Sec. 3.6)."""
    down = (d >= consts.sw_lo[sw]) & (d < consts.sw_hi[sw])
    cnt = consts.sw_up_cnt[sw]
    h = (hashing.hash2(ent.astype(jnp.uint32), consts.sw_salt[sw])
         % jnp.maximum(cnt, 1).astype(jnp.uint32)).astype(I32)
    return jnp.where(down, consts.dn_base[sw] + d // consts.dn_stride[sw],
                     consts.sw_up_base[sw] + h)


def route_from_queue(dims: Dims, consts: Consts, flow, ent):
    """Next queue for the packet departing each fabric port (``flow`` /
    ``ent`` are [NQ], one head-of-line packet per port; negative ids encode
    delivery to node -(id+1)).  Each port's wire feeds the switch
    ``consts.nbr_q`` names; the last N ports (``consts.edge_q``) feed host
    NICs and deliver.

    Same decision as :func:`route_switch` at ``sw = nbr_q``, but reading
    the per-queue tables ``q_*`` (the switch tables pre-gathered through
    ``nbr_q`` at derive time) — the only per-tick gather left is the
    flow -> dst lookup, which genuinely varies."""
    d = consts.dst[jnp.clip(flow, 0, dims.NF - 1)]
    down = (d >= consts.q_lo) & (d < consts.q_hi)
    h = (hashing.hash2(ent.astype(jnp.uint32), consts.q_salt)
         % jnp.maximum(consts.q_up_cnt, 1).astype(jnp.uint32)).astype(I32)
    nxt = jnp.where(down, consts.q_dn_base + d // consts.q_dn_stride,
                    consts.q_up_base + h)
    return jnp.where(consts.edge_q, -(d + 1), nxt)


def route_first_hop(dims: Dims, consts: Consts, ent):
    """First queue for a fresh packet of *every* flow (``ent`` is the
    [NF] per-flow entropy) — the tick's hot path.  The subtree test and
    the down queue are workload constants (``f_down`` / ``f_dn_q``), so
    the whole decision is a gather-free select over [NF] vectors — only
    the ECMP hash runs per tick."""
    h = (hashing.hash2(ent.astype(jnp.uint32), consts.f_salt)
         % jnp.maximum(consts.f_up_cnt, 1).astype(jnp.uint32)).astype(I32)
    return jnp.where(consts.f_down, consts.f_dn_q, consts.f_up_base + h)


def route_from_sender(dims: Dims, consts: Consts, f, ent):
    """First queue for a fresh packet of flow ``f`` carrying entropy
    ``ent``: the routing decision of the sender's rack switch (same-rack
    shortcut straight to the edge port, ECMP uplink hash otherwise).
    ``f`` and ``ent`` broadcast (the routing property tests walk
    [NF, 1] x [1, E] grids); the tick itself uses the all-flows
    :func:`route_first_hop`.  Same per-flow tables, same ints."""
    h = (hashing.hash2(ent.astype(jnp.uint32), consts.f_salt[f])
         % jnp.maximum(consts.f_up_cnt[f], 1).astype(jnp.uint32)
         ).astype(I32)
    return jnp.where(consts.f_down[f], consts.f_dn_q[f],
                     consts.f_up_base[f] + h)


def route_step(dims: Dims, consts: Consts, q, d, ent):
    """Next queue after departing port ``q`` toward node ``d`` — the
    single-port form of :func:`route_from_queue` (tests/tools walk paths
    with it; the tick itself uses the all-ports form)."""
    nxt = route_switch(dims, consts, consts.nbr_q[q], d, ent)
    return jnp.where(consts.edge_q[q], -(d + 1), nxt)


def departures(dims: Dims, consts: Consts, st: SimState) -> SimState:
    """Phase 1: one head-of-line packet per active port onto the wire."""
    t = st.now
    m = st.m
    NQ, CAP, L = dims.NQ, dims.CAP, dims.L
    B = dims.QE                                       # core/edge port split

    qidx = consts.qidx
    # fault schedule: per-port service period as a function of t (1 =
    # healthy, 0 = dead, k > 1 = degraded; faults.port_period evaluates
    # the compiled transition tables — gated statically so no-fault
    # configs keep the historical fault-free graph).  The modulus stays
    # on the absolute tick, so a lowered legacy fault is bit-identical
    # to the historical service_period evaluation.
    if dims.FK or dims.flapped:
        per = faults.port_period(dims, consts, t)
        svc = jnp.where(per > 1, (t % jnp.maximum(per, 1)) == 0, True)
    else:
        svc = True
    active = (st.q_size[:NQ] > 0) & svc
    head = st.q_head[:NQ]
    hf = st.q_fields[qidx, head]                      # [NQ, 5]
    d_flow, d_seq, d_ent, d_ecn, d_ts = (hf[:, i] for i in range(5))
    # RED marking at dequeue (paper Sec. 2.1 / 3.5)
    qsz = st.q_size[:NQ].astype(F32)
    pmark = jnp.clip((qsz - consts.kmin) / consts.kspan, 0.0, 1.0)
    mark = hashing.uniform01(t * jnp.int32(131071) + qidx,
                             jnp.int32(0xECD) + st.salt) < pmark
    d_ecn = d_ecn | (mark & active).astype(I32)
    if dims.FK or dims.flapped:
        black = (per == 0) & active
    else:
        black = jnp.zeros((NQ,), bool)
    emit = active & ~black
    next_q = route_from_queue(dims, consts, d_flow, d_ent)
    q_head = st.q_head.at[:NQ].set(jnp.where(active, (head + 1) % CAP, head))
    q_size = st.q_size.at[:NQ].add(-active.astype(I32))
    payload = jnp.where(emit[:, None], jnp.stack(
        [emit.astype(I32), next_q, d_flow, d_seq, d_ent, d_ecn, d_ts],
        axis=1), 0)
    # Wire placement as two dynamic-update-slices, not a scatter: latency
    # is uniform within the switch-facing ports ([0, QE): every up/down
    # tier) and the edge ports ([QE, NQ): t0_down), and each emitter's target slot
    # (t + lat) % L holds nothing still live at tick t (only this emitter
    # writes its column, and whatever it wrote there last wrap landed
    # L - lat ticks ago) — so blanket-writing zeros for inactive ports is
    # exact, and arrivals never needs to zero a drained slot.
    infl = st.infl.at[(t + consts.lat_core) % L, :B].set(payload[:B])
    infl = infl.at[(t + consts.lat_edge) % L, B:NQ].set(payload[B:])
    m = m._replace(n_black=m.n_black + jnp.sum(black.astype(I32)))
    return st._replace(q_head=q_head, q_size=q_size, infl=infl, m=m)


def arrivals(dims: Dims, consts: Consts, st: SimState,
             enqueue=None) -> SimState:
    """Phase 2: land this tick's wire slot — deliver at the edge (dedupe,
    ACK generation) or enqueue mid-fabric (trim/drop on overflow).

    ``enqueue`` is the backend-resolved enqueue-rank callable
    (``kernels/enqueue_arb/ops.get``); ``None`` means the pure-jnp
    reference (the engine passes the ``SimConfig.fabric_backend``
    resolution)."""
    t = st.now
    m = st.m
    NF, NQ, NE, N = dims.NF, dims.NQ, dims.NE, dims.N
    CAP, L, R = dims.CAP, dims.L, dims.R

    arr = st.infl[t % L]                               # [NE, 7]
    # zero the slot once read: the wire ring then only ever holds live
    # packets, which is what makes `horizon`'s occupied-slot reduction (and
    # therefore time leaping over the skipped blanket rewrites) sound
    infl = st.infl.at[t % L].set(0)

    # ---- deliveries ----
    # Only the t0_down ports (emitter rows [QE, QE+N), one per node, in
    # node order) can deliver, so the delivery path works on that N-row
    # slice: row i delivers to node i.
    lo = dims.QE
    darr = arr[lo:lo + N]
    deliver = (darr[:, 0] == 1) & (darr[:, 1] < 0)
    d_flow, d_seq, d_ent, d_ecn, d_ts = (darr[:, i] for i in range(2, 7))
    # Receiver ledgers in the *flow-major* view: flow f's packets can only
    # ever land at node dst[f], and each node delivers at most one packet
    # per tick — so one gather by ``dst`` plus a flow-id check replaces the
    # historical per-node scatters into bitmap/goodput with dense [NF, *]
    # elementwise updates (row f of the bitmap is flow f's own; the MAXW
    # word axis is resolved with a one-hot select, never a gather).
    dview = darr[consts.dst]                           # [NF, 7]
    del_f = (dview[:, 0] == 1) & (dview[:, 1] < 0) & \
        (dview[:, 2] == consts.flow_ids)
    seq_f = jnp.where(del_f, dview[:, 3], 0)
    word_f, bit_f = seq_f // 32, seq_f % 32
    wsel = word_f[:, None] == jnp.arange(dims.MAXW, dtype=I32)  # [NF, MAXW]
    bm = st.bitmap[:NF]
    old_w = jnp.sum(jnp.where(wsel, bm, 0), axis=1)
    isnew_f = del_f & (((old_w >> bit_f) & 1) == 0)
    bitmap = st.bitmap.at[:NF].set(
        bm + jnp.where(wsel & isnew_f[:, None],
                       (1 << bit_f).astype(I32)[:, None], 0))
    # pkt_size at the all-flows identity: flow f's size is consts.size[f],
    # so the defensive flow clip (and its gather by the traced flow_ids
    # iota) drops out — size the packet directly (bitwise the same ints)
    psz_f = jnp.where(isnew_f,
                      jnp.clip(consts.size - seq_f * dims.mtu, 0, dims.mtu),
                      0)
    goodput = st.goodput + psz_f
    newly_done = (goodput >= consts.size) & ~st.done
    done = st.done | newly_done
    fct = jnp.where(newly_done, t + consts.ret - consts.t_start, st.fct)
    # ACK generation (echoes entropy + ECN + timestamp; priority path).
    # The return delay is constant (state.derive), so slot (t+ret) % R is
    # exclusively this tick's: write all N receiver rows in one
    # dynamic-update-slice, zeros where nothing was delivered.
    ack_payload = jnp.where(deliver[:, None], jnp.stack(
        [deliver.astype(I32), d_flow, d_seq, d_ecn, d_ent, d_ts], axis=1), 0)
    ack_ring = st.ack_ring.at[(t + consts.ret) % R].set(ack_payload)
    # recovery metrics (ISSUE 8): binned goodput history for dip/TTR
    # analysis, plus bytes delivered while the fault schedule is active.
    # Both only accrue on delivery ticks (zero on event-free ticks), so
    # they are leap-exact for free; both live behind the same static
    # fault gate so fault-free configs keep the historical graph.
    dbytes = jnp.sum(psz_f).astype(F32)
    goodput_hist = m.goodput_hist
    delivered_bytes_fault = m.delivered_bytes_fault
    if dims.FK or dims.flapped:
        gbin = jnp.minimum(t // consts.goodput_bin, GOODPUT_BINS - 1)
        goodput_hist = m.goodput_hist + jnp.where(
            jnp.arange(GOODPUT_BINS, dtype=I32) == gbin, dbytes, 0.0)
        delivered_bytes_fault = m.delivered_bytes_fault + jnp.where(
            faults.fault_active(dims, consts, t), dbytes, 0.0)
    m = m._replace(
        delivered_pkts=m.delivered_pkts + jnp.sum(deliver.astype(I32)),
        delivered_bytes=m.delivered_bytes + dbytes,
        goodput_hist=goodput_hist,
        delivered_bytes_fault=delivered_bytes_fault,
    )

    # ---- enqueues (sort-free scatter with capacity + trim) ----
    # Only the enqueue-capable emitters (wire feeds a switch: every core
    # port + every sender NIC; the t0_down ports above deliver and never
    # enqueue) take part, so the whole path runs on the compact [EQ] axis
    # gathered through ``consts.enq_ids`` — every scatter below shrinks
    # from NE to EQ rows, the dominant cost at fabric scale.
    #
    # Same-queue arrivals must land in fixed emitter order (the semantics
    # the old stable-argsort ranking gave).  The rank of emitter e within
    # its destination-queue group is the count of emitters e' < e with the
    # same destination; since same-queue emitters always feed the same
    # switch, the compare+reduce runs per switch fan-in group over the
    # static ``in_tbl``/``in_pos`` tables — O(NSW * DMAX^2) instead of the
    # historical global [NE, NE] pass, bit-for-bit the same ranks (the
    # compact enumeration is id-ascending, so group slot order is
    # unchanged; kernels/enqueue_arb — the jnp reference and the Pallas
    # kernel are interchangeable backends).
    if enqueue is None:
        from repro.kernels.enqueue_arb import ops as _arb_ops
        enqueue = _arb_ops.enqueue_rank
    earr = arr[consts.enq_ids]                         # [EQ, 7]
    e_dstq, e_flow, e_seq, e_ent, e_ecn, e_ts = (
        earr[:, i] for i in range(1, 7))
    enq = (earr[:, 0] == 1) & (e_dstq >= 0)
    q_head, q_size = st.q_head, st.q_size
    edst = jnp.where(enq, e_dstq, NQ)
    acc, pos, q_counts = enqueue(consts.in_tbl, consts.in_pos,
                                 consts.sw_of_q, edst, q_head, q_size,
                                 CAP, NQ)
    row = jnp.where(acc, edst, NQ)
    posw = jnp.where(acc, pos, 0)
    # (indices are NOT unique: every non-accepted emitter collapses onto
    # the write-off cell (NQ, 0), which is never read — the payload is
    # masked to zero there so the cell stays constant and an event-free
    # tick leaves the whole array bitwise unchanged, the property time
    # leaping relies on)
    q_fields = st.q_fields.at[row, posw].set(
        jnp.where(acc[:, None],
                  jnp.stack([e_flow, e_seq, e_ent, e_ecn, e_ts], axis=1), 0),
        mode="promise_in_bounds")
    # per-queue accepted counts come out of the fan-in groups (a dense
    # compare+reduce in the ops layer), not a segment_sum scatter
    q_size = q_size.at[:NQ].add(q_counts)
    rej = (edst < NQ) & ~acc
    # trim (paper: only when the buffer is full) or drop
    rflow = jnp.where(rej, e_flow, NF)
    rej_pkt = pkt_size(dims, consts, e_flow, e_seq)
    rej_bytes_i = jnp.where(rej, rej_pkt, 0)
    trim_seen = st.trim_seen
    if dims.credit_based:
        # receiver-side trim visibility (EQDS: trimmed headers reach the
        # receiver, which re-schedules the pull — paper Sec. 2.2); only
        # the credit grants read it, so sender-based algorithms skip it.
        trim_seen = st.trim_seen.at[rflow].add(
            rej_bytes_i.astype(F32), mode="promise_in_bounds")
    if dims.trimming:
        W, WW = dims.W, dims.WW
        # one packed update feeds the whole delayed trim ledger: count,
        # bytes (exact in i32), and the WW per-slot loss-bitmap words.
        # The trim notification delay is a scalar constant, so every
        # rejection of this tick lands in ONE ring slot: scatter the
        # per-emitter updates into a flow-major [NF+1, 2+WW] staging row
        # (1-D indices — far cheaper than the historical 2-D-indexed
        # scatter into the ring) and fold it in with a single slice add
        # (adding the all-zero rows of idle flows is bitwise a no-op, the
        # property time leaping relies on).
        wslot = (e_seq % W) // 32
        wbit = (e_seq % W) % 32
        words = jnp.where(
            rej[:, None] & (wslot[:, None] == jnp.arange(WW, dtype=I32)),
            (1 << wbit)[:, None].astype(I32), 0)
        upd = jnp.concatenate(
            [rej.astype(I32)[:, None], rej_bytes_i[:, None], words], axis=1)
        staged = jnp.zeros((NF + 1, 2 + WW), I32).at[rflow].add(
            upd, mode="promise_in_bounds")
        trim_ring = st.trim_ring.at[(t + consts.trim_delay) % R].add(staged)
        m = m._replace(n_trim=m.n_trim + jnp.sum(rej.astype(I32)))
    else:
        trim_ring = st.trim_ring
        m = m._replace(n_drop=m.n_drop + jnp.sum(rej.astype(I32)))

    return st._replace(
        infl=infl, bitmap=bitmap, goodput=goodput, done=done, fct=fct,
        ack_ring=ack_ring, q_fields=q_fields, q_size=q_size,
        trim_seen=trim_seen, trim_ring=trim_ring, m=m,
    )


def horizon(dims: Dims, consts: Consts, st: SimState):
    """Ticks until phases 1-2 next do work (DESIGN.md Sec. 6.3).

    0 while any port holds a packet — an occupied port departs (or is
    fault-serviced/blackholed) on a tick-by-tick schedule, so the fabric is
    only leapable once every queue is drained.  Otherwise the next event is
    the earliest occupied wire slot landing: ``arrivals`` reads slot
    ``t % L``, so an entry parked in slot ``s`` lands in ``(s - t) mod L``
    ticks (exact — the wire ring is zeroed on read, so valid entries are
    exactly the packets in flight).
    """
    t = st.now
    busy = jnp.any(st.q_size[:dims.NQ] > 0)
    live = jnp.any(st.infl[:, :, 0] == 1, axis=1)                  # [L]
    dist = (consts.iota_l - t) % dims.L
    h_wire = jnp.min(jnp.where(live, dist, HORIZON_INF))
    h = jnp.where(busy, 0, h_wire)
    if dims.FK or dims.flapped:
        # clamp every leap to the next fault-schedule transition: over
        # [t, t + h) every port's service period is then constant, so a
        # leap can never jump across a fail/degrade/repair/flap edge
        h = jnp.minimum(h, faults.transition_horizon(dims, consts, t))
    return h
