"""Phases 1-2 of the tick — the switching fabric.

  1. ``departures``: dequeue head per port, RED dequeue-marking, route,
     blackhole on failed links, place on the wire
  2. ``arrivals``:  packets landing now -> enqueue (trim/drop on overflow)
     or deliver (receiver dedupe, ACK generation)

Both are pure ``(Dims, Consts, SimState) -> SimState``; they communicate
with the rest of the pipeline only through ``SimState`` fields (the wire
ring ``infl``, the delayed control rings, and the receiver ledgers).
Routing is purely functional over the per-emitter constants in ``Consts``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.netsim import hashing
from repro.netsim.state import Consts, Dims, SimState, pkt_size
from repro.netsim.topology import KIND_T0_UP, KIND_T1_DOWN

I32 = jnp.int32
F32 = jnp.float32


def route_from_queue(dims: Dims, consts: Consts, qidx, flow):
    """Next queue for a packet departing fabric port ``qidx`` (negative ids
    encode delivery to node -(id+1))."""
    d = consts.dst[jnp.clip(flow, 0, dims.NF - 1)]
    drack = d // dims.M
    k, ax = consts.kind[qidx], consts.e_aux[qidx]
    r_up = dims.PU + ax * dims.P + drack    # t0_up -> t1_down[spine, drack]
    r_t1 = 2 * dims.PU + d                  # t1_down -> t0_down[dst]
    r_del = -(d + 1)                        # t0_down -> deliver
    return jnp.where(k == KIND_T0_UP, r_up,
                     jnp.where(k == KIND_T1_DOWN, r_t1, r_del))


def route_from_sender(dims: Dims, consts: Consts, f, ent):
    """First queue for a fresh packet of flow ``f`` carrying entropy ``ent``
    (ECMP uplink hash, same-rack shortcut)."""
    sr = consts.src[f] // dims.M
    d = consts.dst[f]
    h = (hashing.hash2(ent.astype(jnp.uint32),
                       (sr * 0x9E37 + 0x1234).astype(jnp.uint32))
         % jnp.uint32(dims.U)).astype(I32)
    return jnp.where(d // dims.M == sr, 2 * dims.PU + d, sr * dims.U + h)


def departures(dims: Dims, consts: Consts, st: SimState) -> SimState:
    """Phase 1: one head-of-line packet per active port onto the wire."""
    t = st.now
    m = st.m
    NQ, CAP, L = dims.NQ, dims.CAP, dims.L

    qidx = jnp.arange(NQ, dtype=I32)
    in_fault = t >= consts.fault_start
    svc = jnp.where(in_fault & (consts.service_period > 1),
                    (t % jnp.maximum(consts.service_period, 1)) == 0, True)
    active = (st.q_size[:NQ] > 0) & svc
    head = st.q_head[:NQ]
    hf = st.q_fields[qidx, head]                      # [NQ, 5]
    d_flow, d_seq, d_ent, d_ecn, d_ts = (hf[:, i] for i in range(5))
    # RED marking at dequeue (paper Sec. 2.1 / 3.5)
    qsz = st.q_size[:NQ].astype(F32)
    pmark = jnp.clip((qsz - consts.kmin) / consts.kspan, 0.0, 1.0)
    mark = hashing.uniform01(t * jnp.int32(131071) + qidx,
                             jnp.int32(0xECD) + st.salt) < pmark
    d_ecn = d_ecn | (mark & active).astype(I32)
    black = consts.dead[qidx] & active & in_fault
    emit = active & ~black
    next_q = route_from_queue(dims, consts, qidx, d_flow)
    q_head = st.q_head.at[:NQ].set(jnp.where(active, (head + 1) % CAP, head))
    q_size = st.q_size.at[:NQ].add(-active.astype(I32))
    slot = jnp.where(emit, (t + consts.lat_q[:NQ]) % L, L)
    payload = jnp.stack(
        [emit.astype(I32), next_q, d_flow, d_seq, d_ent, d_ecn, d_ts], axis=1)
    infl = st.infl.at[slot, qidx].set(payload)
    m = m._replace(n_black=m.n_black + jnp.sum(black.astype(I32)))
    return st._replace(q_head=q_head, q_size=q_size, infl=infl, m=m)


def arrivals(dims: Dims, consts: Consts, st: SimState) -> SimState:
    """Phase 2: land this tick's wire slot — deliver at the edge (dedupe,
    ACK generation) or enqueue mid-fabric (trim/drop on overflow)."""
    t = st.now
    m = st.m
    NF, NQ, NE, N = dims.NF, dims.NQ, dims.NE, dims.N
    CAP, L, R = dims.CAP, dims.L, dims.R

    arr = st.infl[t % L]                               # [NE, 7]
    infl = st.infl.at[t % L].set(0)
    a_valid = arr[:, 0] == 1
    a_dstq, a_flow, a_seq, a_ent, a_ecn, a_ts = (arr[:, i] for i in range(1, 7))
    deliver = a_valid & (a_dstq < 0)
    enq = a_valid & (a_dstq >= 0)

    # ---- deliveries ----
    node = jnp.where(deliver, -a_dstq - 1, 0)
    dflow = jnp.where(deliver, a_flow, NF)
    word, bit = a_seq // 32, a_seq % 32
    old = st.bitmap[dflow, word]
    isnew = deliver & (((old >> bit) & 1) == 0)
    bitmap = st.bitmap.at[dflow, word].add(
        jnp.where(isnew, (1 << bit).astype(I32), 0))
    psz = pkt_size(dims, consts, a_flow, a_seq)
    goodput = st.goodput.at[jnp.where(isnew, a_flow, 0)].add(
        jnp.where(isnew, psz, 0))
    newly_done = (goodput >= consts.size) & ~st.done
    done = st.done | newly_done
    fct = jnp.where(newly_done, t + consts.ret - consts.t_start, st.fct)
    # ACK generation (echoes entropy + ECN + timestamp; priority path).
    # Non-delivering emitters write into the pre-sized sentinel column N.
    anode = jnp.where(deliver, node, N)
    aslot = (t + consts.ret[jnp.clip(a_flow, 0, NF - 1)]) % R
    aslot = jnp.where(deliver, aslot, 0)
    ack_payload = jnp.stack(
        [deliver.astype(I32), a_flow, a_seq, a_ecn, a_ent, a_ts], axis=1)
    ack_ring = st.ack_ring.at[aslot, anode].set(ack_payload)
    m = m._replace(
        delivered_pkts=m.delivered_pkts + jnp.sum(deliver.astype(I32)),
        delivered_bytes=m.delivered_bytes + jnp.sum(jnp.where(isnew, psz, 0)).astype(F32),
    )

    # ---- enqueues (sorted scatter with capacity + trim) ----
    q_head, q_size = st.q_head, st.q_size
    edst = jnp.where(enq, a_dstq, NQ)
    order = jnp.argsort(edst)
    ds = edst[order]
    eflow, eseq, eent, eecn, ets = (x[order] for x in (a_flow, a_seq, a_ent, a_ecn, a_ts))
    first = jnp.searchsorted(ds, ds, side="left")
    rank = jnp.arange(NE, dtype=first.dtype) - first
    space = CAP - q_size[ds]
    acc = (ds < NQ) & (rank < space)
    pos = (q_head[ds] + q_size[ds] + rank.astype(I32)) % CAP
    row = jnp.where(acc, ds, NQ)
    posw = jnp.where(acc, pos, 0)
    q_fields = st.q_fields.at[row, posw].set(
        jnp.stack([eflow, eseq, eent, eecn, ets], axis=1))
    q_size = q_size + jax.ops.segment_sum(acc.astype(I32), ds, num_segments=NQ + 1)
    rej = (ds < NQ) & ~acc
    # trim (paper: only when the buffer is full) or drop
    rflow = jnp.where(rej, eflow, NF)
    # receiver-side trim visibility (EQDS: trimmed headers reach the
    # receiver, which re-schedules the pull — paper Sec. 2.2)
    trim_seen = jnp.pad(st.trim_seen, (0, 1)).at[rflow].add(
        jnp.where(rej, pkt_size(dims, consts, eflow, eseq).astype(F32), 0.0))[:NF]
    if dims.trimming:
        W = dims.W
        tslot = jnp.where(rej, (t + consts.trim_delay) % R, 0)
        trim_cnt = st.trim_cnt.at[tslot, rflow].add(rej.astype(I32))
        trim_bytes = st.trim_bytes.at[tslot, rflow].add(
            jnp.where(rej, pkt_size(dims, consts, eflow, eseq).astype(F32), 0.0))
        wslot = (eseq % W) // 32
        wbit = (eseq % W) % 32
        lost_bits = st.lost_bits.at[tslot, rflow, wslot].add(
            jnp.where(rej, (1 << wbit).astype(I32), 0))
        m = m._replace(n_trim=m.n_trim + jnp.sum(rej.astype(I32)))
    else:
        trim_cnt, trim_bytes, lost_bits = st.trim_cnt, st.trim_bytes, st.lost_bits
        m = m._replace(n_drop=m.n_drop + jnp.sum(rej.astype(I32)))

    return st._replace(
        infl=infl, bitmap=bitmap, goodput=goodput, done=done, fct=fct,
        ack_ring=ack_ring, q_fields=q_fields, q_size=q_size,
        trim_seen=trim_seen, trim_cnt=trim_cnt, trim_bytes=trim_bytes,
        lost_bits=lost_bits, m=m,
    )
