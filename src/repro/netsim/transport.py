"""Phase 3 of the tick — control-plane events and transport bookkeeping.

Drains this tick's slot of the delayed control rings (ACKs, trimmed-header
notifications, loss bitmaps, EQDS credit grants), frees/loses sent-ring
slots, fires retransmission timeouts, and hands the per-flow event bundle
to the congestion-control update (any registry backend: pure-jnp or the
Pallas ``cc_update`` kernel) and the load-balancer ACK path.

``horizon`` reduces the same rings — plus the armed retransmission
timers — to "ticks until this phase next does work", feeding the engine's
event-horizon time leaping (DESIGN.md Sec. 6.3).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import reps
from repro.core.types import CCEvent
from repro.netsim.metrics import HIST_BINS
from repro.netsim.state import HORIZON_INF, Consts, Dims, SimState, pkt_size

I32 = jnp.int32
F32 = jnp.float32


def control(dims: Dims, consts: Consts, cc_update, st: SimState) -> SimState:
    """Phase 3: ACK / trim / timeout / credit events -> transport state,
    CC update (``cc_update`` resolved by the registry), LB update."""
    t = st.now
    m = st.m
    NF, N, R, W = dims.NF, dims.N, dims.R, dims.W
    MTU = float(dims.mtu)
    flow_ids = consts.flow_ids

    acks = st.ack_ring[t % R]                          # [N, 6]
    # zero the slot once read (the trim/credit rings below already do):
    # valid ACK-ring entries are then exactly the ACKs in flight, which is
    # what makes `horizon`'s occupied-slot reduction — and time leaping
    # over the skipped blanket rewrites — sound
    ack_ring = st.ack_ring.at[t % R].set(0)
    v = acks[:, 0] == 1
    idxf = jnp.where(v, acks[:, 1], NF)

    # one packed flow-major scatter for all five ACK columns (same indices;
    # five separate scatters cost ~5x the XLA:CPU scatter overhead)
    by_flow = jnp.zeros((NF + 1, 6), I32).at[idxf].set(
        acks, mode="promise_in_bounds")[:NF]
    has_ack = by_flow[:, 0] == 1
    ack_seq = jnp.where(has_ack, by_flow[:, 2], 0)
    ack_ecn = has_ack & (by_flow[:, 3] == 1)
    ack_ent = jnp.where(has_ack, by_flow[:, 4], 0)
    ack_ts = jnp.where(has_ack, by_flow[:, 5], 0)
    rtt = jnp.where(has_ack, (t - ack_ts).astype(F32), 0.0)
    ack_bytes = jnp.where(
        has_ack, pkt_size(dims, consts, flow_ids, ack_seq).astype(F32), 0.0)

    tr = st.trim_ring[t % R][:NF]                      # [NF, 2+WW] packed
    trims = tr[:, 0]
    tbytes = tr[:, 1].astype(F32)
    lbits = tr[:, 2:]
    cred = st.credit_ring[t % R][:NF]
    trim_ring = st.trim_ring.at[t % R].set(0)
    credit_ring = st.credit_ring.at[t % R].set(0.0)

    # transport: free the ACKed slot, mark trim/timeout losses — all as
    # dense [NF, W] masks folded into ONE contiguous write of the state
    # component (XLA:CPU runs a 4K-element fused loop far faster than a
    # scatter + two slice-updates; sent ring is component-major [3,.,.]:
    # 0=state, 1=seq, 2=send tick)
    wbits = jnp.arange(W, dtype=I32)
    aslot2 = ack_seq % W
    cur = st.sent[0, flow_ids, aslot2]
    cur_seq = st.sent[1, flow_ids, aslot2]
    match = has_ack & (cur != 0) & (cur_seq == ack_seq)
    st_state = st.sent[0, :NF]
    freed = match[:, None] & (wbits[None, :] == aslot2[:, None])
    st_state = jnp.where(freed, 0, st_state)

    # trimmed packets -> lost (awaiting retransmission)
    bitsel = (lbits[:, wbits // 32] >> (wbits % 32)) & 1      # [NF, W]
    lost_mask = (bitsel == 1) & (st_state == 1)
    st_state = jnp.where(lost_mask, 3, st_state)

    # timeouts
    started_flows = (t >= consts.t_start) & ~st.done
    to_mask = (st_state == 1) & \
        ((t - st.sent[2, :NF]).astype(F32) > consts.rto[:, None]) & \
        started_flows[:, None]
    # count a spurious retx when the receiver already has the packet
    sp_word = st.sent[1, :NF] // 32
    sp_bit = st.sent[1, :NF] % 32
    already = ((st.bitmap[:NF][jnp.arange(NF)[:, None], sp_word] >> sp_bit) & 1) == 1
    m = m._replace(spurious_retx=m.spurious_retx
                   + jnp.sum((to_mask & already).astype(I32)))
    st_state = jnp.where(to_mask, 3, st_state)
    sent = st.sent.at[0, :NF].set(st_state)
    n_to = jnp.sum(to_mask.astype(I32), axis=1)
    to_bytes = n_to.astype(F32) * MTU
    m = m._replace(n_to=m.n_to + jnp.sum(n_to))

    unacked = jnp.sum((st_state == 1).astype(I32), axis=1).astype(F32) * MTU

    ev = CCEvent(
        has_ack=has_ack, ack_bytes=ack_bytes, ecn=ack_ecn, rtt=rtt,
        ack_entropy=ack_ent, n_trims=trims, trim_bytes=tbytes,
        n_timeouts=n_to, to_bytes=to_bytes, unacked=unacked,
        credit_grant=cred,
    )
    cc = cc_update(consts.cc, st.cc, ev, t)
    lb = reps.on_ack(dims.lb_mode, consts.lb, st.lb, has_ack, ack_ecn, ack_ent,
                     flow_ids, t)
    # RTT histogram — one-hot reduce instead of a scatter-add ([NF, BINS]
    # fused compare+sum beats the XLA:CPU scatter loop)
    bins = jnp.clip((rtt * (8.0 / dims.brtt_inter)).astype(I32), 0, HIST_BINS - 1)
    hist_inc = jnp.sum(
        (has_ack[:, None] &
         (bins[:, None] == jnp.arange(HIST_BINS, dtype=I32))).astype(I32),
        axis=0)
    m = m._replace(
        rtt_hist=m.rtt_hist + hist_inc,
        n_ack=m.n_ack + jnp.sum(has_ack.astype(I32)),
    )

    return st._replace(
        ack_ring=ack_ring, trim_ring=trim_ring, credit_ring=credit_ring,
        sent=sent, unacked=unacked, cc=cc, lb=lb, m=m,
    )


def horizon(dims: Dims, consts: Consts, st: SimState):
    """Ticks until phase 3 next does work (DESIGN.md Sec. 6.3).

    Three delayed control rings read slot ``t % R`` and are zeroed on
    read, so a live entry in slot ``s`` is consumed in ``(s - t) mod R``
    ticks.  An armed timeout (outstanding sent-ring slot of a started,
    unfinished flow) fires at the first integer tick strictly beyond
    ``send_tick + rto`` — ``floor(rto) + 1`` ticks after the send — which
    the leap must land on exactly, not skip past.
    """
    t = st.now
    NF, R = dims.NF, dims.R
    dist = (consts.iota_r - t) % R
    live_ack = jnp.any(st.ack_ring[:, :, 0] == 1, axis=1)          # [R]
    h = jnp.min(jnp.where(live_ack, dist, HORIZON_INF))
    if dims.trimming:
        live_trim = jnp.any(st.trim_ring[:, :NF, 0] > 0, axis=1)
        h = jnp.minimum(h, jnp.min(jnp.where(live_trim, dist, HORIZON_INF)))
    if dims.credit_based:
        live_cred = jnp.any(st.credit_ring[:, :NF] != 0.0, axis=1)
        h = jnp.minimum(h, jnp.min(jnp.where(live_cred, dist, HORIZON_INF)))
    started = (t >= consts.t_start) & ~st.done
    armed = (st.sent[0, :NF] == 1) & started[:, None]               # [NF, W]
    fire = (st.sent[2, :NF] + jnp.floor(consts.rto).astype(I32)[:, None]
            + 1 - t)
    h_to = jnp.min(jnp.where(armed, jnp.maximum(fire, 0), HORIZON_INF))
    return jnp.minimum(h, h_to)
