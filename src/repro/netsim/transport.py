"""Phase 3 of the tick — control-plane events and transport bookkeeping.

Drains this tick's slot of the delayed control rings (ACKs, trimmed-header
notifications, loss bitmaps, EQDS credit grants), frees/loses sent-ring
slots, fires retransmission timeouts, and hands the per-flow event bundle
to the congestion-control update (any registry backend: pure-jnp or the
Pallas ``cc_update`` kernel) and the load-balancer ACK path.

``horizon`` reduces the same rings — plus the armed retransmission
timers — to "ticks until this phase next does work", feeding the engine's
event-horizon time leaping (DESIGN.md Sec. 6.3).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import reps
from repro.core.types import CCEvent
from repro.netsim.metrics import HIST_BINS
from repro.netsim.state import HORIZON_INF, Consts, Dims, SimState

I32 = jnp.int32
F32 = jnp.float32


def effective_rto(dims: Dims, consts: Consts, st: SimState):
    """Per-flow RTO with capped exponential backoff (failure recovery,
    ISSUE 8): ``rto * 2^min(consecutive timeouts, cap)``.  ``ldexp``
    scales the f32 base by an exact power of two, and the gate is static,
    so backoff-off configs keep the historical ``consts.rto`` verbatim.
    Used by both the drain and the timeout horizon — the leap must land
    exactly on the backed-off fire tick."""
    if not dims.rto_backoff_max:
        return consts.rto
    return jnp.ldexp(consts.rto,
                     jnp.minimum(st.rto_backoff, dims.rto_backoff_max))


def control(dims: Dims, consts: Consts, cc_update, st: SimState,
            drain=None) -> SimState:
    """Phase 3: ACK / trim / timeout / credit events -> transport state,
    CC update (``cc_update`` resolved by the registry), LB update.

    ``drain`` is the backend-resolved sent-ring drain callable
    (``kernels/ring_drain/ops.get``); ``None`` means the pure-jnp
    reference (the engine passes the ``SimConfig.transport_backend``
    resolution)."""
    if drain is None:
        from repro.kernels.ring_drain import ops as _drain_ops
        drain = _drain_ops.ring_drain
    t = st.now
    m = st.m
    NF, N, R, W = dims.NF, dims.N, dims.R, dims.W
    MTU = float(dims.mtu)
    flow_ids = consts.flow_ids

    acks = st.ack_ring[t % R]                          # [N, 6]
    # zero the slot once read (the trim/credit rings below already do):
    # valid ACK-ring entries are then exactly the ACKs in flight, which is
    # what makes `horizon`'s occupied-slot reduction — and time leaping
    # over the skipped blanket rewrites — sound
    ack_ring = st.ack_ring.at[t % R].set(0)

    # flow-major ACK view as a *gather*: flow f's ACKs can only ever come
    # from its own receiver's row (one delivery per receiver per tick, and
    # the row carries the flow id), so ``acks[dst[f]]`` + a flow-id check
    # replaces the historical [N] -> [NF] scatter at XLA:CPU gather cost
    cand = acks[consts.dst]                            # [NF, 6]
    has_ack = (cand[:, 0] == 1) & (cand[:, 1] == flow_ids)
    by_flow = jnp.where(has_ack[:, None], cand, 0)
    ack_seq = by_flow[:, 2]
    ack_ecn = has_ack & (by_flow[:, 3] == 1)
    ack_ent = by_flow[:, 4]
    ack_ts = by_flow[:, 5]
    rtt = jnp.where(has_ack, (t - ack_ts).astype(F32), 0.0)
    # pkt_size at the all-flows identity (flow_ids is the [0, NF) iota):
    # read consts.size directly instead of gathering it through the traced
    # iota — bitwise the same ints
    ack_bytes = jnp.where(
        has_ack,
        jnp.clip(consts.size - ack_seq * dims.mtu, 0, dims.mtu).astype(F32),
        0.0)

    tr = st.trim_ring[t % R][:NF]                      # [NF, 2+WW] packed
    trims = tr[:, 0]
    tbytes = tr[:, 1].astype(F32)
    lbits = tr[:, 2:]
    cred = st.credit_ring[t % R][:NF]
    trim_ring = st.trim_ring.at[t % R].set(0)
    credit_ring = st.credit_ring.at[t % R].set(0.0)

    # transport: free the ACKed slot, mark trim/timeout losses, reduce the
    # per-flow timeout/spurious/outstanding counts — one packed drain over
    # the component-major sent ring (kernels/ring_drain; elementwise +
    # row reductions only, folded into ONE contiguous write of the state
    # component — the jnp reference and the Pallas kernel are
    # interchangeable backends)
    started_flows = (t >= consts.t_start) & ~st.done
    st_state, n_to, spur, un_pkts = drain(
        t, effective_rto(dims, consts, st), started_flows, has_ack,
        ack_seq, lbits,
        st.bitmap[:NF], st.sent[0, :NF], st.sent[1, :NF], st.sent[2, :NF])
    sent = st.sent.at[0, :NF].set(st_state)
    m = m._replace(spurious_retx=m.spurious_retx + jnp.sum(spur))
    to_bytes = n_to.astype(F32) * MTU
    m = m._replace(n_to=m.n_to + jnp.sum(n_to))

    # capped exponential RTO backoff: bump on a tick that fired timeouts,
    # reset on any ACK (an ACK proves the path is moving again; on a tick
    # with both, the reset wins).  Event-free ticks change nothing, so
    # time leaping stays exact.
    rto_backoff = st.rto_backoff
    if dims.rto_backoff_max:
        rto_backoff = jnp.where(
            n_to > 0,
            jnp.minimum(st.rto_backoff + 1, dims.rto_backoff_max),
            st.rto_backoff)
        rto_backoff = jnp.where(has_ack, 0, rto_backoff)

    unacked = un_pkts.astype(F32) * MTU

    ev = CCEvent(
        has_ack=has_ack, ack_bytes=ack_bytes, ecn=ack_ecn, rtt=rtt,
        ack_entropy=ack_ent, n_trims=trims, trim_bytes=tbytes,
        n_timeouts=n_to, to_bytes=to_bytes, unacked=unacked,
        credit_grant=cred,
    )
    cc = cc_update(consts.cc, st.cc, ev, t)
    lb = reps.on_ack(dims.lb_mode, consts.lb, st.lb, has_ack, ack_ecn, ack_ent,
                     flow_ids, t)
    if dims.evict:
        lb = reps.on_timeout(dims.lb_mode, consts.lb, lb, n_to > 0)
    # RTT histogram — one-hot reduce instead of a scatter-add ([NF, BINS]
    # fused compare+sum beats the XLA:CPU scatter loop)
    bins = jnp.clip((rtt * (8.0 / dims.brtt_inter)).astype(I32), 0, HIST_BINS - 1)
    hist_inc = jnp.sum(
        (has_ack[:, None] &
         (bins[:, None] == jnp.arange(HIST_BINS, dtype=I32))).astype(I32),
        axis=0)
    m = m._replace(
        rtt_hist=m.rtt_hist + hist_inc,
        n_ack=m.n_ack + jnp.sum(has_ack.astype(I32)),
    )

    return st._replace(
        ack_ring=ack_ring, trim_ring=trim_ring, credit_ring=credit_ring,
        sent=sent, unacked=unacked, cc=cc, lb=lb, m=m,
        rto_backoff=rto_backoff,
    )


def horizon(dims: Dims, consts: Consts, st: SimState):
    """Ticks until phase 3 next does work (DESIGN.md Sec. 6.3).

    Three delayed control rings read slot ``t % R`` and are zeroed on
    read, so a live entry in slot ``s`` is consumed in ``(s - t) mod R``
    ticks.  An armed timeout (outstanding sent-ring slot of a started,
    unfinished flow) fires at the first integer tick strictly beyond
    ``send_tick + rto`` — ``floor(rto) + 1`` ticks after the send — which
    the leap must land on exactly, not skip past.
    """
    t = st.now
    NF, R = dims.NF, dims.R
    dist = (consts.iota_r - t) % R
    live_ack = jnp.any(st.ack_ring[:, :, 0] == 1, axis=1)          # [R]
    h = jnp.min(jnp.where(live_ack, dist, HORIZON_INF))
    if dims.trimming:
        live_trim = jnp.any(st.trim_ring[:, :NF, 0] > 0, axis=1)
        h = jnp.minimum(h, jnp.min(jnp.where(live_trim, dist, HORIZON_INF)))
    if dims.credit_based:
        live_cred = jnp.any(st.credit_ring[:, :NF] != 0.0, axis=1)
        h = jnp.minimum(h, jnp.min(jnp.where(live_cred, dist, HORIZON_INF)))
    started = (t >= consts.t_start) & ~st.done
    armed = (st.sent[0, :NF] == 1) & started[:, None]               # [NF, W]
    fire = (st.sent[2, :NF]
            + jnp.floor(effective_rto(dims, consts, st)).astype(I32)[:, None]
            + 1 - t)
    h_to = jnp.min(jnp.where(armed, jnp.maximum(fire, 0), HORIZON_INF))
    return jnp.minimum(h, h_to)
