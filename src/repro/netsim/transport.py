"""Phase 3 of the tick — control-plane events and transport bookkeeping.

Drains this tick's slot of the delayed control rings (ACKs, trimmed-header
notifications, loss bitmaps, EQDS credit grants), frees/loses sent-ring
slots, fires retransmission timeouts, and hands the per-flow event bundle
to the congestion-control update (any registry backend: pure-jnp or the
Pallas ``cc_update`` kernel) and the load-balancer ACK path.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import reps
from repro.core.types import CCEvent
from repro.netsim.metrics import HIST_BINS
from repro.netsim.state import Consts, Dims, SimState, pkt_size

I32 = jnp.int32
F32 = jnp.float32


def control(dims: Dims, consts: Consts, cc_update, st: SimState) -> SimState:
    """Phase 3: ACK / trim / timeout / credit events -> transport state,
    CC update (``cc_update`` resolved by the registry), LB update."""
    t = st.now
    m = st.m
    NF, N, R, W = dims.NF, dims.N, dims.R, dims.W
    MTU = float(dims.mtu)
    flow_ids = jnp.arange(NF, dtype=I32)

    acks = st.ack_ring[t % R][:N]                      # [N, 6] (drop sentinel)
    ack_ring = st.ack_ring.at[t % R].set(0)
    v = acks[:, 0] == 1
    idxf = jnp.where(v, acks[:, 1], NF)

    def scat(vals, fill=0):
        return jnp.full((NF + 1,), fill, vals.dtype).at[idxf].set(vals)[:NF]

    has_ack = jnp.zeros((NF + 1,), bool).at[idxf].set(v)[:NF]
    ack_seq = scat(acks[:, 2])
    ack_ecn = jnp.zeros((NF + 1,), bool).at[idxf].set(acks[:, 3] == 1)[:NF]
    ack_ent = scat(acks[:, 4])
    ack_ts = scat(acks[:, 5])
    rtt = jnp.where(has_ack, (t - ack_ts).astype(F32), 0.0)
    ack_bytes = jnp.where(
        has_ack, pkt_size(dims, consts, flow_ids, ack_seq).astype(F32), 0.0)

    trims = st.trim_cnt[t % R][:NF]
    tbytes = st.trim_bytes[t % R][:NF]
    lbits = st.lost_bits[t % R][:NF]
    cred = st.credit_ring[t % R][:NF]
    trim_cnt = st.trim_cnt.at[t % R].set(0)
    trim_bytes = st.trim_bytes.at[t % R].set(0.0)
    lost_bits = st.lost_bits.at[t % R].set(0)
    credit_ring = st.credit_ring.at[t % R].set(0.0)

    # transport: free the ACKed slot
    aslot2 = ack_seq % W
    cur = st.st_state[flow_ids, aslot2]
    cur_seq = st.st_seq[flow_ids, aslot2]
    match = has_ack & (cur != 0) & (cur_seq == ack_seq)
    st_state = st.st_state.at[flow_ids, aslot2].set(jnp.where(match, 0, cur))

    # trimmed packets -> lost (awaiting retransmission)
    wbits = jnp.arange(W, dtype=I32)
    bitsel = (lbits[:, wbits // 32] >> (wbits % 32)) & 1      # [NF, W]
    lost_mask = (bitsel == 1) & (st_state[:NF] == 1)
    st_state = st_state.at[:NF].set(jnp.where(lost_mask, 3, st_state[:NF]))

    # timeouts
    started_flows = (t >= consts.t_start) & ~st.done
    to_mask = (st_state[:NF] == 1) & \
        ((t - st.st_ts[:NF]).astype(F32) > consts.rto[:, None]) & \
        started_flows[:, None]
    # count a spurious retx when the receiver already has the packet
    sp_word = st.st_seq[:NF] // 32
    sp_bit = st.st_seq[:NF] % 32
    already = ((st.bitmap[:NF][jnp.arange(NF)[:, None], sp_word] >> sp_bit) & 1) == 1
    m = m._replace(spurious_retx=m.spurious_retx
                   + jnp.sum((to_mask & already).astype(I32)))
    st_state = st_state.at[:NF].set(jnp.where(to_mask, 3, st_state[:NF]))
    n_to = jnp.sum(to_mask.astype(I32), axis=1)
    to_bytes = n_to.astype(F32) * MTU
    m = m._replace(n_to=m.n_to + jnp.sum(n_to))

    unacked = jnp.sum((st_state[:NF] == 1).astype(I32), axis=1).astype(F32) * MTU

    ev = CCEvent(
        has_ack=has_ack, ack_bytes=ack_bytes, ecn=ack_ecn, rtt=rtt,
        ack_entropy=ack_ent, n_trims=trims, trim_bytes=tbytes,
        n_timeouts=n_to, to_bytes=to_bytes, unacked=unacked,
        credit_grant=cred,
    )
    cc = cc_update(consts.cc, st.cc, ev, t)
    lb = reps.on_ack(dims.lb_mode, consts.lb, st.lb, has_ack, ack_ecn, ack_ent,
                     flow_ids, t)
    # RTT histogram
    bins = jnp.clip((rtt * (8.0 / dims.brtt_inter)).astype(I32), 0, HIST_BINS - 1)
    m = m._replace(
        rtt_hist=m.rtt_hist.at[jnp.where(has_ack, bins, 0)].add(has_ack.astype(I32)),
        n_ack=m.n_ack + jnp.sum(has_ack.astype(I32)),
    )

    return st._replace(
        ack_ring=ack_ring, trim_cnt=trim_cnt, trim_bytes=trim_bytes,
        lost_bits=lost_bits, credit_ring=credit_ring, st_state=st_state,
        unacked=unacked, cc=cc, lb=lb, m=m,
    )
