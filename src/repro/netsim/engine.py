"""Vectorized, time-stepped packet-level simulator.

Execution model (DESIGN.md Sec. 6): one tick = one MTU serialization time;
every output port forwards at most one data packet per tick.  All state is
struct-of-arrays with static shapes; one tick is a pure function
``step: SimState -> SimState`` executed under ``lax.while_loop`` (aggregate
runs, early exit) or ``lax.scan`` (trace runs, per-tick outputs).

Sub-step order within a tick:
  1. departures : dequeue head per port, RED dequeue-marking, route,
                  blackhole on failed links, place on the wire
  2. arrivals   : packets landing now -> enqueue (trim/drop on overflow) or
                  deliver (receiver dedupe, ACK generation)
  3. control    : ACK / trim / timeout / credit events -> transport
                  bookkeeping, CC update (SMaRTT or baseline), LB update
  4. grants     : EQDS receiver-side pull-credit generation
  5. sends      : per-sender round-robin flow arbitration, window/credit/
                  pacing admission, REPS entropy assignment, emission
  6. metrics    : occupancy/rate accounting
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import registry, reps
from repro.core.types import CCEvent, CCParams, CCState, init_cc_state, make_cc_params
from repro.netsim import hashing
from repro.netsim.topology import (KIND_SENDER, KIND_T0_DOWN, KIND_T0_UP,
                                   KIND_T1_DOWN, Topology, build_topology)
from repro.netsim.units import (FatTreeConfig, LinkConfig, Timing,
                                derive_timing, gamma)
from repro.netsim.workloads import Workload

I32 = jnp.int32
F32 = jnp.float32

HIST_BINS = 64  # RTT histogram bins, width = brtt/8


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimConfig:
    link: LinkConfig = LinkConfig()
    tree: FatTreeConfig = FatTreeConfig()
    algo: str = "smartt"
    lb: str = "reps"
    trimming: bool = True
    rto_mult: float = 0.0            # RTO = rto_mult * trtt; 0 = auto
                                     # (3.0 with trimming, 2.0 aggressive without)
    num_entropies: int = 256
    react_every: int = 1             # CC reaction granularity (Fig. 3b)
    credit_window_mult: float = 1.0  # EQDS outstanding-credit window (BDPs)
    start_cwnd_mult: float = 1.25    # initial window as fraction of BDP
    # fault injection (Fig. 7): ((rack, uplink, period), ...) — period 2 =
    # half-rate link, period 0 = dead link (blackholes traffic)
    faults: tuple = ()
    fault_start: int = 0
    cc_overrides: tuple = ()         # (("fd", 0.5), ...) applied to CCParams


# --------------------------------------------------------------------------
# state
# --------------------------------------------------------------------------


class Metrics(NamedTuple):
    n_trim: jnp.ndarray
    n_drop: jnp.ndarray
    n_black: jnp.ndarray
    n_to: jnp.ndarray
    n_retx: jnp.ndarray
    n_ack: jnp.ndarray
    delivered_pkts: jnp.ndarray
    delivered_bytes: jnp.ndarray
    rtt_hist: jnp.ndarray        # [HIST_BINS]
    q_sum: jnp.ndarray           # sum over (ticks, ports) of occupancy
    q_max: jnp.ndarray
    spurious_retx: jnp.ndarray   # retransmitted packets that had been delivered


class SimState(NamedTuple):
    now: jnp.ndarray                 # i32 scalar
    salt: jnp.ndarray                # i32 scalar — per-run hash decorrelation
    q_fields: jnp.ndarray            # i32 [NQ+1, CAP, 5] flow/seq/ent/ecn/ts
    q_head: jnp.ndarray              # i32 [NQ+1]
    q_size: jnp.ndarray              # i32 [NQ+1]
    infl: jnp.ndarray                # i32 [L+1, NE, 7] valid/dstq/flow/seq/ent/ecn/ts
    ack_ring: jnp.ndarray            # i32 [R, N, 6] valid/flow/seq/ecn/ent/ts
    trim_cnt: jnp.ndarray            # i32 [R, NF+1]
    trim_bytes: jnp.ndarray          # f32 [R, NF+1]
    lost_bits: jnp.ndarray           # i32 [R, NF+1, WW]
    credit_ring: jnp.ndarray         # f32 [R, NF+1]
    st_state: jnp.ndarray            # i32 [NF+1, W] 0=free 1=outstanding 3=lost
    st_seq: jnp.ndarray              # i32 [NF+1, W]
    st_ts: jnp.ndarray               # i32 [NF+1, W]
    next_seq: jnp.ndarray            # i32 [NF]
    done: jnp.ndarray                # bool [NF]
    fct: jnp.ndarray                 # i32 [NF] (-1 = unfinished)
    goodput: jnp.ndarray             # i32 [NF] unique bytes delivered
    bitmap: jnp.ndarray              # i32 [NF+1, MAXW] receiver dedupe
    granted: jnp.ndarray             # f32 [NF] EQDS credit issued
    trim_seen: jnp.ndarray           # f32 [NF] trimmed bytes observed by receiver
    rr_recv: jnp.ndarray             # i32 [N]
    rr_send: jnp.ndarray             # i32 [N]
    pace_accum: jnp.ndarray          # f32 [NF]
    cc: CCState
    lb: reps.LBState
    m: Metrics


@dataclasses.dataclass(frozen=True)
class Sim:
    """Compiled simulator bundle."""

    cfg: SimConfig
    topo: Topology
    timing: Timing
    wl: Workload
    cc_params: CCParams
    lb_params: reps.LBParams
    dims: dict
    step: callable          # jitted SimState -> SimState
    init: callable          # () -> SimState

    def run(self, max_ticks: int) -> SimState:
        return _run_until_done(self.step, self.init(), max_ticks)

    def run_trace(self, ticks: int, trace_flows: int = 8):
        return _run_trace(self.step, self.init(), ticks, trace_flows)

    def run_batch(self, seeds, max_ticks: int) -> SimState:
        """vmap a batch of decorrelated runs (per-seed RED/ECMP salts) —
        amortizes per-op dispatch on CPU and maps onto pjit batching for
        parameter sweeps at scale."""
        import numpy as _np
        states = jax.vmap(lambda s: self.init()._replace(
            salt=s.astype(I32)))(jnp.asarray(_np.asarray(seeds), I32))
        return _run_batch(self.step, states, max_ticks)


# --------------------------------------------------------------------------
# build
# --------------------------------------------------------------------------


def build(cfg: SimConfig, wl: Workload) -> Sim:
    link, tree = cfg.link, cfg.tree
    topo = build_topology(tree)
    tm = derive_timing(link)

    N, NQ, NE = tree.n_nodes, topo.n_queues, topo.n_emitters
    NF = wl.n_flows
    MTU = float(link.mtu_bytes)
    CAP = int(tm.brtt_inter)                      # 1 BDP per port queue
    # sent-ring slots: 1.5x the max window in packets (seq-range headroom;
    # new sends block on occupied slots, modeling a bounded retx buffer)
    W = int(2 ** np.ceil(np.log2(max(1.5 * 1.25 * tm.brtt_inter, 32))))
    WW = W // 32
    L = tm.hop + 2
    R = int(max(tm.ret_inter, tm.trim_delay) + tm.hop + 4)
    max_pkts = int(np.ceil(wl.size.max() / MTU))
    MAXW = (max_pkts + 31) // 32
    P, U, M = tree.racks, tree.uplinks, tree.nodes_per_rack
    PU = P * U

    if np.any(wl.src == wl.dst):
        raise ValueError("flow with src == dst")

    # ---- per-flow constants ----
    src = jnp.asarray(wl.src, I32)
    dst = jnp.asarray(wl.dst, I32)
    size_f = jnp.asarray(wl.size, I32)
    t_start = jnp.asarray(wl.t_start, I32)
    inter = (wl.src // M) != (wl.dst // M)
    # ACK return delay is constant per receiver: the ack ring is indexed
    # (arrival_tick + ret, receiver) and a receiver delivers one packet per
    # tick, so a *constant* return delay guarantees collision-free slots.
    brtt_f = np.where(inter, tm.brtt_inter,
                      tm.fwd_intra + tm.ret_inter).astype(np.float32)
    ret_f = jnp.full(NF, tm.ret_inter, I32)
    flow_ids = jnp.arange(NF, dtype=I32)

    bdp = float(tm.brtt_inter * MTU)
    cc_kwargs = dict(cfg.cc_overrides)
    cc_params = make_cc_params(
        mtu=MTU, bdp=bdp, brtt=brtt_f,
        react_every=cfg.react_every,
        gamma=gamma(link, tm),
        use_trimming=cfg.trimming,
        **cc_kwargs,
    )
    lb_params = reps.make_lb_params(
        num_entropies=cfg.num_entropies,
        bdp_pkts=int(tm.brtt_inter),
    )
    lb_mode = reps.LB_NAMES[cfg.lb]
    cc_update = registry.get(cfg.algo)
    credit_based = cfg.algo in registry.CREDIT_BASED
    paced = cfg.algo in registry.PACED
    rto_mult = cfg.rto_mult or (3.0 if cfg.trimming else 2.0)
    rto_f = jnp.asarray(rto_mult, F32) * cc_params.trtt
    credit_window = jnp.asarray(cfg.credit_window_mult * bdp, F32)

    # ---- per-sender / per-receiver flow matrices ----
    FMAX = max(int(np.max(np.bincount(wl.src, minlength=N))), 1)
    FRMAX = max(int(np.max(np.bincount(wl.dst, minlength=N))), 1)
    flows_of = np.full((N, FMAX), NF, np.int32)
    cnt = np.zeros(N, np.int64)
    for f in np.argsort(wl.order, kind="stable"):  # per-sender, ordered
        s = wl.src[f]
        flows_of[s, cnt[s]] = f
        cnt[s] += 1
    flows_by_recv = np.full((N, FRMAX), NF, np.int32)
    cnt = np.zeros(N, np.int64)
    for f in range(NF):
        r = wl.dst[f]
        flows_by_recv[r, cnt[r]] = f
        cnt[r] += 1
    flows_of = jnp.asarray(flows_of)
    flows_by_recv = jnp.asarray(flows_by_recv)
    window = int(min(wl.window, FMAX))

    # ---- per-emitter routing constants ----
    kind = jnp.asarray(topo.kind, I32)
    e_rack = jnp.asarray(topo.rack, I32)
    e_aux = jnp.asarray(topo.aux, I32)
    # wire latency after departure, per emitter kind
    lat_q = np.zeros(NE, np.int32)
    lat_q[topo.kind == KIND_T0_UP] = link.link_lat_ticks + link.switch_lat_ticks
    lat_q[topo.kind == KIND_T1_DOWN] = link.link_lat_ticks + link.switch_lat_ticks
    lat_q[topo.kind == KIND_T0_DOWN] = link.link_lat_ticks
    lat_q[topo.kind == KIND_SENDER] = 1 + link.link_lat_ticks + link.switch_lat_ticks
    lat_q = jnp.asarray(lat_q)

    # ---- fault maps ----
    service_period = np.ones(NQ, np.int32)
    dead = np.zeros(NQ, bool)
    for (r, k, period) in cfg.faults:
        q = topo.t0_up(r, k)
        if period == 0:
            dead[q] = True
        else:
            service_period[q] = period
    service_period = jnp.asarray(service_period)
    dead = jnp.asarray(dead)
    fault_start = jnp.asarray(cfg.fault_start, I32)

    kmin = 0.2 * CAP
    kmax = 0.8 * CAP

    mtu_i = int(MTU)

    def pkt_size(flow, seq):
        """True wire size of packet `seq` of `flow` (last packet may be short)."""
        rem = size_f[jnp.clip(flow, 0, NF - 1)] - seq * mtu_i
        return jnp.clip(rem, 0, mtu_i)

    def route_from_queue(qidx, flow, ent):
        d = dst[jnp.clip(flow, 0, NF - 1)]
        drack = d // M
        k, rk, ax = kind[qidx], e_rack[qidx], e_aux[qidx]
        r_up = PU + ax * P + drack          # t0_up -> t1_down[spine, drack]
        r_t1 = 2 * PU + d                   # t1_down -> t0_down[dst]
        r_del = -(d + 1)                    # t0_down -> deliver
        return jnp.where(k == KIND_T0_UP, r_up,
                         jnp.where(k == KIND_T1_DOWN, r_t1, r_del))

    def route_from_sender(f, ent):
        sr = src[f] // M
        d = dst[f]
        h = (hashing.hash2(ent.astype(jnp.uint32), (sr * 0x9E37 + 0x1234).astype(jnp.uint32))
             % jnp.uint32(U)).astype(I32)
        return jnp.where(d // M == sr, 2 * PU + d, sr * U + h)

    # ------------------------------------------------------------------
    def init() -> SimState:
        zeros = jnp.zeros
        cc = init_cc_state(NF, cc_params,
                           start_cwnd=cfg.start_cwnd_mult * bdp)
        lb = reps.init_lb_state(NF, lb_params)
        m = Metrics(*(zeros((), F32 if i in (7,) else I32) for i in range(8)),
                    rtt_hist=zeros((HIST_BINS,), I32),
                    q_sum=zeros((), F32), q_max=zeros((), I32),
                    spurious_retx=zeros((), I32))
        return SimState(
            now=zeros((), I32),
            salt=zeros((), I32),
            q_fields=zeros((NQ + 1, CAP, 5), I32),
            q_head=zeros((NQ + 1,), I32),
            q_size=zeros((NQ + 1,), I32),
            infl=zeros((L + 1, NE, 7), I32),
            ack_ring=zeros((R, N, 6), I32),
            trim_cnt=zeros((R, NF + 1), I32),
            trim_bytes=zeros((R, NF + 1), F32),
            lost_bits=zeros((R, NF + 1, WW), I32),
            credit_ring=zeros((R, NF + 1), F32),
            st_state=zeros((NF + 1, W), I32),
            st_seq=zeros((NF + 1, W), I32),
            st_ts=zeros((NF + 1, W), I32),
            next_seq=zeros((NF,), I32),
            done=zeros((NF,), bool),
            fct=jnp.full((NF,), -1, I32),
            goodput=zeros((NF,), I32),
            bitmap=zeros((NF + 1, MAXW), I32),
            granted=zeros((NF,), F32),
            trim_seen=zeros((NF,), F32),
            rr_recv=zeros((N,), I32),
            rr_send=zeros((N,), I32),
            pace_accum=zeros((NF,), F32),
            cc=cc, lb=lb, m=m,
        )

    # ------------------------------------------------------------------
    def step(st: SimState) -> SimState:
        t = st.now
        m = st.m

        # ============ 1. departures ============
        qidx = jnp.arange(NQ, dtype=I32)
        in_fault = t >= fault_start
        svc = jnp.where(in_fault & (service_period > 1),
                        (t % jnp.maximum(service_period, 1)) == 0, True)
        active = (st.q_size[:NQ] > 0) & svc
        head = st.q_head[:NQ]
        hf = st.q_fields[qidx, head]                      # [NQ, 5]
        d_flow, d_seq, d_ent, d_ecn, d_ts = (hf[:, i] for i in range(5))
        # RED marking at dequeue (paper Sec. 2.1 / 3.5)
        qsz = st.q_size[:NQ].astype(F32)
        pmark = jnp.clip((qsz - kmin) / (kmax - kmin), 0.0, 1.0)
        mark = hashing.uniform01(t * jnp.int32(131071) + qidx,
                                 jnp.int32(0xECD) + st.salt) < pmark
        d_ecn = d_ecn | (mark & active).astype(I32)
        black = dead[qidx] & active & in_fault
        emit = active & ~black
        next_q = route_from_queue(qidx, d_flow, d_ent)
        q_head = st.q_head.at[:NQ].set(jnp.where(active, (head + 1) % CAP, head))
        q_size = st.q_size.at[:NQ].add(-active.astype(I32))
        slot = jnp.where(emit, (t + lat_q[:NQ]) % L, L)
        payload = jnp.stack(
            [emit.astype(I32), next_q, d_flow, d_seq, d_ent, d_ecn, d_ts], axis=1)
        infl = st.infl.at[slot, qidx].set(payload)
        m = m._replace(n_black=m.n_black + jnp.sum(black.astype(I32)))

        # ============ 2. arrivals ============
        arr = infl[t % L]                                  # [NE, 7]
        infl = infl.at[t % L].set(0)
        a_valid = arr[:, 0] == 1
        a_dstq, a_flow, a_seq, a_ent, a_ecn, a_ts = (arr[:, i] for i in range(1, 7))
        deliver = a_valid & (a_dstq < 0)
        enq = a_valid & (a_dstq >= 0)

        # ---- deliveries ----
        node = jnp.where(deliver, -a_dstq - 1, 0)
        dflow = jnp.where(deliver, a_flow, NF)
        word, bit = a_seq // 32, a_seq % 32
        old = st.bitmap[dflow, word]
        isnew = deliver & (((old >> bit) & 1) == 0)
        bitmap = st.bitmap.at[dflow, word].add(
            jnp.where(isnew, (1 << bit).astype(I32), 0))
        psz = pkt_size(a_flow, a_seq)
        goodput = st.goodput.at[jnp.where(isnew, a_flow, 0)].add(
            jnp.where(isnew, psz, 0))
        newly_done = (goodput >= size_f) & ~st.done
        done = st.done | newly_done
        fct = jnp.where(newly_done, t + ret_f - t_start, st.fct)
        # ACK generation (echoes entropy + ECN + timestamp; priority path)
        anode = jnp.where(deliver, node, N)
        aslot = (t + ret_f[jnp.clip(a_flow, 0, NF - 1)]) % R
        aslot = jnp.where(deliver, aslot, 0)
        ack_payload = jnp.stack(
            [deliver.astype(I32), a_flow, a_seq, a_ecn, a_ent, a_ts], axis=1)
        ack_ring = jnp.pad(st.ack_ring, ((0, 0), (0, 1), (0, 0)))
        ack_ring = ack_ring.at[aslot, anode].set(ack_payload)[:, :N]
        m = m._replace(
            delivered_pkts=m.delivered_pkts + jnp.sum(deliver.astype(I32)),
            delivered_bytes=m.delivered_bytes + jnp.sum(jnp.where(isnew, psz, 0)).astype(F32),
        )

        # ---- enqueues (sorted scatter with capacity + trim) ----
        edst = jnp.where(enq, a_dstq, NQ)
        order = jnp.argsort(edst)
        ds = edst[order]
        eflow, eseq, eent, eecn, ets = (x[order] for x in (a_flow, a_seq, a_ent, a_ecn, a_ts))
        first = jnp.searchsorted(ds, ds, side="left")
        rank = jnp.arange(NE, dtype=first.dtype) - first
        space = CAP - q_size[ds]
        acc = (ds < NQ) & (rank < space)
        pos = (q_head[ds] + q_size[ds] + rank.astype(I32)) % CAP
        row = jnp.where(acc, ds, NQ)
        posw = jnp.where(acc, pos, 0)
        q_fields = st.q_fields.at[row, posw].set(
            jnp.stack([eflow, eseq, eent, eecn, ets], axis=1))
        q_size = q_size + jax.ops.segment_sum(acc.astype(I32), ds, num_segments=NQ + 1)
        rej = (ds < NQ) & ~acc
        # trim (paper: only when the buffer is full) or drop
        rflow = jnp.where(rej, eflow, NF)
        # receiver-side trim visibility (EQDS: trimmed headers reach the
        # receiver, which re-schedules the pull — paper Sec. 2.2)
        trim_seen = jnp.pad(st.trim_seen, (0, 1)).at[rflow].add(
            jnp.where(rej, pkt_size(eflow, eseq).astype(F32), 0.0))[:NF]
        if cfg.trimming:
            tslot = jnp.where(rej, (t + tm.trim_delay) % R, 0)
            trim_cnt = st.trim_cnt.at[tslot, rflow].add(rej.astype(I32))
            trim_bytes = st.trim_bytes.at[tslot, rflow].add(
                jnp.where(rej, pkt_size(eflow, eseq).astype(F32), 0.0))
            wslot = (eseq % W) // 32
            wbit = (eseq % W) % 32
            lost_bits = st.lost_bits.at[tslot, rflow, wslot].add(
                jnp.where(rej, (1 << wbit).astype(I32), 0))
            m = m._replace(n_trim=m.n_trim + jnp.sum(rej.astype(I32)))
        else:
            trim_cnt, trim_bytes, lost_bits = st.trim_cnt, st.trim_bytes, st.lost_bits
            m = m._replace(n_drop=m.n_drop + jnp.sum(rej.astype(I32)))

        # ============ 3. control events ============
        acks = ack_ring[t % R]                             # [N, 6]
        ack_ring = ack_ring.at[t % R].set(0)
        v = acks[:, 0] == 1
        idxf = jnp.where(v, acks[:, 1], NF)

        def scat(vals, fill=0):
            return jnp.full((NF + 1,), fill, vals.dtype).at[idxf].set(vals)[:NF]

        has_ack = jnp.zeros((NF + 1,), bool).at[idxf].set(v)[:NF]
        ack_seq = scat(acks[:, 2])
        ack_ecn = jnp.zeros((NF + 1,), bool).at[idxf].set(acks[:, 3] == 1)[:NF]
        ack_ent = scat(acks[:, 4])
        ack_ts = scat(acks[:, 5])
        rtt = jnp.where(has_ack, (t - ack_ts).astype(F32), 0.0)
        ack_bytes = jnp.where(has_ack, pkt_size(flow_ids, ack_seq).astype(F32), 0.0)

        trims = trim_cnt[t % R][:NF]
        tbytes = trim_bytes[t % R][:NF]
        lbits = lost_bits[t % R][:NF]
        cred = credit_ring_now = st.credit_ring[t % R][:NF]
        trim_cnt = trim_cnt.at[t % R].set(0)
        trim_bytes = trim_bytes.at[t % R].set(0.0)
        lost_bits = lost_bits.at[t % R].set(0)
        credit_ring = st.credit_ring.at[t % R].set(0.0)

        # transport: free the ACKed slot
        aslot2 = ack_seq % W
        cur = st.st_state[flow_ids, aslot2]
        cur_seq = st.st_seq[flow_ids, aslot2]
        match = has_ack & (cur != 0) & (cur_seq == ack_seq)
        st_state = st.st_state.at[flow_ids, aslot2].set(jnp.where(match, 0, cur))

        # trimmed packets -> lost (awaiting retransmission)
        wbits = jnp.arange(W, dtype=I32)
        bitsel = (lbits[:, wbits // 32] >> (wbits % 32)) & 1      # [NF, W]
        lost_mask = (bitsel == 1) & (st_state[:NF] == 1)
        st_state = st_state.at[:NF].set(jnp.where(lost_mask, 3, st_state[:NF]))

        # timeouts
        started_flows = (t >= t_start) & ~done
        to_mask = (st_state[:NF] == 1) & \
            ((t - st.st_ts[:NF]).astype(F32) > rto_f[:, None]) & started_flows[:, None]
        # count a spurious retx when the receiver already has the packet
        sp_word = st.st_seq[:NF] // 32
        sp_bit = st.st_seq[:NF] % 32
        already = ((bitmap[:NF][jnp.arange(NF)[:, None], sp_word] >> sp_bit) & 1) == 1
        m = m._replace(spurious_retx=m.spurious_retx
                       + jnp.sum((to_mask & already).astype(I32)))
        st_state = st_state.at[:NF].set(jnp.where(to_mask, 3, st_state[:NF]))
        n_to = jnp.sum(to_mask.astype(I32), axis=1)
        to_bytes = n_to.astype(F32) * MTU
        m = m._replace(n_to=m.n_to + jnp.sum(n_to))

        unacked = jnp.sum((st_state[:NF] == 1).astype(I32), axis=1).astype(F32) * MTU

        ev = CCEvent(
            has_ack=has_ack, ack_bytes=ack_bytes, ecn=ack_ecn, rtt=rtt,
            ack_entropy=ack_ent, n_trims=trims, trim_bytes=tbytes,
            n_timeouts=n_to, to_bytes=to_bytes, unacked=unacked,
            credit_grant=cred,
        )
        cc = cc_update(cc_params, st.cc, ev, t)
        lb = reps.on_ack(lb_mode, lb_params, st.lb, has_ack, ack_ecn, ack_ent,
                         flow_ids, t)
        # RTT histogram
        bins = jnp.clip((rtt * (8.0 / tm.brtt_inter)).astype(I32), 0, HIST_BINS - 1)
        m = m._replace(
            rtt_hist=m.rtt_hist.at[jnp.where(has_ack, bins, 0)].add(has_ack.astype(I32)),
            n_ack=m.n_ack + jnp.sum(has_ack.astype(I32)),
        )

        # ============ 4. EQDS receiver credit grants ============
        granted = st.granted
        rr_recv = st.rr_recv
        if credit_based:
            # outstanding credit window above received + known-lost bytes:
            # self-clocks, and re-grants for trimmed packets (the receiver
            # sees trimmed headers) so retransmissions never starve.
            demand = started_flows & (
                granted - goodput.astype(F32) - trim_seen < credit_window)
            dm = jnp.pad(demand, (0, 1))[flows_by_recv]          # [N, FR]
            keys = (jnp.arange(FRMAX, dtype=I32)[None, :] - rr_recv[:, None]) % FRMAX
            keys = jnp.where(dm, keys, FRMAX + 1)
            sel = jnp.argmin(keys, axis=1)
            has_g = jnp.any(dm, axis=1)
            gflow = jnp.where(has_g, flows_by_recv[jnp.arange(N), sel], NF)
            gslot = jnp.where(has_g, (t + ret_f[jnp.clip(gflow, 0, NF - 1)]) % R, 0)
            credit_ring = credit_ring.at[gslot, gflow].add(
                jnp.where(has_g, MTU, 0.0))
            granted = jnp.pad(granted, (0, 1)).at[gflow].add(
                jnp.where(has_g, MTU, 0.0))[:NF]
            rr_recv = jnp.where(has_g, (sel.astype(I32) + 1) % FRMAX, rr_recv)

        # ============ 5. sends ============
        pace = st.pace_accum
        if paced:
            pace = jnp.minimum(pace + cc.pacing_rate, 4.0 * MTU)

        # windowed-alltoall eligibility: < window unfinished predecessors
        done_p = jnp.pad(done, (0, 1), constant_values=True)
        unfin = (~done_p[flows_of]) & (flows_of < NF)            # [N, FMAX]
        prior_unfin = jnp.cumsum(unfin, axis=1) - unfin.astype(I32)
        win_elig = jnp.full((NF + 1,), False).at[flows_of.reshape(-1)].set(
            (prior_unfin < window).reshape(-1))[:NF]

        started = (t >= t_start) & ~done & win_elig
        has_retx = jnp.any(st_state[:NF] == 3, axis=1)
        retx_slot = jnp.argmax(st_state[:NF] == 3, axis=1)
        retx_seq = st.st_seq[flow_ids, retx_slot]
        new_seq = st.next_seq
        new_slot = new_seq % W
        new_ok = (new_seq * mtu_i < size_f) & (st_state[flow_ids, new_slot] == 0)
        seq_emit = jnp.where(has_retx, retx_seq, new_seq)
        nsize = pkt_size(flow_ids, seq_emit).astype(F32)
        win_ok = unacked + nsize <= cc.cwnd
        credit_ok = True
        if credit_based:
            credit_ok = (cc.credits >= nsize) | (cc.spec_budget >= nsize)
        pace_ok = (pace >= nsize) if paced else True
        elig = started & (has_retx | new_ok) & win_ok & credit_ok & pace_ok & (nsize > 0)

        # per-sender round-robin arbitration (one packet per NIC per tick)
        E = jnp.pad(elig, (0, 1))[flows_of]                      # [N, FMAX]
        keys = (jnp.arange(FMAX, dtype=I32)[None, :] - st.rr_send[:, None]) % FMAX
        keys = jnp.where(E, keys, FMAX + 1)
        sel = jnp.argmin(keys, axis=1)
        has_s = jnp.any(E, axis=1)
        sflow = jnp.where(has_s, flows_of[jnp.arange(N), sel], NF)
        rr_send = jnp.where(has_s, (sel.astype(I32) + 1) % FMAX, st.rr_send)

        emit_mask = jnp.zeros((NF + 1,), bool).at[sflow].set(has_s)[:NF]
        lb, entropy = reps.on_send(lb_mode, lb_params, lb, emit_mask, seq_emit,
                                   flow_ids, t)
        first_q = route_from_sender(flow_ids, entropy)

        # place on the wire
        send_slot = jnp.where(has_s, (t + lat_q[NQ]) % L, L)
        sf = jnp.clip(sflow, 0, NF - 1)
        spay = jnp.stack([
            has_s.astype(I32),
            first_q[sf],
            sflow,
            seq_emit[sf],
            entropy[sf],
            jnp.zeros((N,), I32),
            jnp.full((N,), 1, I32) * t,
        ], axis=1)
        infl = infl.at[send_slot, NQ + jnp.arange(N)].set(spay)

        # sent-ring bookkeeping
        eslot = seq_emit % W
        eflow2 = jnp.where(emit_mask, flow_ids, NF)
        st_state = st_state.at[eflow2, eslot].set(
            jnp.where(emit_mask, 1, st_state[eflow2, eslot]))
        st_seq = st.st_seq.at[eflow2, eslot].set(
            jnp.where(emit_mask, seq_emit, st.st_seq[eflow2, eslot]))
        st_ts = st.st_ts.at[eflow2, eslot].set(
            jnp.where(emit_mask, t, st.st_ts[eflow2, eslot]))
        is_new_send = emit_mask & ~has_retx
        next_seq = st.next_seq + is_new_send.astype(I32)
        m = m._replace(n_retx=m.n_retx + jnp.sum((emit_mask & has_retx).astype(I32)))

        spend = jnp.where(emit_mask, nsize, 0.0)
        if credit_based:
            use_credit = cc.credits >= nsize
            cc = cc._replace(
                credits=cc.credits - spend * use_credit,
                spec_budget=cc.spec_budget - spend * (~use_credit),
            )
        if paced:
            pace = pace - spend

        # ============ 6. metrics ============
        m = m._replace(
            q_sum=m.q_sum + jnp.sum(q_size[:NQ]).astype(F32),
            q_max=jnp.maximum(m.q_max, jnp.max(q_size[:NQ])),
        )

        return SimState(
            now=t + 1, salt=st.salt,
            q_fields=q_fields, q_head=q_head, q_size=q_size,
            infl=infl, ack_ring=ack_ring, trim_cnt=trim_cnt,
            trim_bytes=trim_bytes, lost_bits=lost_bits, credit_ring=credit_ring,
            st_state=st_state, st_seq=st_seq, st_ts=st_ts, next_seq=next_seq,
            done=done, fct=fct, goodput=goodput, bitmap=bitmap,
            granted=granted, trim_seen=trim_seen, rr_recv=rr_recv, rr_send=rr_send,
            pace_accum=pace, cc=cc, lb=lb, m=m,
        )

    dims = dict(N=N, NQ=NQ, NE=NE, NF=NF, CAP=CAP, W=W, R=R, L=L,
                MAXW=MAXW, FMAX=FMAX, FRMAX=FRMAX,
                brtt=tm.brtt_inter, bdp_bytes=bdp, mtu=mtu_i)
    return Sim(cfg=cfg, topo=topo, timing=tm, wl=wl, cc_params=cc_params,
               lb_params=lb_params, dims=dims, step=step, init=init)


# --------------------------------------------------------------------------
# run loops
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0, 2))
def _run_until_done(step, state0: SimState, max_ticks: int) -> SimState:
    def cond(st):
        return (st.now < max_ticks) & ~jnp.all(st.done)

    return jax.lax.while_loop(cond, step, state0)


@functools.partial(jax.jit, static_argnums=(0, 2))
def _run_batch(step, states: SimState, max_ticks: int) -> SimState:
    """Run a [B]-batched state bundle to completion (vmapped step)."""
    vstep = jax.vmap(step)

    def cond(st):
        return (st.now[0] < max_ticks) & ~jnp.all(st.done)

    return jax.lax.while_loop(cond, vstep, states)


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def _run_trace(step, state0: SimState, ticks: int, trace_flows: int):
    tf = trace_flows

    def body(st, _):
        st2 = step(st)
        nq = st2.q_size.shape[0] - 1
        ys = dict(
            cwnd=st2.cc.cwnd[:tf],
            q_mean=jnp.mean(st2.q_size[:nq].astype(F32)),
            q_max=jnp.max(st2.q_size[:nq]),
            delivered=st2.m.delivered_bytes,
            goodput=st2.goodput[:tf],
            done=jnp.sum(st2.done.astype(I32)),
        )
        return st2, ys

    return jax.lax.scan(body, state0, None, length=ticks)


# --------------------------------------------------------------------------
# result extraction
# --------------------------------------------------------------------------


def summarize(sim: Sim, st: SimState) -> dict:
    """Pull host-side summary statistics from a finished run."""
    fct = np.asarray(st.fct)
    done = np.asarray(st.done)
    mtu = sim.dims["mtu"]
    m = st.m
    out = dict(
        ticks=int(st.now),
        all_done=bool(done.all()),
        n_done=int(done.sum()),
        fct_ticks=fct,
        fct_max=int(fct.max()) if done.any() else -1,
        fct_min=int(fct[done].min()) if done.any() else -1,
        fct_mean=float(fct[done].mean()) if done.any() else -1.0,
        fct_p99=float(np.percentile(fct[done], 99)) if done.any() else -1.0,
        spread=float(fct[done].max() - fct[done].min()) if done.any() else -1.0,
        trims=int(m.n_trim), drops=int(m.n_drop), blackholed=int(m.n_black),
        timeouts=int(m.n_to), retx=int(m.n_retx), acks=int(m.n_ack),
        delivered_bytes=float(m.delivered_bytes),
        spurious_retx=int(m.spurious_retx),
        rtt_hist=np.asarray(m.rtt_hist),
        q_mean=float(m.q_sum) / max(1, int(st.now)) / sim.dims["NQ"],
        q_max=int(m.q_max),
        goodput_bytes=np.asarray(st.goodput),
    )
    total_pkts = max(1, int(m.delivered_pkts))
    out["spurious_frac"] = out["spurious_retx"] / total_pkts
    # ideal completion: bytes through the tightest static bottleneck
    out["mtu"] = mtu
    return out


def jain_fairness(values: np.ndarray) -> float:
    v = np.asarray(values, np.float64)
    if v.sum() == 0:
        return 1.0
    return float(v.sum() ** 2 / (len(v) * (v ** 2).sum()))
