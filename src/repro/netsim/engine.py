"""Vectorized, time-stepped packet-level simulator — composition layer.

Execution model (DESIGN.md Sec. 6): one tick = one MTU serialization time;
every output port forwards at most one data packet per tick.  All state is
struct-of-arrays with static shapes; one tick is a pure function
``step: SimState -> SimState`` executed in superstep-fused run loops
(aggregate runs, early exit) or under ``lax.scan`` (trace runs, per-tick
outputs).

The aggregate run loops execute in *supersteps* (DESIGN.md Sec. 6): a
``lax.fori_loop`` fuses ``Dims.superstep`` ticks per ``while_loop``
iteration, amortizing the while-loop round-trip (cond dispatch + carry
handling) over K ticks; each fused tick is individually gated on the same
exit condition (``lax.cond``), keeping every trajectory bit-for-bit
identical to the K=1 loop.  When ``Dims.leap`` holds, each superstep first
applies an *event-horizon time leap* (DESIGN.md Sec. 6.3): a cheap
reduction over the delay rings, armed timers, and admission predicates
yields the distance to the next eventful tick, and ``now`` advances by it
in O(1) — event-free ticks are state no-ops by construction, so the
leap-on trajectory stays bit-for-bit equal to leap-off.  All run-loop
entry points donate the incoming ``SimState`` buffers to XLA (callers
must treat a state passed to a run loop as consumed).

The six sub-steps of a tick live in dedicated phase modules, each a pure
function ``(Dims, Consts, SimState) -> SimState``:

  1. departures : ``fabric.departures``  (dequeue, RED mark, route, wire)
  2. arrivals   : ``fabric.arrivals``    (enqueue/trim/drop or deliver/ACK)
  3. control    : ``transport.control``  (ACK/trim/timeout -> CC + LB)
  4. grants     : ``sender.grants``      (EQDS pull credits)
  5. sends      : ``sender.sends``       (arbitration, admission, emission)
  6. metrics    : ``metrics.account``    (occupancy/rate accounting)

``build`` resolves the CC algorithm to a backend-qualified update function
(``cc_backend="jnp"`` pure jnp, or ``"pallas"`` for the ``kernels/
cc_update`` kernel) — and, the same way, the fabric's fused
enqueue-rank/arbitration pair (``fabric_backend`` ->
``kernels/enqueue_arb``) and the transport's packed sent-ring drain
(``transport_backend`` -> ``kernels/ring_drain``); every backend pair is
bit-for-bit interchangeable (DESIGN.md Sec. 6.4).  The phases compose
over a ``Consts`` bundle of traced numerics — so retuning any parameter,
or sweeping a whole grid of them, reuses one compiled step.  Batched execution (seed batches, sweep
grids, full seed x point studies) lives in the experiment API
(``netsim/api.py``, DESIGN.md Sec. 7): its lane loop vmaps ``step_fn``
over ``[P*S]`` lanes with per-lane exit gating and leap horizons;
``Sim.run_batch`` here is a thin wrapper over it.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.analysis import counter as _trace_counter
from repro.core import registry, reps
from repro.core.types import CCParams
from repro.kernels.enqueue_arb import ops as enqueue_arb_ops
from repro.kernels.ring_drain import ops as ring_drain_ops
from repro.netsim import fabric, metrics, sender, transport
from repro.netsim.metrics import HIST_BINS, jain_fairness, summarize  # noqa: F401 (re-export)
from repro.netsim.state import (Consts, Dims, SimConfig, SimState,  # noqa: F401
                                derive, init_state)
from repro.netsim.topology import Topology
from repro.netsim.units import Timing
from repro.netsim.workloads import Workload

I32 = jnp.int32
F32 = jnp.float32

# Incremented each time a composed step function is *traced* (not executed).
# ``tests/test_sweep.py`` asserts a whole parameter grid costs exactly one:
# ``with trace_guard("engine.step", expect=1): ...`` (repro.analysis).
_STEP_TRACES = _trace_counter("engine.step")


@dataclasses.dataclass(frozen=True)
class Sim:
    """Compiled simulator bundle."""

    cfg: SimConfig
    topo: Topology
    timing: Timing
    wl: Workload
    cc_params: CCParams
    lb_params: reps.LBParams
    dims: Dims
    consts: Consts
    phases: tuple           # ordered ((name, (Consts, SimState) -> SimState),
                            #   ...) — the six tick sub-steps step_fn composes;
                            # the phase profiler (benchmarks/profile_tick) and
                            # the jaxpr auditor (repro.analysis.audit) walk
                            # these so their phase split can never drift from
                            # the real tick
    step_fn: callable       # (Consts, SimState) -> SimState — sweepable form
    step: callable          # SimState -> SimState (consts bound)
    horizon_fn: callable    # (Consts, SimState) -> i32 next-event distance
    horizon: callable       # SimState -> i32 (consts bound)
    init: callable          # () -> SimState

    def _leap_horizon(self):
        return self.horizon if self.dims.leap else None

    def run(self, max_ticks: int, seed: int = 0) -> SimState:
        """Run to completion.  ``seed`` sets the per-run hash salt
        (RED/ECMP decorrelation) — seed 0 is the historical default."""
        st0 = self.init()
        if seed:
            st0 = st0._replace(salt=jnp.asarray(seed, I32))
        return _run_until_done(self.step, self._leap_horizon(), st0,
                               max_ticks, self.dims.superstep)

    def run_trace(self, ticks: int, trace_flows: int = 8):
        return _run_trace(self.step, self.init(), ticks, trace_flows)

    def run_batch(self, seeds, max_ticks: int, mesh=None) -> SimState:
        """vmap a batch of decorrelated runs (per-seed RED/ECMP salts) —
        a thin compatibility wrapper over the sharded lane loop
        (``shard.run_lanes``; one compiled step, per-lane exit gating and
        leap horizons, so each lane matches its standalone ``run(seed=s)``
        bit-for-bit).  ``mesh`` (a ``shard.lane_mesh()``) spreads the
        batch across devices; the default stays single-device vmap.

        The init state is built once and broadcast over the batch —
        only the per-seed ``salt`` is scattered (asserted by the
        ``trace_guard("state.init")`` check in tests/test_engine_leap.py);
        each broadcast leaf is a fresh buffer, so donation stays legal.
        """
        import numpy as _np

        from repro.netsim import api, shard
        seeds = jnp.asarray(_np.asarray(seeds), I32)
        base = self.init()
        states = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (seeds.shape[0],) + x.shape),
            base)
        states = states._replace(salt=seeds)
        return shard.run_lanes(self.step_fn,
                               self.horizon_fn if self.dims.leap else None,
                               api.no_axes(self.consts), max_ticks,
                               self.dims.superstep, self.consts, states,
                               mesh=mesh)


# --------------------------------------------------------------------------
# build
# --------------------------------------------------------------------------


def build(cfg: SimConfig, wl: Workload) -> Sim:
    topo, tm, dims, consts = derive(cfg, wl)
    cc_update = registry.get(cfg.algo, cfg.cc_backend)
    # fabric/transport hot-loop backends, resolved once like cc_update:
    # enqueue-rank + round-robin arbitration (kernels/enqueue_arb) and the
    # packed sent-ring drain (kernels/ring_drain) — "jnp" is the reference
    # vector program, "pallas" the bit-identical blocked kernel
    enqueue, arb = enqueue_arb_ops.get(cfg.fabric_backend)
    drain = ring_drain_ops.get(cfg.transport_backend)

    phases = (
        ("departures", lambda c, st: fabric.departures(dims, c, st)),
        ("arrivals", lambda c, st: fabric.arrivals(dims, c, st,
                                                   enqueue=enqueue)),
        ("control", lambda c, st: transport.control(dims, c, cc_update, st,
                                                    drain=drain)),
        ("grants", lambda c, st: sender.grants(dims, c, st, arb=arb)),
        ("sends", lambda c, st: sender.sends(dims, c, st, arb=arb)),
        ("metrics", lambda c, st: metrics.account(dims, c, st)),
    )

    def step_fn(consts: Consts, st: SimState) -> SimState:
        _STEP_TRACES.hit()
        for _, phase in phases:
            st = phase(consts, st)
        return st._replace(now=st.now + 1)

    def step(st: SimState) -> SimState:
        return step_fn(consts, st)

    def horizon_fn(consts: Consts, st: SimState):
        """Distance (ticks) to the next eventful tick — min over the
        per-phase next-event reductions (DESIGN.md Sec. 6.3)."""
        h = fabric.horizon(dims, consts, st)
        h = jnp.minimum(h, transport.horizon(dims, consts, st))
        return jnp.minimum(h, sender.horizon(dims, consts, st))

    def horizon(st: SimState):
        return horizon_fn(consts, st)

    def init() -> SimState:
        return init_state(dims, consts)

    return Sim(cfg=cfg, topo=topo, timing=tm, wl=wl, cc_params=consts.cc,
               lb_params=consts.lb, dims=dims, consts=consts, phases=phases,
               step_fn=step_fn, step=step, horizon_fn=horizon_fn,
               horizon=horizon, init=init)


# --------------------------------------------------------------------------
# run loops (superstep execution; donated state buffers)
# --------------------------------------------------------------------------
#
# The outer while loop advances one *superstep* (K fused ticks) per
# iteration, amortizing the loop round-trip over K ticks.  Each fused tick
# is gated on the *same* exit predicate via ``lax.cond`` (so the cheap
# reduction still runs per tick, but as part of the fused body) — the
# predicate is scalar (reduced over flows; the api lane loop additionally
# gates each lane on its own predicate) so the cond stays a real branch,
# and once the run
# finishes or hits max_ticks the remaining ticks of the superstep are
# identity — which makes every K > 1 trajectory bit-for-bit identical to
# K = 1, including ``now`` and all metrics counters (asserted in
# tests/test_engine_superstep.py).
#
# ``donate_argnums`` hands the incoming state's buffers to XLA for in-place
# reuse as the loop carry.  Contract: a ``SimState`` passed to a run loop
# is consumed — callers must not read it afterwards (all entry points here
# build a fresh ``init()`` per call).


def _superstep_loop(step, cond, K, leap=None):
    """while(cond) { leap?; K x (cond ? step : id) } — cond reduced once
    per K.

    Every K (including 1) uses the same gated fori-in-while structure, so
    the tick graph is embedded — and therefore lowered by XLA — identically
    for every superstep size; only the trip count changes.  (Embedding the
    K=1 tick bare in the while body changes XLA's fusion/FMA-contraction
    decisions and perturbs f32 CC arithmetic by an ULP, which would break
    the bit-for-bit equivalence contract across K.)

    ``leap``, when given, runs once per superstep before the fused ticks:
    it advances ``now`` to the next event horizon in O(1) (DESIGN.md Sec.
    6.3).  The leap lands *at or before* the next eventful tick and the
    leap distance is clamped to the remaining tick budget, so the gated
    ticks that follow execute exactly the eventful ticks (plus event-free
    ticks, which are state no-ops) of the leap-free trajectory."""
    def tick(_, st):
        return jax.lax.cond(cond(st), step, lambda s: s, st)

    def body(st):
        if leap is not None:
            st = leap(st)
        return jax.lax.fori_loop(0, max(K, 1), tick, st)

    return lambda st: jax.lax.while_loop(cond, body, st)


def _leap(horizon, max_ticks):
    """Single-run time leap: jump ``now`` to the next event horizon and
    apply the closed-form Δ-tick accounting (``metrics.leap_account``).

    Today's leap predicate only jumps with every queue empty, so the
    occupancy integral provably contributes 0.0 — the general Δ * Σq form
    is kept so a relaxed predicate (e.g. leaping a degraded link's idle
    service periods with packets parked) inherits correct accounting."""
    def leap(st):
        d = jnp.minimum(horizon(st), max_ticks - st.now)
        occ = jnp.sum(st.q_size[:-1])
        return st._replace(now=st.now + d,
                           m=metrics.leap_account(st.m, d, occ))
    return leap


@functools.partial(jax.jit, static_argnums=(0, 1, 3, 4), donate_argnums=(2,))
def _run_until_done(step, horizon, state0: SimState, max_ticks: int,
                    superstep: int) -> SimState:
    def cond(st):
        return (st.now < max_ticks) & ~jnp.all(st.done)

    leap = _leap(horizon, max_ticks) if horizon is not None else None
    return _superstep_loop(step, cond, superstep, leap)(state0)


@functools.partial(jax.jit, static_argnums=(0, 2, 3), donate_argnums=(1,))
def _run_trace(step, state0: SimState, ticks: int, trace_flows: int):
    tf = trace_flows

    def body(st, _):
        st2 = step(st)
        nq = st2.q_size.shape[0] - 1
        ys = dict(
            cwnd=st2.cc.cwnd[:tf],
            q_mean=jnp.mean(st2.q_size[:nq].astype(F32)),
            q_max=jnp.max(st2.q_size[:nq]),
            delivered=st2.m.delivered_bytes,
            goodput=st2.goodput[:tf],
            done=jnp.sum(st2.done.astype(I32)),
        )
        return st2, ys

    return jax.lax.scan(body, state0, None, length=ticks)
