"""Declarative scenario catalogue: named, frozen (config, workload,
max_ticks) bundles — the string-addressable entry points of the
experiment API (DESIGN.md Sec. 7).

A :class:`Scenario` fixes everything a run needs *except* the tuning
point and the seed: the fabric (``SimConfig.tree``/``link``), the
algorithm and load balancer, fault injection, the traffic table, and the
tick budget.  ``netsim/api.py`` takes a Scenario and lowers
``Scenario x sweep points x seeds`` onto one compiled step.

The registry maps short stable names (``"incast8_32n"``, ``"perm64"``,
``"sparse_heavy_32n"``, ...) to factories; the names double as benchmark
ledger keys (``BENCH_netsim.json``), so keep them stable.  ``scenario()``
resolves a name and applies per-call config overrides::

    sc = scenario("perm64", algo="swift")          # same grid, new CC
    sc = scenario("incast8_32n", max_ticks=30_000)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.netsim import collectives, faults, workloads
from repro.netsim.state import SimConfig
from repro.netsim.units import FatTreeConfig, LinkConfig
from repro.netsim.workloads import Workload

KiB = 1024
MiB = 1024 * 1024

# Standard scaled topologies (EXPERIMENTS.md Sec. "Scaled topologies").
# benchmarks/common.py re-exports these; the paper's 1024-node 800 Gb/s
# fabric is scaled to CPU-tractable sizes with relative behavior as the
# reproduction target.
TREE_8TO1 = FatTreeConfig(racks=8, nodes_per_rack=16, uplinks=2)   # 128 nodes
TREE_4TO1 = FatTreeConfig(racks=4, nodes_per_rack=16, uplinks=4)   # 64 nodes
TREE_2TO1 = FatTreeConfig(racks=4, nodes_per_rack=16, uplinks=8)   # 64 nodes
TREE_FLAT = FatTreeConfig(racks=4, nodes_per_rack=8, uplinks=8)    # 32, 1:1
TREE_16 = FatTreeConfig(racks=2, nodes_per_rack=8, uplinks=2)      # 16, 4:1
TREE_TINY = FatTreeConfig(racks=2, nodes_per_rack=2, uplinks=2)    # 4 nodes

# Three-tier fat trees (pods of racks + a T2 core plane) — the paper's
# evaluation shape (Sec. 4: up to 1024 endpoints on a 3-tier oversubscribed
# fat tree), scaled to CPU-tractable sizes.  Oversubscription is per tier:
# T0 = nodes_per_rack/uplinks, T1 = racks_per_pod/core_uplinks.
TREE_1024_3T = FatTreeConfig(racks=128, nodes_per_rack=8, uplinks=4,
                             pods=8, core_uplinks=4)  # 1024 nodes — the
                                                      # paper's headline
                                                      # scale (Sec. 4)
TREE_512_3T = FatTreeConfig(racks=64, nodes_per_rack=8, uplinks=4,
                            pods=8, core_uplinks=4)   # 512 nodes, 2:1 x 2:1
TREE_128_3T = FatTreeConfig(racks=16, nodes_per_rack=8, uplinks=2,
                            pods=4, core_uplinks=2)   # 128 nodes, 4:1 x 2:1
TREE_3T_TINY = FatTreeConfig(racks=4, nodes_per_rack=2, uplinks=2,
                             pods=2, core_uplinks=2)  # 8 nodes, 1:1 x 1:1

LINK = LinkConfig()


@dataclasses.dataclass(frozen=True, eq=False)
class Scenario:
    """One named experiment setup: config + workload + tick budget.

    Frozen and declarative — building, running, and sweeping happen in
    ``netsim/api.py`` (``api.run`` / ``api.study``); the Scenario itself
    holds no compiled or device state.
    """

    name: str
    cfg: SimConfig
    wl: Workload
    max_ticks: int = 60_000

    def with_(self, *, name: str | None = None, max_ticks: int | None = None,
              wl: Workload | None = None, **cfg_overrides) -> "Scenario":
        """A copy with config fields (``algo=``, ``lb=``, ``faults=`` ...),
        the workload, or the tick budget replaced."""
        cfg = (dataclasses.replace(self.cfg, **cfg_overrides)
               if cfg_overrides else self.cfg)
        return dataclasses.replace(
            self, cfg=cfg,
            name=self.name if name is None else name,
            max_ticks=self.max_ticks if max_ticks is None else int(max_ticks),
            wl=self.wl if wl is None else wl)

    def build(self):
        """Compile this scenario's simulator (``engine.build``)."""
        from repro.netsim import engine
        return engine.build(self.cfg, self.wl)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Scenario]] = {}


def register(name: str, factory: Callable[[], Scenario], *aliases: str):
    """Register a scenario factory under ``name`` (and ``aliases``)."""
    for key in (name,) + aliases:
        if key in _REGISTRY:
            raise ValueError(f"scenario {key!r} already registered")
        _REGISTRY[key] = factory
    return factory


def names() -> tuple:
    """Registered scenario names, sorted."""
    return tuple(sorted(_REGISTRY))


def scenario(name: str, **overrides) -> Scenario:
    """Resolve a registered scenario by name; ``overrides`` are forwarded
    to :meth:`Scenario.with_` (config fields, ``max_ticks``)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {', '.join(names())}"
        ) from None
    sc = factory()
    return sc.with_(**overrides) if overrides else sc


def _std(name: str, tree: FatTreeConfig, wl: Workload,
         max_ticks: int) -> Scenario:
    return Scenario(name=name, cfg=SimConfig(link=LINK, tree=tree),
                    wl=wl, max_ticks=max_ticks)


# --------------------------------------------------------------------------
# catalogue — names are ledger keys (BENCH_netsim.json); keep stable
# --------------------------------------------------------------------------

# tiny smoke scenarios (CI bench smoke, `--quick` modes)
register("tiny_incast3", lambda: _std(
    "tiny_incast3", TREE_TINY,
    workloads.incast(TREE_TINY, degree=3, size_bytes=16 * KiB, seed=0),
    20_000))
register("tiny_perm4", lambda: _std(
    "tiny_perm4", TREE_TINY,
    workloads.permutation(TREE_TINY, size_bytes=32 * KiB, seed=1),
    20_000))
register("tiny_sparse", lambda: _std(
    "tiny_sparse", TREE_TINY,
    workloads.heavy_tailed(TREE_TINY, 8, size_base=8 * KiB,
                           size_cap=256 * KiB, gap_mean=1500.0, seed=1),
    30_000))

# dense standard scenarios (perf ledger rows, figures)
register("incast8_32n", lambda: _std(
    "incast8_32n", TREE_FLAT,
    workloads.incast(TREE_FLAT, degree=8, size_bytes=512 * KiB, seed=0),
    60_000), "incast_8x1_32n")
register("incast_32x1", lambda: _std(
    "incast_32x1", TREE_4TO1,
    workloads.incast(TREE_4TO1, degree=32, size_bytes=256 * KiB, seed=0),
    60_000))
register("perm64", lambda: _std(
    "perm64", TREE_4TO1,
    workloads.permutation(TREE_4TO1, size_bytes=2 * MiB, seed=7),
    60_000), "perm_64n")
register("perm128_8to1", lambda: _std(
    "perm128_8to1", TREE_8TO1,
    workloads.permutation(TREE_8TO1, size_bytes=512 * KiB, seed=7),
    120_000))
register("alltoall16_w4", lambda: _std(
    "alltoall16_w4", TREE_4TO1,
    workloads.alltoall(TREE_4TO1, size_bytes=64 * KiB, window=4, nodes=16),
    200_000))

# small 4:1 grid for tuning studies (benchmarks/sweep.py)
register("incast8_16n", lambda: _std(
    "incast8_16n", TREE_16,
    workloads.incast(TREE_16, degree=8, size_bytes=64 * 4096, seed=3),
    60_000))
register("perm_16n", lambda: _std(
    "perm_16n", TREE_16,
    workloads.permutation(TREE_16, size_bytes=64 * 4096, seed=3),
    60_000))

# three-tier scenarios (paper-scale fabrics; EXPERIMENTS.md "Three-tier
# scenarios").  perm/incast/alltoall cross the T2 core; the degraded
# variant injects core-link faults (dead t1_up uplink + half-rate t2_down).
register("tiny_3t", lambda: _std(
    "tiny_3t", TREE_3T_TINY,
    workloads.permutation(TREE_3T_TINY, size_bytes=16 * KiB, seed=1),
    20_000))
register("perm_512n_3t", lambda: _std(
    "perm_512n_3t", TREE_512_3T,
    workloads.permutation(TREE_512_3T, size_bytes=256 * KiB, seed=7),
    60_000))
register("perm_1024n_3t", lambda: _std(
    "perm_1024n_3t", TREE_1024_3T,
    workloads.permutation(TREE_1024_3T, size_bytes=256 * KiB, seed=7),
    60_000))
register("incast_256x1_3t", lambda: _std(
    "incast_256x1_3t", TREE_512_3T,
    workloads.incast(TREE_512_3T, degree=256, size_bytes=32 * KiB, seed=0),
    60_000))
register("alltoall_3t", lambda: _std(
    "alltoall_3t", TREE_512_3T,
    workloads.alltoall(TREE_512_3T, size_bytes=32 * KiB, window=4,
                       nodes=32, spread=True),
    200_000))
register("perm_512n_3t_degraded", lambda: _std(
    "perm_512n_3t_degraded", TREE_512_3T,
    workloads.permutation(TREE_512_3T, size_bytes=256 * KiB, seed=7),
    120_000).with_(faults=(("t1_up", 0, 0, 0), ("t2_down", 1, 2, 2)),
                   fault_start=0))
register("perm_128n_3t", lambda: _std(
    "perm_128n_3t", TREE_128_3T,
    workloads.permutation(TREE_128_3T, size_bytes=256 * KiB, seed=7),
    120_000))

# failover scenarios (ISSUE 8): dynamic FaultSchedule timelines on the
# 128-node three-tier tree, benched with and without the failure-recovery
# transport knobs (benchmarks/failover.py).  1 MiB flows so the kill lands
# mid-flight, *after* the REPS explore phase — the stranding mode the
# recovery knobs exist for is a flow retransmitting past-explore packets
# onto a dead cached entropy forever.
register("corefail_128n_3t", lambda: _std(
    "corefail_128n_3t", TREE_128_3T,
    workloads.permutation(TREE_128_3T, size_bytes=1 * MiB, seed=7),
    6_000).with_(faults=faults.FaultSchedule(events=(
        # both core uplinks of T1 switch 0 die at t=500; the repair lands
        # 10 ticks before the budget — less than one forward traversal —
        # so a flow still stranded at the repair cannot sneak in.
        faults.FaultEvent(t=500, kind="t1_up", i=0, j=0, period=0),
        faults.FaultEvent(t=500, kind="t1_up", i=0, j=1, period=0),
        faults.FaultEvent(t=5_990, kind="t1_up", i=0, j=0, period=1),
        faults.FaultEvent(t=5_990, kind="t1_up", i=0, j=1, period=1)))))
register("flap_128n_3t", lambda: _std(
    "flap_128n_3t", TREE_128_3T,
    workloads.permutation(TREE_128_3T, size_bytes=1 * MiB, seed=7),
    8_000).with_(faults=faults.FaultSchedule(flaps=(
        # rack 0's uplink 0 flaps 300 down / 300 up for five cycles
        faults.Flap(kind="t0_up", i=0, j=0, up=300, cycle=600,
                    t=200, t_end=3_200, period=0),))))
register("switchkill_128n_3t", lambda: _std(
    "switchkill_128n_3t", TREE_128_3T,
    workloads.permutation(TREE_128_3T, size_bytes=1 * MiB, seed=7),
    8_000).with_(faults=faults.FaultSchedule(events=(
        # T1 switch 1 (switch id racks + 1) dies whole at t=500 — every
        # port it owns blackholes — and comes back at t=3000.
        faults.FaultEvent(t=500, kind="switch", i=17, period=0),
        faults.FaultEvent(t=3_000, kind="switch", i=17, period=1)))))

# dependency-driven collectives (DESIGN.md Sec. 11): the chunk DAG gates
# each flow on its parents' delivered bytes; rows land in the BENCH
# `collectives` section with CCT next to FCT (benchmarks/collectives.py).
register("tiny_allreduce_ring", lambda: _std(
    "tiny_allreduce_ring", TREE_3T_TINY,
    collectives.ring_allreduce(TREE_3T_TINY, chunk_bytes=8 * KiB, nodes=8),
    20_000))
register("tiny_allgather", lambda: _std(
    "tiny_allgather", TREE_TINY,
    collectives.all_gather(TREE_TINY, chunk_bytes=16 * KiB, nodes=4),
    20_000))
register("tiny_pipeline", lambda: _std(
    "tiny_pipeline", TREE_TINY,
    collectives.pipeline(TREE_TINY, stage_bytes=8 * KiB, stages=3,
                         microbatches=4),
    20_000))
register("allreduce_ring_128n_3t", lambda: _std(
    "allreduce_ring_128n_3t", TREE_128_3T,
    collectives.ring_allreduce(TREE_128_3T, chunk_bytes=32 * KiB, nodes=128),
    120_000))
register("allreduce_tree_128n_3t", lambda: _std(
    "allreduce_tree_128n_3t", TREE_128_3T,
    collectives.tree_allreduce(TREE_128_3T, msg_bytes=128 * KiB, nodes=128,
                               branching=2),
    120_000))
register("allgather_64n_3t", lambda: _std(
    "allgather_64n_3t", TREE_128_3T,
    collectives.all_gather(TREE_128_3T, chunk_bytes=64 * KiB, nodes=64,
                           spread=True),
    120_000))
register("pipeline_32n", lambda: _std(
    "pipeline_32n", TREE_FLAT,
    collectives.pipeline(TREE_FLAT, stage_bytes=64 * KiB, stages=32,
                         microbatches=8),
    120_000))

# sparse/large-message scenarios (event-horizon leap targets, DESIGN 6.3)
register("sparse_heavy_32n", lambda: _std(
    "sparse_heavy_32n", TREE_FLAT,
    workloads.heavy_tailed(TREE_FLAT, 24, size_base=16 * KiB,
                           size_cap=2 * MiB, gap_mean=2500.0, seed=3),
    100_000))
register("sparse_large_32n", lambda: _std(
    "sparse_large_32n", TREE_FLAT,
    workloads.staggered_large(TREE_FLAT, 8, 2 * MiB, gap_ticks=6000, seed=0),
    100_000))
