"""Device-sharded lane execution (DESIGN.md Sec. 7).

The experiment API lowers a ``Scenario x points x seeds`` grid onto one
``[B = P*S]`` lane batch (``netsim/api.py``).  This module is the
executor under it: the *lane loop* — the per-lane gated, per-lane
leaping superstep loop — plus the machinery that partitions a lane
batch across every host/accelerator device through
``jax.experimental.shard_map``:

* ``lane_loop``        the vmapped loop as a pure ``(consts_b, states)
                       -> states`` function (shared verbatim by the
                       single-device jit and every shard body, so the
                       two paths cannot drift);
* ``lane_mesh``        a 1-D ``Mesh`` over the available devices
                       (``jax.sharding.Mesh``, axis ``"lanes"`` — the
                       same mesh idiom as ``src/repro/sharding.py``,
                       reduced to the one axis lane batches need);
* ``pad_lanes``        pads a batch to a device-count multiple with
                       *frozen* lanes (copies of the last lane with
                       every flow marked done — the lane gate makes a
                       finished lane a bitwise no-op, so padding never
                       perturbs real lanes and costs no loop
                       iterations on its shard);
* ``run_lanes``        the one entry point: vmap on a single device,
                       ``shard_map`` otherwise.

Sharding semantics: each device owns a contiguous ``B/D`` block of
lanes (the batch is point-major, so seed replicas of one point land
together) and runs its *own* while loop over them — the exit reduction
and the superstep cadence are per shard, so a shard whose lanes all
finish (or leap far) stops early instead of idling through the gated
ticks of a congested lane on another device.  Per-lane trajectories
are independent by construction (the gate and the leap are per lane —
DESIGN.md Sec. 7), so the sharded result is **bit-for-bit identical**
to the single-device vmap path, which is itself bit-identical to the
standalone run of every (point, seed) (tests/test_shard.py asserts
both, over the full final-state pytree).

Swept ``Consts`` leaves (vmap axis 0) shard with the lanes; deduped
leaves (axis ``None``) replicate.  The incoming state batch is donated
(Sec. 6.1 contract); the batched consts are not.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.netsim import engine, metrics, state

I32 = jnp.int32

LANE_AXIS = "lanes"


# --------------------------------------------------------------------------
# the lane loop (shared by the vmap path and every shard body)
# --------------------------------------------------------------------------


def lane_loop(step_fn, horizon_fn, axes, max_ticks: int, superstep: int):
    """The ``[B]`` lane batch run loop as a pure function
    ``(consts_b, states) -> states`` (not jitted — the callers wrap it).

    Each lane is gated on its *own* exit predicate — the same scalar
    ``(now < max_ticks) & ~all(done)`` the standalone loop uses — so a
    finished lane freezes (its gated tick is the identity, bitwise)
    while the rest keep stepping, and every lane's final state equals
    its standalone ``Sim.run`` bit-for-bit, ``now`` included.  With
    ``horizon_fn`` the loop leaps **per lane**: each lane jumps by its
    own next-event distance under its own swept ``Consts`` (clamped to
    its remaining budget, zero once the lane is done), so sparse lanes
    skip their quiescent stretches without waiting on busy lanes
    (DESIGN.md Sec. 6.3).  The superstep structure (leap once, then K
    gated ticks per while iteration) matches ``engine._superstep_loop``
    exactly."""

    def lane_live(st):
        return (st.now < max_ticks) & ~jnp.all(st.done)

    def lane_tick(c, st):
        return jax.lax.cond(lane_live(st), lambda s: step_fn(c, s),
                            lambda s: s, st)

    vtick = jax.vmap(lane_tick, in_axes=(axes, 0))

    def cond(st):
        return jnp.any((st.now < max_ticks) & ~jnp.all(st.done, axis=-1))

    def run(consts_b, states: state.SimState) -> state.SimState:
        leap = None
        if horizon_fn is not None:
            vhorizon = jax.vmap(horizon_fn, in_axes=(axes, 0))
            vlive = jax.vmap(lane_live)

            def leap(st):
                d = jnp.minimum(vhorizon(consts_b, st), max_ticks - st.now)
                d = jnp.where(vlive(st), d, 0)
                occ = jnp.sum(st.q_size[:, :-1], axis=1)
                return st._replace(now=st.now + d,
                                   m=metrics.leap_account(st.m, d, occ))

        return engine._superstep_loop(lambda st: vtick(consts_b, st), cond,
                                      superstep, leap)(states)

    return run


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4),
                   donate_argnums=(6,))
def _run_lanes(step_fn, horizon_fn, axes, max_ticks: int, superstep: int,
               consts_b, states: state.SimState) -> state.SimState:
    """Single-device vmap execution of :func:`lane_loop` (the historical
    ``api._run_lanes``).  ``states`` is donated; ``consts_b`` is not
    (reused across calls)."""
    return lane_loop(step_fn, horizon_fn, axes, max_ticks,
                     superstep)(consts_b, states)


# --------------------------------------------------------------------------
# mesh + padding
# --------------------------------------------------------------------------


def lane_mesh(devices=None) -> Mesh:
    """A 1-D device mesh over ``devices`` (default: every visible
    device) with the single axis ``"lanes"``.  On CPU, multiple host
    devices come from ``XLA_FLAGS=--xla_force_host_platform_device_count=D``
    (set before jax initializes — CI's multi-device job and
    ``benchmarks/study_throughput.py`` use exactly that)."""
    devs = list(devices) if devices is not None else list(jax.devices())
    return Mesh(np.asarray(devs), (LANE_AXIS,))


def axes_leaves(axes) -> list:
    """Flatten a vmap in_axes tree (0 / None leaves) to a per-leaf
    list aligned with ``jax.tree_util.tree_flatten`` of the matching
    pytree (``None`` is a leaf here, not an empty subtree)."""
    return jax.tree_util.tree_flatten(
        axes, is_leaf=lambda x: x is None)[0]


def pad_lanes(states: state.SimState, consts_b, axes, mult: int):
    """Pad a ``[B]`` lane batch (and the swept consts leaves) to the
    next multiple of ``mult``.

    Pad lanes are copies of the last real lane with every flow marked
    ``done`` — the lane gate (`lane_loop`) then freezes them from tick
    zero, so they are pure ballast: bit-inert, loop-iteration-free on
    their shard, and sliced off by the caller after the run.  Returns
    ``(states, consts_b, n_pad)``."""
    B = int(states.now.shape[0])
    n_pad = (-B) % max(int(mult), 1)
    if n_pad == 0:
        return states, consts_b, 0

    def pad_state(x):
        tail = jnp.broadcast_to(x[-1:], (n_pad,) + x.shape[1:])
        return jnp.concatenate([x, tail], axis=0)

    states = jax.tree.map(pad_state, states)
    states = states._replace(done=states.done.at[B:].set(True))
    leaves, treedef = jax.tree_util.tree_flatten(consts_b)
    padded = [pad_state(x) if a == 0 else x
              for x, a in zip(leaves, axes_leaves(axes))]
    return (states, jax.tree_util.tree_unflatten(treedef, padded), n_pad)


def _specs(states, axes, treedef):
    """(state_specs, consts_specs) partition-spec trees: every state
    leaf shards on the lane axis; consts leaves shard iff swept
    (vmap axis 0), else replicate."""
    lane = P(LANE_AXIS)
    state_specs = jax.tree.map(lambda _: lane, states)
    consts_specs = jax.tree_util.tree_unflatten(
        treedef, [lane if a == 0 else P() for a in axes_leaves(axes)])
    return state_specs, consts_specs


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5),
                   donate_argnums=(7,))
def _run_lanes_sharded(step_fn, horizon_fn, axes, max_ticks: int,
                       superstep: int, mesh: Mesh, consts_b,
                       states: state.SimState) -> state.SimState:
    """shard_map execution: each device runs :func:`lane_loop` over its
    own contiguous lane block under its own while loop.  Lane count
    must be a multiple of ``mesh.size`` (see :func:`pad_lanes`)."""
    loop = lane_loop(step_fn, horizon_fn, axes, max_ticks, superstep)
    _, treedef = jax.tree_util.tree_flatten(consts_b)
    state_specs, consts_specs = _specs(states, axes, treedef)
    sharded = shard_map(loop, mesh=mesh,
                        in_specs=(consts_specs, state_specs),
                        out_specs=state_specs, check_rep=False)
    return sharded(consts_b, states)


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------


def run_lanes(step_fn, horizon_fn, axes, max_ticks: int, superstep: int,
              consts_b, states: state.SimState, mesh: Mesh | None = None,
              ) -> state.SimState:
    """Run a ``[B]`` lane batch to completion — THE batched run loop
    behind ``Study``/``Sim.run_batch``/``Sweep.run``.

    ``mesh=None`` (or a 1-device mesh) is the single-device vmap path,
    unchanged from PR 4.  A larger mesh pads the batch to a
    device-count multiple, shards lanes (and swept consts) across the
    mesh via ``shard_map``, runs one independent loop per device, and
    gathers + slices the result back to ``[B]`` — bit-identical to the
    vmap path, lane for lane.  ``states`` is donated either way."""
    if mesh is None or mesh.size <= 1:
        return _run_lanes(step_fn, horizon_fn, axes, max_ticks, superstep,
                          consts_b, states)
    B = int(states.now.shape[0])
    states, consts_p, n_pad = pad_lanes(states, consts_b, axes, mesh.size)
    out = _run_lanes_sharded(step_fn, horizon_fn, axes, max_ticks,
                             superstep, mesh, consts_p, states)
    if n_pad:
        out = jax.tree.map(lambda x: x[:B], out)
    return out
