"""Phases 4-5 of the tick — the host NICs.

  4. ``grants``: EQDS receiver-side pull-credit generation (round-robin over
     demanding flows per receiver; no-op unless the algorithm is
     credit-based)
  5. ``sends``:  per-sender round-robin flow arbitration, window/credit/
     pacing admission, REPS entropy assignment, emission onto the wire,
     sent-ring bookkeeping

Static branch selectors (credit_based / paced / lb_mode / window) come from
``Dims``; every numeric knob is traced through ``Consts``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import reps
from repro.netsim.fabric import route_from_sender
from repro.netsim.state import Consts, Dims, SimState, pkt_size

I32 = jnp.int32
F32 = jnp.float32


def grants(dims: Dims, consts: Consts, st: SimState) -> SimState:
    """Phase 4: EQDS receiver credit grants (paper Sec. 2.2)."""
    if not dims.credit_based:
        return st
    t = st.now
    NF, N, R, FRMAX = dims.NF, dims.N, dims.R, dims.FRMAX
    MTU = float(dims.mtu)

    # outstanding credit window above received + known-lost bytes:
    # self-clocks, and re-grants for trimmed packets (the receiver
    # sees trimmed headers) so retransmissions never starve.
    started_flows = (t >= consts.t_start) & ~st.done
    demand = started_flows & (
        st.granted - st.goodput.astype(F32) - st.trim_seen < consts.credit_window)
    dm = jnp.pad(demand, (0, 1))[consts.flows_by_recv]          # [N, FR]
    keys = (jnp.arange(FRMAX, dtype=I32)[None, :] - st.rr_recv[:, None]) % FRMAX
    keys = jnp.where(dm, keys, FRMAX + 1)
    sel = jnp.argmin(keys, axis=1)
    has_g = jnp.any(dm, axis=1)
    gflow = jnp.where(has_g, consts.flows_by_recv[jnp.arange(N), sel], NF)
    gslot = jnp.where(has_g, (t + consts.ret[jnp.clip(gflow, 0, NF - 1)]) % R, 0)
    credit_ring = st.credit_ring.at[gslot, gflow].add(
        jnp.where(has_g, MTU, 0.0))
    granted = jnp.pad(st.granted, (0, 1)).at[gflow].add(
        jnp.where(has_g, MTU, 0.0))[:NF]
    rr_recv = jnp.where(has_g, (sel.astype(I32) + 1) % FRMAX, st.rr_recv)
    return st._replace(credit_ring=credit_ring, granted=granted, rr_recv=rr_recv)


def sends(dims: Dims, consts: Consts, st: SimState) -> SimState:
    """Phase 5: one packet per NIC per tick, arbitration + admission."""
    t = st.now
    m = st.m
    NF, N, NQ, L, W = dims.NF, dims.N, dims.NQ, dims.L, dims.W
    FMAX, window = dims.FMAX, dims.window
    mtu_i = dims.mtu
    flow_ids = jnp.arange(NF, dtype=I32)
    cc = st.cc

    pace = st.pace_accum
    if dims.paced:
        pace = jnp.minimum(pace + cc.pacing_rate, 4.0 * float(mtu_i))

    # windowed-alltoall eligibility: < window unfinished predecessors
    done_p = jnp.pad(st.done, (0, 1), constant_values=True)
    unfin = (~done_p[consts.flows_of]) & (consts.flows_of < NF)  # [N, FMAX]
    prior_unfin = jnp.cumsum(unfin, axis=1) - unfin.astype(I32)
    win_elig = jnp.full((NF + 1,), False).at[consts.flows_of.reshape(-1)].set(
        (prior_unfin < window).reshape(-1))[:NF]

    started = (t >= consts.t_start) & ~st.done & win_elig
    has_retx = jnp.any(st.st_state[:NF] == 3, axis=1)
    retx_slot = jnp.argmax(st.st_state[:NF] == 3, axis=1)
    retx_seq = st.st_seq[flow_ids, retx_slot]
    new_seq = st.next_seq
    new_slot = new_seq % W
    new_ok = (new_seq * mtu_i < consts.size) & \
        (st.st_state[flow_ids, new_slot] == 0)
    seq_emit = jnp.where(has_retx, retx_seq, new_seq)
    nsize = pkt_size(dims, consts, flow_ids, seq_emit).astype(F32)
    win_ok = st.unacked + nsize <= cc.cwnd
    credit_ok = True
    if dims.credit_based:
        credit_ok = (cc.credits >= nsize) | (cc.spec_budget >= nsize)
    pace_ok = (pace >= nsize) if dims.paced else True
    elig = started & (has_retx | new_ok) & win_ok & credit_ok & pace_ok & (nsize > 0)

    # per-sender round-robin arbitration (one packet per NIC per tick)
    E = jnp.pad(elig, (0, 1))[consts.flows_of]                   # [N, FMAX]
    keys = (jnp.arange(FMAX, dtype=I32)[None, :] - st.rr_send[:, None]) % FMAX
    keys = jnp.where(E, keys, FMAX + 1)
    sel = jnp.argmin(keys, axis=1)
    has_s = jnp.any(E, axis=1)
    sflow = jnp.where(has_s, consts.flows_of[jnp.arange(N), sel], NF)
    rr_send = jnp.where(has_s, (sel.astype(I32) + 1) % FMAX, st.rr_send)

    emit_mask = jnp.zeros((NF + 1,), bool).at[sflow].set(has_s)[:NF]
    lb, entropy = reps.on_send(dims.lb_mode, consts.lb, st.lb, emit_mask,
                               seq_emit, flow_ids, t)
    first_q = route_from_sender(dims, consts, flow_ids, entropy)

    # place on the wire
    send_slot = jnp.where(has_s, (t + consts.lat_q[NQ]) % L, L)
    sf = jnp.clip(sflow, 0, NF - 1)
    spay = jnp.stack([
        has_s.astype(I32),
        first_q[sf],
        sflow,
        seq_emit[sf],
        entropy[sf],
        jnp.zeros((N,), I32),
        jnp.full((N,), 1, I32) * t,
    ], axis=1)
    infl = st.infl.at[send_slot, NQ + jnp.arange(N)].set(spay)

    # sent-ring bookkeeping
    eslot = seq_emit % W
    eflow2 = jnp.where(emit_mask, flow_ids, NF)
    st_state = st.st_state.at[eflow2, eslot].set(
        jnp.where(emit_mask, 1, st.st_state[eflow2, eslot]))
    st_seq = st.st_seq.at[eflow2, eslot].set(
        jnp.where(emit_mask, seq_emit, st.st_seq[eflow2, eslot]))
    st_ts = st.st_ts.at[eflow2, eslot].set(
        jnp.where(emit_mask, t, st.st_ts[eflow2, eslot]))
    is_new_send = emit_mask & ~has_retx
    next_seq = st.next_seq + is_new_send.astype(I32)
    m = m._replace(n_retx=m.n_retx + jnp.sum((emit_mask & has_retx).astype(I32)))

    spend = jnp.where(emit_mask, nsize, 0.0)
    if dims.credit_based:
        use_credit = cc.credits >= nsize
        cc = cc._replace(
            credits=cc.credits - spend * use_credit,
            spec_budget=cc.spec_budget - spend * (~use_credit),
        )
    if dims.paced:
        pace = pace - spend

    return st._replace(
        infl=infl, st_state=st_state, st_seq=st_seq, st_ts=st_ts,
        next_seq=next_seq, rr_send=rr_send, pace_accum=pace, cc=cc, lb=lb, m=m,
    )
