"""Phases 4-5 of the tick — the host NICs.

  4. ``grants``: EQDS receiver-side pull-credit generation (round-robin over
     demanding flows per receiver; no-op unless the algorithm is
     credit-based)
  5. ``sends``:  per-sender round-robin flow arbitration, window/credit/
     pacing admission, REPS entropy assignment, emission onto the wire,
     sent-ring bookkeeping

Static branch selectors (credit_based / paced / lb_mode / window) come from
``Dims``; every numeric knob is traced through ``Consts``.

``horizon`` reduces the same admission/demand predicates to "ticks until a
NIC or a receiver next acts", feeding the engine's event-horizon time
leaping (DESIGN.md Sec. 6.3).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import reps
from repro.netsim.fabric import route_first_hop
from repro.netsim.state import HORIZON_INF, Consts, Dims, SimState

I32 = jnp.int32
F32 = jnp.float32


def activated(dims: Dims, consts: Consts, st: SimState):
    """The activation predicate (DESIGN.md Sec. 11): a flow is live once
    ``t >= t_start``, it is unfinished, and — when the workload carries a
    dependency table — every parent has delivered its threshold bytes.

    ``st.goodput`` only grows on delivery (an *eventful* tick by
    construction), so between events this predicate is constant: the leap
    horizon needs no dependency-release term beyond sharing this exact
    predicate with ``admission`` (the clamp that keeps leap-on bit-equal
    to leap-off).  With ``Dims.D == 0`` the dependency gather vanishes and
    the traced graph is the legacy ``t_start``-only one, bit-for-bit."""
    act = (st.now >= consts.t_start) & ~st.done
    if dims.D:
        # goodput of each parent (pad row NF covers the free-slot sentinel,
        # which the == NF test forces true regardless)
        gp = jnp.pad(st.goodput, (0, 1))[consts.dep_par]        # [NF, D]
        ok = (consts.dep_par == dims.NF) | (gp >= consts.dep_thr)
        act &= jnp.all(ok, axis=1)
    return act


def _grant_demand(dims: Dims, consts: Consts, st: SimState):
    """Flows whose receiver owes pull credit (EQDS): outstanding credit
    window above received + known-lost bytes — self-clocks, and re-grants
    for trimmed packets (the receiver sees trimmed headers) so
    retransmissions never starve."""
    return activated(dims, consts, st) & (
        st.granted - st.goodput.astype(F32) - st.trim_seen[:dims.NF]
        < consts.credit_window)


def grants(dims: Dims, consts: Consts, st: SimState, arb=None) -> SimState:
    """Phase 4: EQDS receiver credit grants (paper Sec. 2.2).

    ``arb`` is the backend-resolved round-robin arbitration callable
    (``kernels/enqueue_arb/ops.get``); ``None`` means the pure-jnp
    reference."""
    if not dims.credit_based:
        return st
    if arb is None:
        from repro.kernels.enqueue_arb import ops as _arb_ops
        arb = _arb_ops.rr_pick
    t = st.now
    NF, N, R, FRMAX = dims.NF, dims.N, dims.R, dims.FRMAX
    MTU = float(dims.mtu)

    demand = _grant_demand(dims, consts, st)
    dm = jnp.pad(demand, (0, 1))[consts.flows_by_recv]          # [N, FR]
    has_g, sel = arb(dm, st.rr_recv, FRMAX)
    gflow = jnp.where(has_g, consts.flows_by_recv[consts.node_ids, sel], NF)
    # the grant return delay is the constant `ret` (state.derive), so all
    # grants of this tick land in one ring slot
    credit_ring = st.credit_ring.at[(t + consts.ret) % R, gflow].add(
        jnp.where(has_g, MTU, 0.0), mode="promise_in_bounds")
    granted = jnp.pad(st.granted, (0, 1)).at[gflow].add(
        jnp.where(has_g, MTU, 0.0), mode="promise_in_bounds")[:NF]
    rr_recv = jnp.where(has_g, (sel.astype(I32) + 1) % FRMAX, st.rr_recv)
    return st._replace(credit_ring=credit_ring, granted=granted, rr_recv=rr_recv)


def admission(dims: Dims, consts: Consts, st: SimState):
    """Send admission for every flow at the current tick, *excluding* rate
    pacing (the caller folds in the freshly accrued pacing budget; the
    leap ``horizon`` runs only for unpaced configurations, where this IS
    the full admission).  Returns ``(elig, has_retx, seq_emit, nsize)``.
    """
    NF, W, FMAX, window = dims.NF, dims.W, dims.FMAX, dims.window
    mtu_i = dims.mtu
    flow_ids = consts.flow_ids
    cc = st.cc

    started = activated(dims, consts, st)
    if window < FMAX:
        # windowed-alltoall eligibility: < window unfinished predecessors.
        # Each flow's (sender, column) is static (consts.slot_of), so the
        # eligibility is a gather from the per-sender prefix count — no
        # scatter back through flows_of.
        done_p = jnp.pad(st.done, (0, 1), constant_values=True)
        unfin = (~done_p[consts.flows_of]) & (consts.flows_of < NF)  # [N, FMAX]
        prior_unfin = jnp.cumsum(unfin, axis=1) - unfin.astype(I32)
        started &= prior_unfin[consts.src, consts.slot_of] < window

    is_retx = st.sent[0, :NF] == 3
    has_retx = jnp.any(is_retx, axis=1)
    retx_slot = jnp.argmax(is_retx, axis=1)
    retx_seq = st.sent[1, flow_ids, retx_slot]
    new_seq = st.next_seq
    new_slot = new_seq % W
    new_ok = (new_seq * mtu_i < consts.size) & \
        (st.sent[0, flow_ids, new_slot] == 0)
    seq_emit = jnp.where(has_retx, retx_seq, new_seq)
    # flow_ids is the exact [0, NF) iota, so pkt_size's defensive flow clip
    # (and its gather) is unnecessary — size the packet directly.
    nsize = jnp.clip(consts.size - seq_emit * mtu_i, 0, mtu_i).astype(F32)
    win_ok = st.unacked + nsize <= cc.cwnd
    credit_ok = True
    if dims.credit_based:
        credit_ok = (cc.credits >= nsize) | (cc.spec_budget >= nsize)
    elig = started & (has_retx | new_ok) & win_ok & credit_ok & (nsize > 0)
    return elig, has_retx, seq_emit, nsize


def sends(dims: Dims, consts: Consts, st: SimState, arb=None) -> SimState:
    """Phase 5: one packet per NIC per tick, arbitration + admission.

    ``arb`` is the backend-resolved round-robin arbitration callable
    (``kernels/enqueue_arb/ops.get``); ``None`` means the pure-jnp
    reference."""
    if arb is None:
        from repro.kernels.enqueue_arb import ops as _arb_ops
        arb = _arb_ops.rr_pick
    t = st.now
    m = st.m
    NF, N, NQ, L, W = dims.NF, dims.N, dims.NQ, dims.L, dims.W
    FMAX = dims.FMAX
    mtu_i = dims.mtu
    flow_ids = consts.flow_ids
    cc = st.cc

    pace = st.pace_accum
    if dims.paced:
        pace = jnp.minimum(pace + cc.pacing_rate, 4.0 * float(mtu_i))

    elig, has_retx, seq_emit, nsize = admission(dims, consts, st)
    if dims.paced:
        elig &= pace >= nsize

    # per-sender round-robin arbitration (one packet per NIC per tick)
    if FMAX == 1:
        # at most one flow per sender: arbitration is the identity
        has_s = jnp.pad(elig, (0, 1))[consts.flows_of[:, 0]]
        sflow = jnp.where(has_s, consts.flows_of[:, 0], NF)
        rr_send = st.rr_send
    else:
        E = jnp.pad(elig, (0, 1))[consts.flows_of]               # [N, FMAX]
        has_s, sel = arb(E, st.rr_send, FMAX)
        sflow = jnp.where(has_s, consts.flows_of[consts.node_ids, sel], NF)
        rr_send = jnp.where(has_s, (sel.astype(I32) + 1) % FMAX, st.rr_send)

    # flow f emits iff its own sender selected it (gather, not scatter)
    emit_mask = sflow[consts.src] == flow_ids
    lb, entropy = reps.on_send(dims.lb_mode, consts.lb, st.lb, emit_mask,
                               seq_emit, flow_ids, t)
    first_q = route_first_hop(dims, consts, entropy)

    # place on the wire — one dynamic-update-slice over the NIC emitter
    # rows [NQ, NE) at the (uniform) sender latency slot; zeros for idle
    # NICs are exact because the slot holds no live packet (see the
    # exclusivity argument in fabric.departures)
    sf = jnp.clip(sflow, 0, NF - 1)
    spay = jnp.where(has_s[:, None], jnp.stack([
        has_s.astype(I32),
        first_q[sf],
        sflow,
        seq_emit[sf],
        entropy[sf],
        jnp.zeros((N,), I32),
        jnp.broadcast_to(t, (N,)),
    ], axis=1), 0)
    infl = st.infl.at[(t + consts.lat_send) % L, NQ:].set(spay)

    # sent-ring bookkeeping: a one-hot masked write of the [3, NF, W] body
    # (the emitting flow's slot is seq_emit % W) folded into one contiguous
    # slice update — XLA:CPU fuses the compare+select pass, which beats the
    # historical packed scatter by an order of magnitude at 512-node scale;
    # non-emitting rows copy through unchanged and the write-off row NF is
    # never touched, so an event-free tick leaves the ring bitwise
    # unchanged — the property time leaping relies on
    hit = emit_mask[:, None] & \
        (jnp.arange(W, dtype=I32)[None, :] == (seq_emit % W)[:, None])
    body = st.sent[:, :NF]
    sent = st.sent.at[:, :NF].set(jnp.stack([
        jnp.where(hit, 1, body[0]),
        jnp.where(hit, seq_emit[:, None], body[1]),
        jnp.where(hit, t, body[2]),
    ]))
    is_new_send = emit_mask & ~has_retx
    next_seq = st.next_seq + is_new_send.astype(I32)
    m = m._replace(n_retx=m.n_retx + jnp.sum((emit_mask & has_retx).astype(I32)))

    spend = jnp.where(emit_mask, nsize, 0.0)
    if dims.credit_based:
        use_credit = cc.credits >= nsize
        cc = cc._replace(
            credits=cc.credits - spend * use_credit,
            spec_budget=cc.spec_budget - spend * (~use_credit),
        )
    if dims.paced:
        pace = pace - spend

    return st._replace(
        infl=infl, sent=sent,
        next_seq=next_seq, rr_send=rr_send, pace_accum=pace, cc=cc, lb=lb, m=m,
    )


def horizon(dims: Dims, consts: Consts, st: SimState):
    """Ticks until phases 4-5 next do work (DESIGN.md Sec. 6.3).

    0 while any flow passes send admission (its NIC emits this tick) or —
    for credit-based algorithms — any receiver owes a grant: both
    predicates are functions of state that only *eventful* ticks mutate,
    so between events the only thing that can flip them is a flow-start
    deadline, which bounds the leap.  Dependency releases (DESIGN.md Sec.
    11) need no extra term: ``admission`` (shared here bit-for-bit, the
    leap clamp) gates on ``sender.activated``, and a parent's threshold
    crossing rides on a delivery — an arrival the fabric horizon already
    bounds.  Never traced for paced configurations (``Dims.leap`` is
    forced off there — the pacing budget accrues every tick).
    """
    t = st.now
    elig, _, _, _ = admission(dims, consts, st)
    h = jnp.where(jnp.any(elig), 0, HORIZON_INF)
    if dims.credit_based:
        h = jnp.minimum(
            h, jnp.where(jnp.any(_grant_demand(dims, consts, st)),
                         0, HORIZON_INF))
    unstarted = t < consts.t_start
    h_start = jnp.min(jnp.where(unstarted, consts.t_start - t, HORIZON_INF))
    return jnp.minimum(h, h_start)
