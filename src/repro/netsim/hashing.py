"""Deterministic counter-based hashing used for ECMP path selection and RED
marking decisions.  splitmix32-style mixing: stateless, vectorizes, bitwise
reproducible across hosts/devices (no RNG state threaded through the sim)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# numpy scalars so Pallas kernels see literals, not captured device constants
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def mix32(x) -> jnp.ndarray:
    """Finalizer from murmur3/splitmix — good avalanche behavior."""
    x = jnp.asarray(x).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash2(a, b) -> jnp.ndarray:
    """Hash two lanes of uint32 into one."""
    a = jnp.asarray(a).astype(jnp.uint32)
    b = jnp.asarray(b).astype(jnp.uint32)
    return mix32(a * _GOLDEN + mix32(b))


def hash3(a, b, c) -> jnp.ndarray:
    return hash2(hash2(a, b), c)


def uniform01(*lanes) -> jnp.ndarray:
    """Deterministic uniform in [0, 1) from integer lanes."""
    h = lanes[0]
    for lane in lanes[1:]:
        h = hash2(h, lane)
    h = mix32(h)
    return h.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
