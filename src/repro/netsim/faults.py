"""Dynamic link-fault schedules (paper Sec. 4 / Fig. 7's degraded fabric).

The historical fault model was a static port set that switches on at one
``fault_start`` and never changes.  This module generalizes it to a
:class:`FaultSchedule` — a timeline of per-port (or per-switch, expanding
to every port the switch owns) fail / degrade / repair events plus
periodic flapping windows — while keeping the compiled form small enough
to live in ``Consts`` and be evaluated branch-free every tick:

* ``compile_tables`` turns a schedule into per-port *transition tables*
  ``ft_time`` / ``ft_period`` of static shape [NQ, FK] (``FK`` columns =
  1 + max events on any one port; both are ``Dims`` statics).  Column 0
  is always the healthy state ``(t=0, period=1)``; real events follow in
  time order, padded with ``(HORIZON_INF, 1)``.  The service period of
  port q at tick t is then the last column whose time is <= t — one
  comparison + ``take_along_axis`` per tick (:func:`port_period`).

* Times in the tables are *relative to* ``Consts.fault_start`` (the
  evaluation uses ``t - fault_start``), so ``fault_start`` stays a plain
  sweepable scalar exactly as before: legacy ``faults=((kind,i,j,p),...)``
  tuples lower (:func:`lower`) to one-event schedules whose compiled
  evaluation is bit-for-bit the historical
  ``where(t >= fault_start, period, healthy)``.

* Period semantics match the historical ``Consts.service_period``:
  ``1`` = healthy, ``0`` = dead (packets blackhole), ``k > 1`` = degraded
  (the port serves only when ``t % k == 0`` — the *absolute* tick, so the
  lowered form reproduces the legacy modulus bitwise).

* Flaps compile to per-port scalars (``fl_start/fl_end/fl_cycle/fl_up/
  fl_period``): inside ``[start, end)`` the port cycles ``up`` healthy
  ticks then ``cycle - up`` ticks at ``period`` (0 = dead while down).
  At most one flap per port.

* :func:`transition_horizon` is the leap clamp (DESIGN.md Sec. 6.3): the
  distance to the next schedule transition strictly after ``t`` (table
  times and flap phase boundaries), which ``fabric.horizon`` min's in so
  a time leap never jumps across a fault state change.

Host-side mirrors (:func:`np_port_period`, :func:`fault_ticks`,
:func:`repair_times`) integrate the same piecewise-constant activity
function exactly for the recovery metrics in ``api.RunResult`` — no
device accounting needed beyond the delivered-during-fault counter.
"""

from __future__ import annotations

import dataclasses

import numpy as np

HORIZON_INF = 1 << 30

# port kinds resolvable by (kind, i, j); "switch" takes a switch id in
# ``i`` and expands to every queue that switch owns
PORT_KINDS = ("t0_up", "t1_up", "t2_down", "t1_down")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """At tick ``t`` (relative to ``fault_start``) set the target's
    service period: 0 = fail dead, k > 1 = degrade to serve every k-th
    tick, 1 = repair to healthy."""
    t: int
    kind: str           # one of PORT_KINDS, or "switch"
    i: int
    j: int = 0
    period: int = 0


@dataclasses.dataclass(frozen=True)
class Flap:
    """Periodic flapping of one target inside ``[t, t_end)``: each
    ``cycle``-tick window is ``up`` healthy ticks followed by
    ``cycle - up`` ticks at ``period`` (default 0 = dead)."""
    kind: str
    i: int
    up: int
    cycle: int
    j: int = 0
    t: int = 0
    t_end: int = HORIZON_INF
    period: int = 0


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    events: tuple = ()
    flaps: tuple = ()

    def __bool__(self) -> bool:
        return bool(self.events or self.flaps)


def lower(faults) -> FaultSchedule:
    """Lower ``SimConfig.faults`` to a :class:`FaultSchedule`.

    Accepts a schedule verbatim, or the legacy tuple forms — 3-tuples
    ``(r, a, period)`` / 4-tuples ``(kind, i, j, period)`` — each
    becoming a single event at relative t=0 (i.e. absolute
    ``fault_start``, which stays a separate sweepable scalar)."""
    if isinstance(faults, FaultSchedule):
        return faults
    events = []
    for f in faults:
        f = tuple(f)
        if len(f) == 3 and not isinstance(f[0], str):
            kind, i, j, period = "t0_up", f[0], f[1], f[2]
        elif len(f) == 4 and isinstance(f[0], str):
            kind, i, j, period = f
        else:
            raise ValueError(
                f"fault tuple {f!r} not understood: want (r, a, period) or "
                f"(kind, i, j, period) with kind one of {PORT_KINDS}"
                f" or 'switch', or pass a FaultSchedule")
        events.append(FaultEvent(t=0, kind=kind, i=i, j=j, period=period))
    return FaultSchedule(events=tuple(events))


def resolve_ports(topo, kind: str, i: int, j: int, ctx: str) -> list:
    """Queue ids targeted by ``(kind, i, j)``, with actionable range
    validation (mirrors ``Workload.validate``): ``ctx`` names the
    offending schedule entry in errors."""
    tree = topo.tree

    def _chk(name, v, hi):
        if not 0 <= v < hi:
            raise ValueError(
                f"{ctx}: {name}={v} out of range [0, {hi}) for "
                f"kind={kind!r} on this tree")

    if kind == "switch":
        _chk("switch", i, topo.n_switches)
        return [int(q) for q in np.where(topo.sw_of_q == i)[0]]
    if kind not in PORT_KINDS:
        raise ValueError(
            f"{ctx}: unknown fault kind {kind!r} "
            f"(want one of {PORT_KINDS} or 'switch')")
    if kind in ("t1_up", "t2_down") and not tree.pods:
        raise ValueError(
            f"{ctx}: kind={kind!r} exists only on three-tier trees "
            f"(this tree has pods=0)")
    if kind == "t0_up":
        _chk("i (rack)", i, tree.racks)
        _chk("j (uplink)", j, tree.uplinks)
    elif kind == "t1_up":
        _chk("i (t1 switch)", i, tree.n_t1)
        _chk("j (core uplink)", j, tree.core_uplinks)
    elif kind == "t2_down":
        _chk("i (core)", i, tree.n_cores)
        _chk("j (pod)", j, tree.pods)
    elif kind == "t1_down":
        _chk("i (t1 switch)", i, tree.n_t1)
        _chk("j (rack-in-pod)", j, tree.racks_per_pod)
    return [int(getattr(topo, kind)(i, j))]


def validate(sched: FaultSchedule, fault_start: int) -> None:
    """Schedule-shape errors that don't need the topology."""
    if fault_start < 0:
        raise ValueError(f"fault_start={fault_start} must be >= 0")
    for ev in sched.events:
        if ev.t < 0:
            raise ValueError(f"fault event {ev}: t must be >= 0")
        if ev.period < 0:
            raise ValueError(
                f"fault event {ev}: period must be >= 0 "
                f"(0 = dead, 1 = healthy, k > 1 = degraded)")
    for fl in sched.flaps:
        if fl.t < 0 or fl.t_end <= fl.t:
            raise ValueError(f"flap {fl}: need 0 <= t < t_end")
        if fl.cycle < 2 or not 0 < fl.up < fl.cycle:
            raise ValueError(
                f"flap {fl}: need cycle >= 2 and 0 < up < cycle")
        if fl.period < 0:
            raise ValueError(f"flap {fl}: period must be >= 0")


@dataclasses.dataclass(frozen=True)
class CompiledFaults:
    """Numpy transition tables + static shape bits (see module doc)."""
    ft_time: np.ndarray     # [NQ, FK] i32, row-sorted, col0 = (0, 1)
    ft_period: np.ndarray   # [NQ, FK] i32
    fl_start: np.ndarray    # [NQ] i32
    fl_end: np.ndarray      # [NQ] i32 (HORIZON_INF = open)
    fl_cycle: np.ndarray    # [NQ] i32 (0 = no flap on this port)
    fl_up: np.ndarray       # [NQ] i32
    fl_period: np.ndarray   # [NQ] i32
    FK: int                 # 0 = no timeline events at all
    flapped: bool


def compile_tables(sched: FaultSchedule, topo,
                   fault_start: int = 0) -> CompiledFaults:
    """Compile a schedule against a topology (validating every entry)."""
    validate(sched, fault_start)
    NQ = topo.n_queues
    per_port: dict = {}
    for k, ev in enumerate(sched.events):
        for q in resolve_ports(topo, ev.kind, ev.i, ev.j,
                               f"faults[{k}] = {ev}"):
            per_port.setdefault(q, []).append((ev.t, ev.period))
    maxev = max((len(v) for v in per_port.values()), default=0)
    FK = 1 + maxev if per_port else 0
    ft_time = np.full((NQ, max(FK, 1)), HORIZON_INF, np.int32)
    ft_period = np.ones((NQ, max(FK, 1)), np.int32)
    ft_time[:, 0] = 0                      # column 0: healthy from t=0
    for q, evs in per_port.items():
        evs.sort(key=lambda e: e[0])       # stable: later-listed wins ties
        for k, (et, ep) in enumerate(evs):
            ft_time[q, 1 + k] = et
            ft_period[q, 1 + k] = ep

    fl_start = np.zeros(NQ, np.int32)
    fl_end = np.zeros(NQ, np.int32)
    fl_cycle = np.zeros(NQ, np.int32)
    fl_up = np.zeros(NQ, np.int32)
    fl_period = np.zeros(NQ, np.int32)
    for k, fl in enumerate(sched.flaps):
        for q in resolve_ports(topo, fl.kind, fl.i, fl.j,
                               f"flaps[{k}] = {fl}"):
            if fl_cycle[q]:
                raise ValueError(
                    f"flaps[{k}] = {fl}: port {q} already has a flap "
                    f"(at most one flap per port)")
            fl_start[q] = fl.t
            fl_end[q] = min(fl.t_end, HORIZON_INF)
            fl_cycle[q] = fl.cycle
            fl_up[q] = fl.up
            fl_period[q] = fl.period
    return CompiledFaults(ft_time=ft_time, ft_period=ft_period,
                          fl_start=fl_start, fl_end=fl_end,
                          fl_cycle=fl_cycle, fl_up=fl_up,
                          fl_period=fl_period, FK=FK,
                          flapped=bool(sched.flaps))


# ---- traced evaluation (consts carries the tables; dims the shape) ----

def port_period(dims, consts, t):
    """[NQ] service period of every port at absolute tick ``t`` (1 =
    healthy, 0 = dead, k > 1 = degraded).  Gated on the static
    ``dims.FK`` / ``dims.flapped`` so no-fault configs keep a clean
    graph.  Table times are relative to ``consts.fault_start``."""
    import jax.numpy as jnp
    tr = t - consts.fault_start
    if dims.FK:
        cnt = jnp.sum((tr >= consts.ft_time).astype(jnp.int32), axis=1)
        idx = jnp.maximum(cnt - 1, 0)      # tr < 0 -> healthy column 0
        per = jnp.take_along_axis(consts.ft_period, idx[:, None],
                                  axis=1)[:, 0]
    else:
        per = jnp.ones((dims.NQ,), jnp.int32)
    if dims.flapped:
        has = consts.fl_cycle > 0
        cyc = jnp.maximum(consts.fl_cycle, 1)
        ph = (tr - consts.fl_start) % cyc
        in_win = has & (tr >= consts.fl_start) & (tr < consts.fl_end)
        down = in_win & (ph >= consts.fl_up)
        per = jnp.where(down, consts.fl_period, per)
    return per


def fault_active(dims, consts, t):
    """Scalar bool: any port not healthy at tick ``t``."""
    import jax.numpy as jnp
    return jnp.any(port_period(dims, consts, t) != 1)


def transition_horizon(dims, consts, t):
    """Ticks until the next schedule transition strictly after ``t`` —
    the leap clamp.  Over ``[t, t + horizon)`` every port's period is
    constant, so fault activity cannot change inside a leap window."""
    import jax.numpy as jnp
    I32 = jnp.int32
    tr = t - consts.fault_start
    h = jnp.asarray(HORIZON_INF, I32)
    if dims.FK:
        dt = jnp.where(consts.ft_time > tr,
                       consts.ft_time - tr, HORIZON_INF)
        h = jnp.minimum(h, jnp.min(dt))
    if dims.flapped:
        has = consts.fl_cycle > 0
        cyc = jnp.maximum(consts.fl_cycle, 1)
        ph = (tr - consts.fl_start) % cyc
        to_bound = jnp.where(ph < consts.fl_up,
                             consts.fl_up - ph, cyc - ph)
        before = has & (tr < consts.fl_start)
        inside = has & (tr >= consts.fl_start) & (tr < consts.fl_end)
        d = jnp.where(
            before, consts.fl_start - tr,
            jnp.where(inside,
                      jnp.minimum(to_bound, consts.fl_end - tr),
                      HORIZON_INF))
        h = jnp.minimum(h, jnp.min(d))
    return jnp.maximum(h, 1)


# ---- host-side mirrors (recovery metrics in api.RunResult) ----

def np_port_period(cf: CompiledFaults, fault_start: int, t: int):
    """Numpy mirror of :func:`port_period` (same definition, exact)."""
    tr = t - fault_start
    if cf.FK:
        idx = np.maximum((tr >= cf.ft_time).sum(axis=1) - 1, 0)
        per = np.take_along_axis(cf.ft_period, idx[:, None], axis=1)[:, 0]
    else:
        per = np.ones(cf.ft_time.shape[0], np.int32)
    if cf.flapped:
        has = cf.fl_cycle > 0
        cyc = np.maximum(cf.fl_cycle, 1)
        ph = (tr - cf.fl_start) % cyc
        in_win = has & (tr >= cf.fl_start) & (tr < cf.fl_end)
        per = np.where(in_win & (ph >= cf.fl_up), cf.fl_period, per)
    return per


def _breakpoints(cf: CompiledFaults, fault_start: int, ticks: int):
    """Sorted absolute ticks in [0, ticks) where activity may change."""
    pts = {0}
    for tt in np.unique(cf.ft_time):
        at = int(tt) + fault_start
        if 0 <= at < ticks and tt < HORIZON_INF:
            pts.add(at)
    if cf.flapped:
        for q in np.where(cf.fl_cycle > 0)[0]:
            cyc, up = int(cf.fl_cycle[q]), int(cf.fl_up[q])
            s = int(cf.fl_start[q]) + fault_start
            e = min(int(cf.fl_end[q]) + fault_start, ticks)
            k = s
            while k < e:
                for b in (k, k + up):
                    if 0 <= b < min(e, ticks):
                        pts.add(b)
                k += cyc
            if 0 <= e < ticks:
                pts.add(e)
    return sorted(pts)


def fault_ticks(cf: CompiledFaults, fault_start: int, ticks: int) -> int:
    """Exact count of ticks in [0, ticks) with any port unhealthy —
    integrates the same piecewise-constant function the fabric evaluates
    (activity is constant between breakpoints), so no device counter is
    needed."""
    if not (cf.FK or cf.flapped) or ticks <= 0:
        return 0
    pts = _breakpoints(cf, fault_start, ticks) + [ticks]
    total = 0
    for a, b in zip(pts[:-1], pts[1:]):
        if np.any(np_port_period(cf, fault_start, a) != 1):
            total += b - a
    return int(total)


def repair_times(cf: CompiledFaults, fault_start: int, ticks: int) -> list:
    """Absolute ticks in (0, ticks) where the fabric transitions from
    fault-active to all-healthy — the anchors for time-to-recover."""
    if not (cf.FK or cf.flapped) or ticks <= 0:
        return []
    pts = _breakpoints(cf, fault_start, ticks)
    out, prev = [], False
    for a in pts:
        act = bool(np.any(np_port_period(cf, fault_start, a) != 1))
        if prev and not act and a > 0:
            out.append(int(a))
        prev = act
    return out


def first_fault_time(cf: CompiledFaults, fault_start: int,
                     ticks: int) -> int:
    """First absolute tick in [0, ticks) with any port unhealthy
    (-1 if the schedule never activates inside the run)."""
    if not (cf.FK or cf.flapped) or ticks <= 0:
        return -1
    for a in _breakpoints(cf, fault_start, ticks):
        if np.any(np_port_period(cf, fault_start, a) != 1):
            return int(a)
    return -1
