"""Experiment API: one declarative entry point for runs, seed batches,
and parameter sweeps (DESIGN.md Sec. 7).

The paper's evaluation is a grid of {workload x topology x algorithm x
tuning x seeds}.  This module lowers that grid onto the engine in two
calls::

    res = run("incast8_32n")                      # one run -> RunResult
    res = study("perm64",                          # P x S grid -> StudyResult
                points=[{"start_cwnd_mult": a} for a in (0.5, 1.0, 1.25)],
                seeds=range(4)).run()

``study`` fuses the engine's two batching mechanisms — the per-seed salt
scatter of ``Sim.run_batch`` and the per-point traced-``Consts`` batching
of the config sweep — into a single ``[P*S]`` vmap lane batch driven by
one superstep run loop:

* **one compile** — the composed step is traced exactly once for the
  whole grid (``engine.STEP_TRACE_COUNT``, asserted in tests/test_api.py);
  swept ``Consts`` leaves carry a leading ``[P*S]`` axis, everything else
  broadcasts;
* **per-lane trajectories** — every lane is gated on its *own* exit
  predicate and, when leaping, jumps by its *own* event horizon (clamped
  to its remaining budget), so each lane's final ``SimState`` — ``now``
  and metrics included — is **bit-for-bit equal** to the standalone
  ``Sim.run`` of that (point, seed), leap on or off;
* **donated buffers** — the freshly built ``[P*S]`` init state is donated
  to the run loop (DESIGN.md Sec. 6.1 contract); the batched ``Consts``
  are *not* donated and are reused across ``run()`` calls.

Results come back typed: :class:`RunResult` (per-lane summary, Jain
fairness, FCT slowdowns) and :class:`StudyResult` (point-major lane grid,
tidy-row export for the fig scripts and the benchmark ledger).

``engine.build(cfg, wl).run(...)`` and ``sweep.build_sweep(...)`` remain
as thin compatibility wrappers over the same machinery.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim import engine, metrics, scenarios, state
from repro.netsim.metrics import jain_fairness
from repro.netsim.scenarios import Scenario

I32 = jnp.int32

# --------------------------------------------------------------------------
# sweep points
# --------------------------------------------------------------------------

# make_cc_params tuning kwargs routable through SimConfig.cc_overrides
CC_PARAM_KEYS = frozenset({
    "target_mult", "fd", "md", "fi", "k_fast", "qa_scaling", "wtd_alpha",
    "wtd_thresh", "fi_rtt_tol", "maxcwnd_mult", "sw_ai", "sw_beta",
    "sw_max_mdf",
})
# numeric SimConfig fields that stay inside Consts (no Dims impact)
CFG_KEYS = frozenset({
    "rto_mult", "react_every", "credit_window_mult", "start_cwnd_mult",
    "kmin_frac", "kmax_frac", "num_entropies", "fault_start",
})
# SimConfig fields that change Dims / the compiled step — never sweepable;
# vary the Scenario instead (one build per value)
STATIC_KEYS = frozenset({
    "link", "tree", "algo", "cc_backend", "lb", "superstep", "leap",
    "trimming", "faults", "cc_overrides",
})


def apply_point(cfg: state.SimConfig, point: Mapping[str, float]) -> state.SimConfig:
    """Fold one sweep point into a SimConfig (cc keys -> cc_overrides)."""
    cfg_kw = {}
    cc = dict(cfg.cc_overrides)
    for k, v in dict(point).items():
        if k in CFG_KEYS:
            cfg_kw[k] = v
        elif k in CC_PARAM_KEYS:
            cc[k] = v
        elif k in STATIC_KEYS:
            raise KeyError(
                f"key {k!r} changes Dims (shapes/branches) and cannot be "
                f"swept inside one compiled step; build one Scenario per "
                f"value instead (scenario(name, {k}=...))")
        else:
            raise KeyError(
                f"unsweepable key {k!r}; numeric keys are "
                f"{sorted(CFG_KEYS | CC_PARAM_KEYS)}")
    return dataclasses.replace(cfg, cc_overrides=tuple(sorted(cc.items())),
                               **cfg_kw)


def _norm_point(point) -> tuple:
    """Normalize a sweep point to sorted ``((key, value), ...)``."""
    return tuple(sorted(dict(point).items()))


def point_tag(point) -> str:
    """Human/ledger tag for a sweep point (``"base"`` for the empty one)."""
    kv = _norm_point(point)
    return "+".join(f"{k}={v:g}" for k, v in kv) if kv else "base"


# --------------------------------------------------------------------------
# Consts lane batching
# --------------------------------------------------------------------------


def no_axes(consts: state.Consts):
    """An all-``None`` vmap in_axes tree matching ``consts``."""
    leaves, treedef = jax.tree_util.tree_flatten(consts)
    return jax.tree_util.tree_unflatten(treedef, [None] * len(leaves))


def _stack_consts(consts_list: Sequence[state.Consts], repeats: int):
    """Stack per-point Consts into a lane batch.

    Leaves identical across points stay unbatched (vmap axis ``None``);
    varying leaves are stacked to ``[P]`` and repeated ``repeats`` times
    along axis 0 to ``[P*repeats]`` (point-major lane order).  Returns
    ``(consts_b, axes)`` where ``axes`` is the matching in_axes tree.
    """
    flats, treedefs = zip(*[jax.tree_util.tree_flatten(c)
                            for c in consts_list])
    if any(td != treedefs[0] for td in treedefs[1:]):
        raise ValueError("sweep points disagree on Consts structure")
    leaves, axes_leaves = [], []
    for slot in zip(*flats):
        x0 = np.asarray(slot[0])
        if all(np.array_equal(np.asarray(x), x0) for x in slot[1:]):
            leaves.append(slot[0])
            axes_leaves.append(None)
        else:
            stacked = jnp.stack([jnp.asarray(x) for x in slot])
            leaves.append(jnp.repeat(stacked, repeats, axis=0)
                          if repeats > 1 else stacked)
            axes_leaves.append(0)
    return (jax.tree_util.tree_unflatten(treedefs[0], leaves),
            jax.tree_util.tree_unflatten(treedefs[0], axes_leaves))


# --------------------------------------------------------------------------
# the lane run loop
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4),
                   donate_argnums=(6,))
def _run_lanes(step_fn, horizon_fn, axes, max_ticks: int, superstep: int,
               consts_b, states: state.SimState) -> state.SimState:
    """Run a ``[B]`` lane batch to completion under one compiled step.

    Each lane is gated on its *own* exit predicate — the same scalar
    ``(now < max_ticks) & ~all(done)`` the standalone loop uses — so a
    finished lane freezes (its gated tick is the identity, bitwise) while
    the rest keep stepping, and every lane's final state equals its
    standalone ``Sim.run`` bit-for-bit, ``now`` included.  With
    ``horizon_fn`` the loop leaps **per lane**: each lane jumps by its own
    next-event distance under its own swept ``Consts`` (clamped to its
    remaining budget, zero once the lane is done), so sparse lanes skip
    their quiescent stretches without waiting on busy lanes (DESIGN.md
    Sec. 6.3).  The superstep structure (leap once, then K gated ticks per
    while iteration) matches ``engine._superstep_loop`` exactly.

    ``states`` is donated; ``consts_b`` is not (reused across calls).
    """
    def lane_live(st):
        return (st.now < max_ticks) & ~jnp.all(st.done)

    def lane_tick(c, st):
        return jax.lax.cond(lane_live(st), lambda s: step_fn(c, s),
                            lambda s: s, st)

    vtick = jax.vmap(lane_tick, in_axes=(axes, 0))

    def cond(st):
        return jnp.any((st.now < max_ticks) & ~jnp.all(st.done, axis=-1))

    leap = None
    if horizon_fn is not None:
        vhorizon = jax.vmap(horizon_fn, in_axes=(axes, 0))
        vlive = jax.vmap(lane_live)

        def leap(st):
            d = jnp.minimum(vhorizon(consts_b, st), max_ticks - st.now)
            d = jnp.where(vlive(st), d, 0)
            occ = jnp.sum(st.q_size[:, :-1], axis=1)
            return st._replace(now=st.now + d,
                               m=metrics.leap_account(st.m, d, occ))

    return engine._superstep_loop(lambda st: vtick(consts_b, st), cond,
                                  superstep, leap)(states)


# --------------------------------------------------------------------------
# typed results
# --------------------------------------------------------------------------


def _flow_meta(sim: engine.Sim) -> dict:
    """Host copies of the per-flow constants a RunResult carries."""
    return dict(size=np.asarray(sim.consts.size),
                t_start=np.asarray(sim.consts.t_start),
                flow_brtt=np.asarray(sim.consts.cc.brtt))


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class RunResult:
    """Typed summary of one finished run (one lane of a study).

    Per-flow arrays are host-side numpy; ``state`` keeps the full final
    ``SimState`` (host copies) for tests and deeper digging (excluded
    from ``row()``)."""

    scenario: str
    algo: str
    lb: str
    point: tuple              # normalized ((key, value), ...), () = base
    seed: int
    max_ticks: int
    ticks: int                # this lane's own final `now`
    mtu: int
    brtt: int                 # base RTT ticks == BDP packets
    fct: np.ndarray           # i32 [NF], -1 = unfinished
    goodput: np.ndarray       # i32 [NF] unique bytes delivered
    done: np.ndarray          # bool [NF]
    size: np.ndarray          # i32 [NF] flow bytes
    t_start: np.ndarray       # i32 [NF]
    flow_brtt: np.ndarray     # f32 [NF] per-flow base RTT (hop-specific)
    trims: int
    drops: int
    blackholed: int
    timeouts: int
    retx: int
    acks: int
    spurious_retx: int
    delivered_pkts: int
    delivered_bytes: float
    rtt_hist: np.ndarray
    q_mean: float
    q_max: int
    wall_s: float | None = None
    state: state.SimState | None = dataclasses.field(default=None)

    @classmethod
    def from_state(cls, sim: engine.Sim, st: state.SimState, *,
                   scenario: str, point=(), seed: int = 0,
                   max_ticks: int, wall_s: float | None = None,
                   flow_meta: dict | None = None) -> "RunResult":
        """Build from a (host or device) final state.  ``flow_meta`` lets a
        Study hoist the per-flow constants (size/t_start/flow_brtt host
        copies) out of its per-lane loop."""
        if flow_meta is None:
            flow_meta = _flow_meta(sim)
        m = st.m
        now = int(st.now)
        return cls(
            scenario=scenario, algo=sim.cfg.algo, lb=sim.cfg.lb,
            point=_norm_point(point), seed=int(seed), max_ticks=int(max_ticks),
            ticks=now, mtu=sim.dims.mtu, brtt=sim.dims.brtt_inter,
            fct=np.asarray(st.fct), goodput=np.asarray(st.goodput),
            done=np.asarray(st.done), **flow_meta,
            trims=int(m.n_trim), drops=int(m.n_drop),
            blackholed=int(m.n_black), timeouts=int(m.n_to),
            retx=int(m.n_retx), acks=int(m.n_ack),
            spurious_retx=int(m.spurious_retx),
            delivered_pkts=int(m.delivered_pkts),
            delivered_bytes=float(m.delivered_bytes),
            rtt_hist=np.asarray(m.rtt_hist),
            q_mean=float(m.q_sum) / max(1, now) / sim.dims.NQ,
            q_max=int(m.q_max), wall_s=wall_s, state=st)

    # -- flow-level views ---------------------------------------------------

    @property
    def n_flows(self) -> int:
        return int(self.fct.shape[0])

    @property
    def n_done(self) -> int:
        return int(self.done.sum())

    @property
    def all_done(self) -> bool:
        return bool(self.done.all())

    @property
    def fct_done(self) -> np.ndarray:
        return self.fct[self.done]

    @property
    def completion(self) -> int:
        """Last flow-completion tick (-1 when nothing finished)."""
        return int(self.fct_done.max()) if self.n_done else -1

    @property
    def fct_min(self) -> int:
        return int(self.fct_done.min()) if self.n_done else -1

    @property
    def fct_mean(self) -> float:
        return float(self.fct_done.mean()) if self.n_done else -1.0

    @property
    def fct_p99(self) -> float:
        return float(np.percentile(self.fct_done, 99)) if self.n_done else -1.0

    @property
    def jain(self) -> float:
        """Jain fairness over finished-flow FCTs."""
        return jain_fairness(self.fct_done) if self.n_done else 0.0

    @property
    def ideal_fct(self) -> np.ndarray:
        """Per-flow uncongested lower bound: back-to-back serialization of
        ``ceil(size/mtu)`` packets plus that flow's base RTT (hop-count
        specific — intra-rack flows have a shorter one)."""
        pkts = -(-self.size.astype(np.int64) // self.mtu)
        return np.maximum(pkts - 1 + self.flow_brtt.astype(np.float64), 1.0)

    @property
    def slowdown(self) -> np.ndarray:
        """FCT slowdown vs the uncongested ideal (NaN for unfinished)."""
        s = self.fct / self.ideal_fct.astype(np.float64)
        return np.where(self.done, s, np.nan)

    @property
    def slowdown_mean(self) -> float:
        return (float(np.nanmean(self.slowdown)) if self.n_done else -1.0)

    @property
    def slowdown_p99(self) -> float:
        return (float(np.nanpercentile(self.slowdown, 99))
                if self.n_done else -1.0)

    @property
    def spurious_frac(self) -> float:
        return self.spurious_retx / max(1, self.delivered_pkts)

    # -- export -------------------------------------------------------------

    @property
    def point_tag(self) -> str:
        return point_tag(self.point)

    @property
    def name(self) -> str:
        """Stable row key: ``scenario/algo+lb[point]/sN``."""
        return (f"{self.scenario}/{self.algo}+{self.lb}"
                f"[{self.point_tag}]/s{self.seed}")

    def row(self) -> dict:
        """One tidy, JSON-able row for fig scripts and the bench ledger."""
        d = dict(
            name=self.name, scenario=self.scenario, algo=self.algo,
            lb=self.lb, point=dict(self.point), seed=self.seed,
            max_ticks=self.max_ticks, ticks=self.ticks,
            n_flows=self.n_flows, n_done=self.n_done,
            all_done=self.all_done, completion=self.completion,
            fct_mean=round(self.fct_mean, 3), fct_p99=round(self.fct_p99, 3),
            jain=round(self.jain, 6),
            slowdown_mean=round(self.slowdown_mean, 6),
            slowdown_p99=round(self.slowdown_p99, 6),
            trims=self.trims, drops=self.drops, blackholed=self.blackholed,
            timeouts=self.timeouts, retx=self.retx,
            spurious_frac=round(self.spurious_frac, 6),
            delivered_bytes=self.delivered_bytes,
            q_mean=round(self.q_mean, 6), q_max=self.q_max,
        )
        if self.wall_s is not None:
            d["wall_s"] = round(self.wall_s, 6)
        return d

    def summary(self) -> dict:
        """Legacy ``metrics.summarize``-shaped dict (compat helper)."""
        return dict(
            ticks=self.ticks, all_done=self.all_done, n_done=self.n_done,
            fct_ticks=self.fct, fct_max=self.completion,
            fct_min=self.fct_min, fct_mean=self.fct_mean,
            fct_p99=self.fct_p99,
            spread=(float(self.fct_done.max() - self.fct_done.min())
                    if self.n_done else -1.0),
            trims=self.trims, drops=self.drops, blackholed=self.blackholed,
            timeouts=self.timeouts, retx=self.retx, acks=self.acks,
            delivered_bytes=self.delivered_bytes,
            spurious_retx=self.spurious_retx,
            spurious_frac=self.spurious_frac, rtt_hist=self.rtt_hist,
            q_mean=self.q_mean, q_max=self.q_max,
            goodput_bytes=self.goodput, mtu=self.mtu)

    def __repr__(self) -> str:
        return (f"RunResult({self.name}: ticks={self.ticks} "
                f"done={self.n_done}/{self.n_flows} "
                f"completion={self.completion} jain={self.jain:.3f} "
                f"trims={self.trims})")


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class StudyResult:
    """The finished ``P x S`` grid: point-major lanes of RunResults."""

    scenario: str
    points: tuple             # P normalized points
    seeds: tuple              # S ints
    results: tuple            # P*S RunResults, lane = p*S + s
    states: state.SimState    # [P*S]-batched final states
    wall_s: float

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, lane) -> RunResult:
        return self.results[lane]

    @property
    def n_points(self) -> int:
        return len(self.points)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    def lane(self, point_idx: int, seed_idx: int = 0) -> RunResult:
        return self.results[point_idx * self.n_seeds + seed_idx]

    def by_point(self, point_idx: int) -> tuple:
        """All seeds of one sweep point."""
        s = self.n_seeds
        return self.results[point_idx * s:(point_idx + 1) * s]

    def rows(self) -> list:
        """Tidy rows (one per lane) for fig scripts / the bench ledger."""
        return [r.row() for r in self.results]

    def best(self, metric: str = "completion") -> RunResult:
        """Lane minimizing ``metric`` (unfinished lanes rank last)."""
        def key(r):
            v = getattr(r, metric)
            return (not r.all_done, v if v >= 0 else np.inf)
        return min(self.results, key=key)

    def __repr__(self) -> str:
        return (f"StudyResult({self.scenario}: {self.n_points} points x "
                f"{self.n_seeds} seeds, wall {self.wall_s:.2f}s)")


# --------------------------------------------------------------------------
# the Study planner
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class Study:
    """A planned ``Scenario x points x seeds`` grid, lowered onto one
    compiled step.  Build via :func:`study`; execute via :meth:`run`
    (typed results) or :meth:`run_states` (raw ``[P*S]`` states)."""

    scenario: Scenario
    points: tuple             # P normalized ((k, v), ...) points
    seeds: tuple              # S ints
    sim: engine.Sim           # built for the base config
    consts_b: state.Consts    # swept leaves carry a leading [P*S] axis
    axes: state.Consts        # matching vmap in_axes tree (0 / None)
    salts: tuple              # P*S ints, lane = p*S + s -> seeds[s]

    @property
    def n_points(self) -> int:
        return len(self.points)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    @property
    def n_lanes(self) -> int:
        return len(self.salts)

    def init(self) -> state.SimState:
        """The ``[P*S]`` tick-0 lane batch: one vmapped ``init_state``
        trace over the batched Consts, then the per-lane seed salts.
        Every leaf is a fresh buffer (donation-safe)."""
        dims = self.sim.dims
        states = jax.vmap(lambda c: state.init_state(dims, c),
                          in_axes=(self.axes,),
                          axis_size=self.n_lanes)(self.consts_b)
        return states._replace(salt=jnp.asarray(self.salts, I32))

    def run_states(self, max_ticks: int | None = None) -> state.SimState:
        """Run all lanes to completion; one step compile for the grid.
        The freshly built lane batch is donated to the run loop."""
        mt = int(max_ticks if max_ticks is not None
                 else self.scenario.max_ticks)
        horizon_fn = self.sim.horizon_fn if self.sim.dims.leap else None
        return _run_lanes(self.sim.step_fn, horizon_fn, self.axes, mt,
                          self.sim.dims.superstep, self.consts_b, self.init())

    def run(self, max_ticks: int | None = None) -> StudyResult:
        """Execute the grid and pull typed per-lane results."""
        mt = int(max_ticks if max_ticks is not None
                 else self.scenario.max_ticks)
        t0 = time.time()
        states = self.run_states(mt)
        states.now.block_until_ready()
        wall = time.time() - t0
        # one bulk device->host transfer; lanes then slice numpy (the
        # per-lane RunResults would otherwise issue ~25 tiny transfers
        # per lane)
        states_h = jax.device_get(states)
        meta = _flow_meta(self.sim)
        results = []
        for pi, pt in enumerate(self.points):
            for si, seed in enumerate(self.seeds):
                lane = pi * self.n_seeds + si
                lane_st = jax.tree.map(lambda x: x[lane], states_h)
                results.append(RunResult.from_state(
                    self.sim, lane_st, scenario=self.scenario.name,
                    point=pt, seed=seed, max_ticks=mt, flow_meta=meta))
        return StudyResult(scenario=self.scenario.name, points=self.points,
                           seeds=self.seeds, results=tuple(results),
                           states=states, wall_s=wall)

    def __repr__(self) -> str:
        return (f"Study({self.scenario.name}: {self.n_points} points x "
                f"{self.n_seeds} seeds = {self.n_lanes} lanes)")


def _resolve(sc) -> Scenario:
    return scenarios.scenario(sc) if isinstance(sc, str) else sc


def study(sc, points=None, seeds=(0,), **scenario_overrides) -> Study:
    """Plan a ``Scenario x points x seeds`` grid as one compiled step.

    ``sc`` is a :class:`Scenario` or a registered scenario name;
    ``points`` a sequence of sweep-point mappings (numeric ``SimConfig``
    fields and CC tuning kwargs — see ``CFG_KEYS`` / ``CC_PARAM_KEYS``;
    ``None`` or ``[{}]`` = just the base config); ``seeds`` the per-lane
    salt seeds.  Anything per-point that would change ``Dims`` raises at
    plan time (``KeyError``)."""
    sc = _resolve(sc)
    if scenario_overrides:
        sc = sc.with_(**scenario_overrides)
    pts = (tuple(_norm_point(p) for p in points)
           if points is not None else ((),))
    if not pts:
        raise ValueError("empty sweep")
    seeds = tuple(int(s) for s in seeds)
    if not seeds:
        raise ValueError("empty seeds")
    # engine.build -> state.derive validates the workload up front
    sim = engine.build(sc.cfg, sc.wl)
    # derive() is re-run per point: that repeats the O(NF) structural host
    # loops, but keeps a single source of truth for Consts derivation.
    # Host-side cost is negligible next to the device run; identical
    # leaves are deduplicated in _stack_consts.
    consts_list = [sim.consts if not pt
                   else state.derive(apply_point(sc.cfg, dict(pt)), sc.wl)[3]
                   for pt in pts]
    consts_b, axes = _stack_consts(consts_list, repeats=len(seeds))
    salts = tuple(np.tile(np.asarray(seeds, np.int64), len(pts)).tolist())
    return Study(scenario=sc, points=pts, seeds=seeds, sim=sim,
                 consts_b=consts_b, axes=axes, salts=salts)


def run(sc, *, seed: int = 0, max_ticks: int | None = None,
        **scenario_overrides) -> RunResult:
    """Run one scenario standalone (unbatched ``Sim.run``) -> RunResult.

    ``sc`` is a :class:`Scenario` or a registered name; ``overrides`` are
    forwarded to :meth:`Scenario.with_` (``algo=``, ``lb=``, ...)."""
    sc = _resolve(sc)
    if scenario_overrides:
        sc = sc.with_(**scenario_overrides)
    mt = int(max_ticks if max_ticks is not None else sc.max_ticks)
    sim = engine.build(sc.cfg, sc.wl)   # derive validates the workload
    t0 = time.time()
    st = sim.run(max_ticks=mt, seed=seed)
    st.now.block_until_ready()
    wall = time.time() - t0
    return RunResult.from_state(sim, jax.device_get(st), scenario=sc.name,
                                seed=seed, max_ticks=mt, wall_s=wall)
