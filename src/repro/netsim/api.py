"""Experiment API: one declarative entry point for runs, seed batches,
and parameter sweeps (DESIGN.md Sec. 7).

The paper's evaluation is a grid of {workload x topology x algorithm x
tuning x seeds}.  This module lowers that grid onto the engine in two
calls::

    res = run("incast8_32n")                      # one run -> RunResult
    res = study("perm64",                          # P x S grid -> StudyResult
                points=[{"start_cwnd_mult": a} for a in (0.5, 1.0, 1.25)],
                seeds=range(4)).run()

``study`` fuses the engine's two batching mechanisms — the per-seed salt
scatter of ``Sim.run_batch`` and the per-point traced-``Consts`` batching
of the config sweep — into a single ``[P*S]`` vmap lane batch driven by
one superstep run loop:

* **one compile** — the composed step is traced exactly once for the
  whole grid (``trace_guard("engine.step")``, asserted in tests/test_api.py);
  swept ``Consts`` leaves carry a leading ``[P*S]`` axis, everything else
  broadcasts;
* **per-lane trajectories** — every lane is gated on its *own* exit
  predicate and, when leaping, jumps by its *own* event horizon (clamped
  to its remaining budget), so each lane's final ``SimState`` — ``now``
  and metrics included — is **bit-for-bit equal** to the standalone
  ``Sim.run`` of that (point, seed), leap on or off;
* **donated buffers** — the freshly built ``[P*S]`` init state is donated
  to the run loop (DESIGN.md Sec. 6.1 contract); the batched ``Consts``
  are *not* donated and are reused across ``run()`` calls.

Results come back typed: :class:`RunResult` (per-lane summary, Jain
fairness, FCT slowdowns) and :class:`StudyResult` (point-major lane grid,
tidy-row export for the fig scripts and the benchmark ledger).

Fleet-scale execution (DESIGN.md Sec. 7) layers three orthogonal knobs
onto ``Study.run`` without touching the fast path:

* ``mesh=`` shards the lane batch across devices
  (``netsim/shard.py`` — bit-identical to the single-device vmap path);
* ``cache=`` reuses lanes by content address
  (``netsim/cache.py`` — keyed ``(scenario, point, seed, code_digest)``,
  so re-running a sweep with 3 new points recomputes only ``3*S`` lanes);
* ``chunk_lanes=`` runs the missing lanes in chunks, flushing each
  finished chunk to the cache — a killed grid resumes from the last
  completed chunk, bit-equal to an uninterrupted run.

``engine.build(cfg, wl).run(...)`` and ``sweep.build_sweep(...)`` remain
as thin compatibility wrappers over the same machinery.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim import cache as cache_mod
from repro.netsim import engine, faults as faults_mod, scenarios, shard, state
from repro.netsim.metrics import jain_fairness
from repro.netsim.scenarios import Scenario

I32 = jnp.int32

# --------------------------------------------------------------------------
# sweep points
# --------------------------------------------------------------------------

# make_cc_params tuning kwargs routable through SimConfig.cc_overrides
CC_PARAM_KEYS = frozenset({
    "target_mult", "fd", "md", "fi", "k_fast", "qa_scaling", "wtd_alpha",
    "wtd_thresh", "fi_rtt_tol", "maxcwnd_mult", "sw_ai", "sw_beta",
    "sw_max_mdf",
})
# numeric SimConfig fields that stay inside Consts (no Dims impact)
CFG_KEYS = frozenset({
    "rto_mult", "react_every", "credit_window_mult", "start_cwnd_mult",
    "kmin_frac", "kmax_frac", "num_entropies", "fault_start",
    "goodput_bin",
})
# SimConfig fields that change Dims / the compiled step — never sweepable;
# vary the Scenario instead (one build per value).  The recovery knobs
# (rto_backoff_max / evict_on_timeout) are here because crossing their
# off/on boundary changes the traced graph — sweeping them would silently
# keep the base config's branch.  The three backend selectors swap whole
# kernel implementations, so they are static by the same argument.
# ``repro.analysis`` (JX006) perturbs every SimConfig field through
# ``derive`` and fails the build if this classification drifts from the
# empirical Dims/aval impact.
STATIC_KEYS = frozenset({
    "link", "tree", "algo", "cc_backend", "fabric_backend",
    "transport_backend", "lb", "superstep", "leap",
    "trimming", "faults", "cc_overrides", "rto_backoff_max",
    "evict_on_timeout",
})


def apply_point(cfg: state.SimConfig, point: Mapping[str, float]) -> state.SimConfig:
    """Fold one sweep point into a SimConfig (cc keys -> cc_overrides)."""
    cfg_kw = {}
    cc = dict(cfg.cc_overrides)
    for k, v in dict(point).items():
        if k in CFG_KEYS:
            cfg_kw[k] = v
        elif k in CC_PARAM_KEYS:
            cc[k] = v
        elif k in STATIC_KEYS:
            raise KeyError(
                f"key {k!r} changes Dims (shapes/branches) and cannot be "
                f"swept inside one compiled step; build one Scenario per "
                f"value instead (scenario(name, {k}=...))")
        else:
            raise KeyError(
                f"unsweepable key {k!r}; numeric keys are "
                f"{sorted(CFG_KEYS | CC_PARAM_KEYS)}")
    return dataclasses.replace(cfg, cc_overrides=tuple(sorted(cc.items())),
                               **cfg_kw)


def _norm_point(point) -> tuple:
    """Normalize a sweep point to sorted ``((key, value), ...)``."""
    return tuple(sorted(dict(point).items()))


def point_tag(point) -> str:
    """Human/ledger tag for a sweep point (``"base"`` for the empty one)."""
    kv = _norm_point(point)
    return "+".join(f"{k}={v:g}" for k, v in kv) if kv else "base"


# --------------------------------------------------------------------------
# Consts lane batching
# --------------------------------------------------------------------------


def no_axes(consts: state.Consts):
    """An all-``None`` vmap in_axes tree matching ``consts``."""
    leaves, treedef = jax.tree_util.tree_flatten(consts)
    return jax.tree_util.tree_unflatten(treedef, [None] * len(leaves))


def _stack_consts(consts_list: Sequence[state.Consts], repeats: int):
    """Stack per-point Consts into a lane batch.

    Leaves identical across points stay unbatched (vmap axis ``None``);
    varying leaves are stacked to ``[P]`` and repeated ``repeats`` times
    along axis 0 to ``[P*repeats]`` (point-major lane order).  Returns
    ``(consts_b, axes)`` where ``axes`` is the matching in_axes tree.
    """
    flats, treedefs = zip(*[jax.tree_util.tree_flatten(c)
                            for c in consts_list])
    if any(td != treedefs[0] for td in treedefs[1:]):
        raise ValueError("sweep points disagree on Consts structure")
    leaves, axes_leaves = [], []
    for slot in zip(*flats):
        x0 = np.asarray(slot[0])
        if all(np.array_equal(np.asarray(x), x0) for x in slot[1:]):
            leaves.append(slot[0])
            axes_leaves.append(None)
        else:
            stacked = jnp.stack([jnp.asarray(x) for x in slot])
            leaves.append(jnp.repeat(stacked, repeats, axis=0)
                          if repeats > 1 else stacked)
            axes_leaves.append(0)
    return (jax.tree_util.tree_unflatten(treedefs[0], leaves),
            jax.tree_util.tree_unflatten(treedefs[0], axes_leaves))


# --------------------------------------------------------------------------
# the lane run loop (moved to netsim/shard.py; compat re-export)
# --------------------------------------------------------------------------

# The per-lane gated/leaping superstep loop and its single-device jit now
# live in ``netsim/shard.py`` next to the shard_map execution path, so
# both share one loop body.  Kept under the historical name for callers.
_run_lanes = shard._run_lanes


# --------------------------------------------------------------------------
# typed results
# --------------------------------------------------------------------------


def _flow_meta(sim: engine.Sim) -> dict:
    """Host copies of the per-flow constants a RunResult carries.
    ``coll_id`` is host-only workload metadata (never lowered into
    Consts) — it groups flows into collectives for the CCT metric."""
    return dict(size=np.asarray(sim.consts.size),
                t_start=np.asarray(sim.consts.t_start),
                flow_brtt=np.asarray(sim.consts.cc.brtt),
                coll_id=(None if sim.wl.coll_id is None
                         else np.asarray(sim.wl.coll_id)))


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class RunResult:
    """Typed summary of one finished run (one lane of a study).

    Per-flow arrays are host-side numpy; ``state`` keeps the full final
    ``SimState`` (host copies) for tests and deeper digging (excluded
    from ``row()``)."""

    scenario: str
    algo: str
    lb: str
    point: tuple              # normalized ((key, value), ...), () = base
    seed: int
    max_ticks: int
    ticks: int                # this lane's own final `now`
    mtu: int
    brtt: int                 # base RTT ticks == BDP packets
    fct: np.ndarray           # i32 [NF], -1 = unfinished
    goodput: np.ndarray       # i32 [NF] unique bytes delivered
    done: np.ndarray          # bool [NF]
    size: np.ndarray          # i32 [NF] flow bytes
    t_start: np.ndarray       # i32 [NF]
    flow_brtt: np.ndarray     # f32 [NF] per-flow base RTT (hop-specific)
    trims: int
    drops: int
    blackholed: int
    timeouts: int
    retx: int
    acks: int
    spurious_retx: int
    delivered_pkts: int
    delivered_bytes: float
    rtt_hist: np.ndarray
    q_mean: float
    q_max: int
    # collective grouping (None when the workload has no coll_id column)
    coll_id: np.ndarray | None = None   # i32 [NF], -1 = not in a collective
    # recovery metrics (zero/empty when the config has no fault schedule)
    delivered_bytes_fault: float = 0.0
    goodput_hist: np.ndarray | None = None  # f32 [GOODPUT_BINS] binned bytes
    goodput_bin: int = 0      # histogram bin width (ticks)
    fault_ticks: int = 0      # ticks in [0, ticks) with any port unhealthy
    repair_ticks: tuple = ()  # schedule transitions back to all-healthy
    first_fault: int = -1     # first fault-active tick (-1 = never)
    wall_s: float | None = None
    state: state.SimState | None = dataclasses.field(default=None)

    @classmethod
    def from_state(cls, sim: engine.Sim, st: state.SimState, *,
                   scenario: str, point=(), seed: int = 0,
                   max_ticks: int, wall_s: float | None = None,
                   flow_meta: dict | None = None) -> "RunResult":
        """Build from a (host or device) final state.  ``flow_meta`` lets a
        Study hoist the per-flow constants (size/t_start/flow_brtt host
        copies) out of its per-lane loop."""
        if flow_meta is None:
            flow_meta = _flow_meta(sim)
        m = st.m
        now = int(st.now)
        # fault-schedule host meta: the activity function is static (the
        # schedule times a possibly point-swept fault_start), so
        # fault_ticks / repair anchors integrate host-side exactly —
        # no device counter or leap-accounting term needed
        pt = dict(_norm_point(point))
        eff_fs = int(pt.get("fault_start", sim.cfg.fault_start))
        eff_gb = (int(pt.get("goodput_bin", sim.cfg.goodput_bin))
                  or 8 * sim.dims.brtt_inter)
        sched = faults_mod.lower(sim.cfg.faults)
        if sched:
            cf = faults_mod.compile_tables(sched, sim.topo, eff_fs)
            fault_meta = dict(
                fault_ticks=faults_mod.fault_ticks(cf, eff_fs, now),
                repair_ticks=tuple(faults_mod.repair_times(cf, eff_fs, now)),
                first_fault=faults_mod.first_fault_time(cf, eff_fs, now),
            )
        else:
            fault_meta = {}
        return cls(
            scenario=scenario, algo=sim.cfg.algo, lb=sim.cfg.lb,
            point=_norm_point(point), seed=int(seed), max_ticks=int(max_ticks),
            ticks=now, mtu=sim.dims.mtu, brtt=sim.dims.brtt_inter,
            fct=np.asarray(st.fct), goodput=np.asarray(st.goodput),
            done=np.asarray(st.done), **flow_meta,
            trims=int(m.n_trim), drops=int(m.n_drop),
            blackholed=int(m.n_black), timeouts=int(m.n_to),
            retx=int(m.n_retx), acks=int(m.n_ack),
            spurious_retx=int(m.spurious_retx),
            delivered_pkts=int(m.delivered_pkts),
            delivered_bytes=float(m.delivered_bytes),
            rtt_hist=np.asarray(m.rtt_hist),
            q_mean=float(m.q_sum) / max(1, now) / sim.dims.NQ,
            q_max=int(m.q_max),
            delivered_bytes_fault=float(m.delivered_bytes_fault),
            goodput_hist=np.asarray(m.goodput_hist),
            goodput_bin=eff_gb, **fault_meta,
            wall_s=wall_s, state=st)

    # -- flow-level views ---------------------------------------------------

    @property
    def n_flows(self) -> int:
        return int(self.fct.shape[0])

    @property
    def n_done(self) -> int:
        return int(self.done.sum())

    @property
    def all_done(self) -> bool:
        return bool(self.done.all())

    @property
    def fct_done(self) -> np.ndarray:
        return self.fct[self.done]

    @property
    def completion(self) -> int:
        """Last flow-completion tick (-1 when nothing finished)."""
        return int(self.fct_done.max()) if self.n_done else -1

    @property
    def fct_min(self) -> int:
        return int(self.fct_done.min()) if self.n_done else -1

    @property
    def fct_mean(self) -> float:
        return float(self.fct_done.mean()) if self.n_done else -1.0

    @property
    def fct_p99(self) -> float:
        return float(np.percentile(self.fct_done, 99)) if self.n_done else -1.0

    @property
    def jain(self) -> float:
        """Jain fairness over finished-flow FCTs."""
        return jain_fairness(self.fct_done) if self.n_done else 0.0

    @property
    def ideal_fct(self) -> np.ndarray:
        """Per-flow uncongested lower bound: back-to-back serialization of
        ``ceil(size/mtu)`` packets plus that flow's base RTT (hop-count
        specific — intra-rack flows have a shorter one)."""
        pkts = -(-self.size.astype(np.int64) // self.mtu)
        return np.maximum(pkts - 1 + self.flow_brtt.astype(np.float64), 1.0)

    @property
    def slowdown(self) -> np.ndarray:
        """FCT slowdown vs the uncongested ideal (NaN for unfinished)."""
        s = self.fct / self.ideal_fct.astype(np.float64)
        return np.where(self.done, s, np.nan)

    @property
    def slowdown_mean(self) -> float:
        return (float(np.nanmean(self.slowdown)) if self.n_done else -1.0)

    @property
    def slowdown_p99(self) -> float:
        return (float(np.nanpercentile(self.slowdown, 99))
                if self.n_done else -1.0)

    @property
    def spurious_frac(self) -> float:
        return self.spurious_retx / max(1, self.delivered_pkts)

    # -- collective completion time (DESIGN.md Sec. 11) ---------------------

    @property
    def cct_by_coll(self) -> dict:
        """Per-collective completion time (CCT), keyed by ``coll_id``:
        ticks from the group's earliest ``t_start`` to its last flow's
        delivery (``max(fct + t_start) - min(t_start)`` over members);
        -1 while any member is unfinished.  Empty without a ``coll_id``
        column."""
        if self.coll_id is None:
            return {}
        out = {}
        finish = self.fct.astype(np.int64) + self.t_start
        for c in np.unique(self.coll_id[self.coll_id >= 0]):
            m = self.coll_id == c
            out[int(c)] = (int(finish[m].max() - self.t_start[m].min())
                           if self.done[m].all() else -1)
        return out

    @property
    def cct(self) -> int:
        """Slowest collective's CCT (-1: none defined, or any collective
        unfinished) — the scalar the bench ledger tracks."""
        ccts = self.cct_by_coll
        if not ccts or any(v < 0 for v in ccts.values()):
            return -1
        return max(ccts.values())

    # -- recovery metrics (ISSUE 8) -----------------------------------------

    @property
    def delivered_fault_frac(self) -> float:
        """Fraction of delivered bytes that landed while the fault
        schedule was active (0.0 without faults)."""
        return self.delivered_bytes_fault / max(self.delivered_bytes, 1.0)

    def _goodput_rates(self):
        """(rates, n_bins): per-bin delivered bytes/tick over the run."""
        if self.goodput_hist is None or self.goodput_bin <= 0:
            return np.zeros(0), 0
        n = min(len(self.goodput_hist),
                -(-max(self.ticks, 1) // self.goodput_bin))
        return self.goodput_hist[:n] / float(self.goodput_bin), n

    @property
    def _baseline_rate(self) -> float:
        """Healthy goodput reference: mean rate over the bins fully
        before the first fault, falling back to the peak bin when the
        fault is active from tick 0."""
        rates, n = self._goodput_rates()
        if not n:
            return 0.0
        pre = self.first_fault // self.goodput_bin if self.first_fault > 0 \
            else 0
        if pre > 0:
            return float(rates[:pre].mean())
        return float(rates.max())

    @property
    def time_to_recover(self) -> tuple:
        """Per repair event: ticks from the repair until binned goodput
        first returns to >= 90% of the healthy baseline (-1 = never
        inside the run)."""
        rates, n = self._goodput_rates()
        base = self._baseline_rate
        out = []
        for r in self.repair_ticks:
            ttr = -1
            if n and base > 0:
                b0 = min(r // self.goodput_bin, n - 1)
                for b in range(b0, n):
                    if rates[b] >= 0.9 * base:
                        ttr = max((b + 1) * self.goodput_bin - r, 0)
                        break
            out.append(int(ttr))
        return tuple(out)

    @property
    def ttr_max(self) -> int:
        """Worst per-fault-event time-to-recover (-1: no repair events,
        or goodput never returned to baseline inside the run)."""
        ttrs = self.time_to_recover
        if not ttrs or any(t < 0 for t in ttrs):
            return -1
        return max(ttrs)

    @property
    def dip_depth(self) -> float:
        """Goodput dip depth while the schedule is active: 1 - (minimum
        binned rate inside the fault window) / baseline, in [0, 1]."""
        rates, n = self._goodput_rates()
        base = self._baseline_rate
        if not n or base <= 0 or self.first_fault < 0:
            return 0.0
        b0 = min(self.first_fault // self.goodput_bin, n - 1)
        return float(np.clip(1.0 - rates[b0:].min() / base, 0.0, 1.0))

    @property
    def dip_ticks(self) -> int:
        """Ticks (bin-quantized) from the first fault with binned goodput
        below 90% of the healthy baseline — the dip duration."""
        rates, n = self._goodput_rates()
        base = self._baseline_rate
        if not n or base <= 0 or self.first_fault < 0:
            return 0
        b0 = min(self.first_fault // self.goodput_bin, n - 1)
        return int((rates[b0:] < 0.9 * base).sum()) * self.goodput_bin

    # -- export -------------------------------------------------------------

    @property
    def point_tag(self) -> str:
        return point_tag(self.point)

    @property
    def name(self) -> str:
        """Stable row key: ``scenario/algo+lb[point]/sN``."""
        return (f"{self.scenario}/{self.algo}+{self.lb}"
                f"[{self.point_tag}]/s{self.seed}")

    def row(self) -> dict:
        """One tidy, JSON-able row for fig scripts and the bench ledger."""
        d = dict(
            name=self.name, scenario=self.scenario, algo=self.algo,
            lb=self.lb, point=dict(self.point), seed=self.seed,
            max_ticks=self.max_ticks, ticks=self.ticks,
            n_flows=self.n_flows, n_done=self.n_done,
            all_done=self.all_done, completion=self.completion,
            fct_mean=round(self.fct_mean, 3), fct_p99=round(self.fct_p99, 3),
            jain=round(self.jain, 6),
            slowdown_mean=round(self.slowdown_mean, 6),
            slowdown_p99=round(self.slowdown_p99, 6),
            trims=self.trims, drops=self.drops, blackholed=self.blackholed,
            timeouts=self.timeouts, retx=self.retx,
            spurious_frac=round(self.spurious_frac, 6),
            delivered_bytes=self.delivered_bytes,
            q_mean=round(self.q_mean, 6), q_max=self.q_max,
        )
        if self.coll_id is not None and np.any(self.coll_id >= 0):
            # collective metrics, only when the workload groups flows
            # (keeps plain flow-list ledger rows unchanged)
            d.update(cct=self.cct, n_collectives=len(self.cct_by_coll))
        if self.first_fault >= 0:
            # recovery metrics, only for runs with an active fault
            # schedule (keeps fault-free ledger rows unchanged)
            d.update(
                fault_ticks=self.fault_ticks,
                delivered_fault_frac=round(self.delivered_fault_frac, 6),
                ttr_max=self.ttr_max,
                dip_depth=round(self.dip_depth, 4),
                dip_ticks=self.dip_ticks,
            )
        if self.wall_s is not None:
            d["wall_s"] = round(self.wall_s, 6)
        return d

    def summary(self) -> dict:
        """Legacy ``metrics.summarize``-shaped dict (compat helper)."""
        return dict(
            ticks=self.ticks, all_done=self.all_done, n_done=self.n_done,
            fct_ticks=self.fct, fct_max=self.completion,
            fct_min=self.fct_min, fct_mean=self.fct_mean,
            fct_p99=self.fct_p99,
            spread=(float(self.fct_done.max() - self.fct_done.min())
                    if self.n_done else -1.0),
            trims=self.trims, drops=self.drops, blackholed=self.blackholed,
            timeouts=self.timeouts, retx=self.retx, acks=self.acks,
            delivered_bytes=self.delivered_bytes,
            spurious_retx=self.spurious_retx,
            spurious_frac=self.spurious_frac, rtt_hist=self.rtt_hist,
            q_mean=self.q_mean, q_max=self.q_max,
            goodput_bytes=self.goodput, mtu=self.mtu)

    def __repr__(self) -> str:
        return (f"RunResult({self.name}: ticks={self.ticks} "
                f"done={self.n_done}/{self.n_flows} "
                f"completion={self.completion} jain={self.jain:.3f} "
                f"trims={self.trims})")


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class StudyResult:
    """The finished ``P x S`` grid: point-major lanes of RunResults."""

    scenario: str
    points: tuple             # P normalized points
    seeds: tuple              # S ints
    results: tuple            # P*S RunResults, lane = p*S + s
    states: state.SimState    # [P*S]-batched final states
    wall_s: float
    cache_hits: int = 0       # lanes served from the result cache
    cache_misses: int = 0     # lanes actually computed (when caching)

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, lane) -> RunResult:
        return self.results[lane]

    @property
    def n_points(self) -> int:
        return len(self.points)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    def lane(self, point_idx: int, seed_idx: int = 0) -> RunResult:
        return self.results[point_idx * self.n_seeds + seed_idx]

    def by_point(self, point_idx: int) -> tuple:
        """All seeds of one sweep point."""
        s = self.n_seeds
        return self.results[point_idx * s:(point_idx + 1) * s]

    def rows(self) -> list:
        """Tidy rows (one per lane) for fig scripts / the bench ledger."""
        return [r.row() for r in self.results]

    def best(self, metric: str = "completion") -> RunResult:
        """Lane minimizing ``metric``.  Unfinished lanes rank *strictly*
        last regardless of their metric value (an unfinished lane's
        partial completion/FCT can look arbitrarily good — including the
        0 / -1 / NaN sentinels — and must never beat a finished lane);
        sentinel values (negative, NaN) rank last within each group, and
        exact ties resolve to the lowest lane index (stable)."""
        def key(lane_r):
            lane, r = lane_r
            v = getattr(r, metric)
            v = float(v)
            if not (v >= 0):          # negative sentinel or NaN
                v = np.inf
            return (not r.all_done, v, lane)
        return min(enumerate(self.results), key=key)[1]

    def __repr__(self) -> str:
        return (f"StudyResult({self.scenario}: {self.n_points} points x "
                f"{self.n_seeds} seeds, wall {self.wall_s:.2f}s)")


# --------------------------------------------------------------------------
# the Study planner
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class Study:
    """A planned ``Scenario x points x seeds`` grid, lowered onto one
    compiled step.  Build via :func:`study`; execute via :meth:`run`
    (typed results) or :meth:`run_states` (raw ``[P*S]`` states)."""

    scenario: Scenario
    points: tuple             # P normalized ((k, v), ...) points
    seeds: tuple              # S ints
    sim: engine.Sim           # built for the base config
    consts_b: state.Consts    # swept leaves carry a leading [P*S] axis
    axes: state.Consts        # matching vmap in_axes tree (0 / None)
    salts: tuple              # P*S ints, lane = p*S + s -> seeds[s]

    @property
    def n_points(self) -> int:
        return len(self.points)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    @property
    def n_lanes(self) -> int:
        return len(self.salts)

    def _max_ticks(self, max_ticks) -> int:
        return int(max_ticks if max_ticks is not None
                   else self.scenario.max_ticks)

    def lane_point_seed(self, lane: int) -> tuple:
        """``(point, seed)`` of one point-major lane index."""
        return self.points[lane // self.n_seeds], self.salts[lane]

    def _consts_subset(self, lanes: np.ndarray):
        """Batched Consts restricted to ``lanes`` (swept leaves row-
        gathered, deduped leaves untouched)."""
        leaves, treedef = jax.tree_util.tree_flatten(self.consts_b)
        sub = [jnp.take(x, jnp.asarray(lanes), axis=0) if a == 0 else x
               for x, a in zip(leaves, shard.axes_leaves(self.axes))]
        return jax.tree_util.tree_unflatten(treedef, sub)

    def _init_lanes(self, consts_sub, salts) -> state.SimState:
        """A tick-0 batch for an arbitrary lane subset: one vmapped
        ``init_state`` trace over the subset Consts, then the subset's
        seed salts.  Every leaf is a fresh buffer (donation-safe)."""
        dims = self.sim.dims
        states = jax.vmap(lambda c: state.init_state(dims, c),
                          in_axes=(self.axes,),
                          axis_size=len(salts))(consts_sub)
        return states._replace(salt=jnp.asarray(np.asarray(salts), I32))

    def init(self) -> state.SimState:
        """The full ``[P*S]`` tick-0 lane batch."""
        return self._init_lanes(self.consts_b, self.salts)

    def run_states(self, max_ticks: int | None = None, *,
                   mesh=None) -> state.SimState:
        """Run all lanes to completion; one step compile for the grid.
        The freshly built lane batch is donated to the run loop.  With
        ``mesh`` the batch shards across its devices (``shard.run_lanes``
        — bit-identical to the single-device path)."""
        mt = self._max_ticks(max_ticks)
        horizon_fn = self.sim.horizon_fn if self.sim.dims.leap else None
        return shard.run_lanes(self.sim.step_fn, horizon_fn, self.axes, mt,
                               self.sim.dims.superstep, self.consts_b,
                               self.init(), mesh=mesh)

    def _run_lane_subset(self, lanes, max_ticks: int,
                         mesh=None) -> state.SimState:
        """Run only ``lanes`` (absolute point-major indices) and return
        their ``[len(lanes)]`` final states.  Each lane's trajectory is
        batch-composition-independent (per-lane gating/leaping), so the
        result is bit-equal to the same lanes of a full-grid run."""
        lanes = np.asarray(lanes, np.int64)
        consts_sub = self._consts_subset(lanes)
        states = self._init_lanes(consts_sub, np.asarray(self.salts)[lanes])
        horizon_fn = self.sim.horizon_fn if self.sim.dims.leap else None
        return shard.run_lanes(self.sim.step_fn, horizon_fn, self.axes,
                               max_ticks, self.sim.dims.superstep,
                               consts_sub, states, mesh=mesh)

    def lane_keys(self, max_ticks: int | None = None) -> list:
        """Content address of every lane (``cache.lane_key``) — the
        scenario digest is computed once, the code digest per process."""
        mt = self._max_ticks(max_ticks)
        sd = cache_mod.scenario_digest(self.scenario, mt)
        cd = cache_mod.code_digest()
        return [cache_mod.lane_key(sd, *self.lane_point_seed(lane),
                                   code_dig=cd)
                for lane in range(self.n_lanes)]

    def _lane_result(self, lane_st, lane: int, max_ticks: int,
                     meta: dict) -> "RunResult":
        pt, seed = self.lane_point_seed(lane)
        return RunResult.from_state(
            self.sim, lane_st, scenario=self.scenario.name,
            point=pt, seed=seed, max_ticks=max_ticks, flow_meta=meta)

    def run(self, max_ticks: int | None = None, *, mesh=None,
            cache=None, chunk_lanes: int | None = None) -> StudyResult:
        """Execute the grid and pull typed per-lane results.

        ``mesh``         shard the lane batch across a device mesh
                         (``shard.lane_mesh()``; default single-device).
        ``cache``        reuse finished lanes by content address —
                         ``True`` (default dir), a path, or a
                         :class:`cache.ResultCache`; only missing lanes
                         are computed, and every computed lane is written
                         back.  Hit/miss counts land on the result.
        ``chunk_lanes``  run missing lanes at most this many at a time,
                         flushing each finished chunk to the cache — the
                         checkpoint granularity for resumable grids.
                         (Chunking alone, without a cache, just bounds
                         peak batch memory.)

        All three compose, and every combination is bit-equal to the
        plain single-device, uncached run (tests/test_shard.py,
        tests/test_cache.py)."""
        mt = self._max_ticks(max_ticks)
        rc = cache_mod.resolve(cache)
        t0 = time.time()
        if rc is None and chunk_lanes is None:
            states = self.run_states(mt, mesh=mesh)
            states.now.block_until_ready()
            # one bulk device->host transfer; lanes then slice numpy (the
            # per-lane RunResults would otherwise issue ~25 tiny
            # transfers per lane)
            states_h = jax.device_get(states)
            hits, misses = 0, self.n_lanes
        else:
            states_h, hits, misses = self._run_stitched(
                mt, mesh=mesh, rc=rc, chunk_lanes=chunk_lanes)
        wall = time.time() - t0
        meta = _flow_meta(self.sim)
        results = [self._lane_result(jax.tree.map(lambda x: x[lane],
                                                  states_h),
                                     lane, mt, meta)
                   for lane in range(self.n_lanes)]
        return StudyResult(scenario=self.scenario.name, points=self.points,
                           seeds=self.seeds, results=tuple(results),
                           states=states_h, wall_s=wall,
                           cache_hits=hits, cache_misses=misses)

    def _run_stitched(self, mt: int, *, mesh, rc, chunk_lanes):
        """Cached/chunked execution: look every lane up in the cache,
        run the misses in chunks (flushing each finished chunk back),
        and stitch hits + fresh lanes into one host-side ``[P*S]``
        batch.  Returns ``(states_h, hits, misses)``."""
        lane_struct = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            jax.eval_shape(self.init))
        lane_states = [None] * self.n_lanes
        keys = self.lane_keys(mt) if rc is not None else None
        if rc is not None:
            for lane, key in enumerate(keys):
                hit = rc.get(key, lane_struct)
                if hit is not None:
                    lane_states[lane] = hit[0]
        missing = [i for i in range(self.n_lanes) if lane_states[i] is None]
        hits = self.n_lanes - len(missing)
        meta = _flow_meta(self.sim)
        step = int(chunk_lanes) if chunk_lanes else max(len(missing), 1)
        cd = cache_mod.code_digest() if rc is not None else None
        for lo in range(0, len(missing), step):
            chunk = missing[lo:lo + step]
            out_h = jax.device_get(self._run_lane_subset(chunk, mt, mesh))
            for j, lane in enumerate(chunk):
                lane_st = jax.tree.map(lambda x: x[j], out_h)
                lane_states[lane] = lane_st
                if rc is not None:
                    res = self._lane_result(lane_st, lane, mt, meta)
                    rc.put(keys[lane], lane_st, res.row(),
                           extra=dict(code_digest=cd, name=res.name))
        states_h = jax.tree.map(lambda *xs: np.stack(xs), *lane_states)
        return states_h, hits, len(missing)

    def __repr__(self) -> str:
        return (f"Study({self.scenario.name}: {self.n_points} points x "
                f"{self.n_seeds} seeds = {self.n_lanes} lanes)")


def _resolve(sc) -> Scenario:
    return scenarios.scenario(sc) if isinstance(sc, str) else sc


def study(sc, points=None, seeds=(0,), **scenario_overrides) -> Study:
    """Plan a ``Scenario x points x seeds`` grid as one compiled step.

    ``sc`` is a :class:`Scenario` or a registered scenario name;
    ``points`` a sequence of sweep-point mappings (numeric ``SimConfig``
    fields and CC tuning kwargs — see ``CFG_KEYS`` / ``CC_PARAM_KEYS``;
    ``None`` or ``[{}]`` = just the base config); ``seeds`` the per-lane
    salt seeds.  Anything per-point that would change ``Dims`` raises at
    plan time (``KeyError``)."""
    sc = _resolve(sc)
    if scenario_overrides:
        sc = sc.with_(**scenario_overrides)
    pts = (tuple(_norm_point(p) for p in points)
           if points is not None else ((),))
    if not pts:
        raise ValueError("empty sweep")
    seeds = tuple(int(s) for s in seeds)
    if not seeds:
        raise ValueError("empty seeds")
    # engine.build -> state.derive validates the workload up front
    sim = engine.build(sc.cfg, sc.wl)
    # derive() is re-run per point: that repeats the O(NF) structural host
    # loops, but keeps a single source of truth for Consts derivation.
    # Host-side cost is negligible next to the device run; identical
    # leaves are deduplicated in _stack_consts.
    consts_list = [sim.consts if not pt
                   else state.derive(apply_point(sc.cfg, dict(pt)), sc.wl)[3]
                   for pt in pts]
    consts_b, axes = _stack_consts(consts_list, repeats=len(seeds))
    salts = tuple(np.tile(np.asarray(seeds, np.int64), len(pts)).tolist())
    return Study(scenario=sc, points=pts, seeds=seeds, sim=sim,
                 consts_b=consts_b, axes=axes, salts=salts)


def run(sc, *, seed: int = 0, max_ticks: int | None = None,
        **scenario_overrides) -> RunResult:
    """Run one scenario standalone (unbatched ``Sim.run``) -> RunResult.

    ``sc`` is a :class:`Scenario` or a registered name; ``overrides`` are
    forwarded to :meth:`Scenario.with_` (``algo=``, ``lb=``, ...)."""
    sc = _resolve(sc)
    if scenario_overrides:
        sc = sc.with_(**scenario_overrides)
    mt = int(max_ticks if max_ticks is not None else sc.max_ticks)
    sim = engine.build(sc.cfg, sc.wl)   # derive validates the workload
    t0 = time.time()
    st = sim.run(max_ticks=mt, seed=seed)
    st.now.block_until_ready()
    wall = time.time() - t0
    return RunResult.from_state(sim, jax.device_get(st), scenario=sc.name,
                                seed=seed, max_ticks=mt, wall_s=wall)
