"""Typed simulator state, configuration, and build-time derivation.

This module owns every container the phase pipeline operates on:

  ``SimConfig``  user-facing knobs (dataclass; static + numeric mixed)
  ``Dims``       static shape/branch facts (Python ints/bools — hashable,
                 safe to close over in jitted code; changing any retraces)
  ``Consts``     *traced* numeric constants (a jax pytree — changing any
                 value, e.g. a CC parameter or the RED thresholds, reuses
                 the compiled step; ``netsim/sweep.py`` vmaps over a batch
                 of these for one-compile parameter sweeps)
  ``SimState``   the per-tick mutable world

``derive(cfg, wl)`` maps a config+workload onto (topology, timing, Dims,
Consts); ``init_state(dims, consts)`` produces the tick-0 world.  The six
tick phases in ``fabric``/``transport``/``sender``/``metrics`` are pure
functions ``(Dims, Consts, SimState) -> SimState`` composed by
``engine.build``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.analysis import counter as _trace_counter
from repro.core import registry, reps
from repro.core.types import CCParams, CCState, init_cc_state, make_cc_params
from repro.netsim import faults as faults_schedule
from repro.netsim.metrics import Metrics, init_metrics
from repro.netsim.topology import build_topology
from repro.netsim.units import (FatTreeConfig, LinkConfig,
                                derive_timing, gamma)
from repro.netsim.workloads import Workload

I32 = jnp.int32
F32 = jnp.float32


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimConfig:
    link: LinkConfig = LinkConfig()
    tree: FatTreeConfig = FatTreeConfig()
    algo: str = "smartt"
    cc_backend: str = "jnp"          # "jnp" | "pallas" (kernels/cc_update)
    fabric_backend: str = "jnp"      # "jnp" | "pallas" — enqueue-rank +
                                     # send/grant arbitration
                                     # (kernels/enqueue_arb)
    transport_backend: str = "jnp"   # "jnp" | "pallas" — sent-ring
                                     # ACK/trim/timeout drain
                                     # (kernels/ring_drain)
    lb: str = "reps"
    superstep: int = 0               # ticks fused per run-loop iteration;
                                     # 0 = auto (one base RTT), 1 = legacy
    leap: bool = True                # event-horizon time leaping: skip
                                     # quiescent ticks in closed form
                                     # (DESIGN.md Sec. 6.3; auto-disabled
                                     # for paced CC and PLB, whose state
                                     # ages on event-free ticks)
    trimming: bool = True
    rto_mult: float = 0.0            # RTO = rto_mult * trtt; 0 = auto
                                     # (3.0 with trimming, 2.0 aggressive without)
    num_entropies: int = 256
    react_every: int = 1             # CC reaction granularity (Fig. 3b)
    credit_window_mult: float = 1.0  # EQDS outstanding-credit window (BDPs)
    start_cwnd_mult: float = 1.25    # initial window as fraction of BDP
    kmin_frac: float = 0.2           # RED thresholds as fraction of port buffer
    kmax_frac: float = 0.8
    # fault injection (Fig. 7): a faults.FaultSchedule (timeline of
    # fail/degrade/repair events plus periodic flapping), or the legacy
    # static tuples ((rack, uplink, period), ...) / ((kind, i, j, period),
    # ...) which lower to one-event schedules — period 2 = half-rate link,
    # period 0 = dead link (blackholes traffic).  Schedule times are
    # relative to fault_start, which stays a sweepable scalar.
    faults: tuple = ()
    fault_start: int = 0
    rto_backoff_max: int = 0         # capped exponential RTO backoff:
                                     # RTO * 2^min(consecutive timeouts,
                                     # cap); 0 = off (legacy fixed RTO)
    evict_on_timeout: bool = False   # REPS: evict the cached entropy on
                                     # timeout so retransmits explore
                                     # fresh paths around a failure
    goodput_bin: int = 0             # recovery-metric goodput histogram
                                     # bin width (ticks); 0 = auto (8 brtt)
    cc_overrides: tuple = ()         # (("fd", 0.5), ...) applied to CCParams


# --------------------------------------------------------------------------
# static dimensions / branch selectors
# --------------------------------------------------------------------------


class Dims(NamedTuple):
    """Shape- and branch-determining facts.  All plain Python scalars:
    hashable, compared by value, safe as closed-over constants under jit."""

    N: int          # nodes
    NQ: int         # queues (output ports)
    NE: int         # emitters (queues + sender NICs)
    NF: int         # flows
    CAP: int        # per-port queue capacity (packets)
    W: int          # sent-ring slots per flow
    WW: int         # W // 32 loss-bitmap words
    L: int          # wire-latency ring length
    R: int          # control-return ring length
    MAXW: int       # receiver dedupe bitmap words
    FMAX: int       # max flows per sender
    FRMAX: int      # max flows per receiver
    P: int          # racks
    U: int          # T0 uplinks per rack (spines / aggs-per-pod)
    M: int          # nodes per rack
    QE: int         # edge-port base: queues [QE, NQ) are the t0_down ports
    tiers: int      # 2 or 3 (FatTreeConfig.tiers)
    window: int     # windowed-alltoall eligibility window
    D: int          # dependency-table width (0 = no table: the legacy
                    # t_start-only activation graph, bit-for-bit)
    mtu: int        # bytes
    brtt_inter: int  # base RTT ticks == BDP packets
    bdp_bytes: float
    superstep: int  # ticks per fused run-loop iteration (>= 1)
    leap: bool      # event-horizon time leaping enabled (and exact: the
                    # CC/LB choice mutates no state on event-free ticks)
    trimming: bool
    credit_based: bool
    paced: bool
    lb_mode: int
    FK: int         # fault transition-table columns (0 = no timeline)
    flapped: bool   # any flapping fault window in the schedule
    rto_backoff_max: int  # RTO backoff exponent cap (0 = backoff off)
    evict: bool     # REPS entropy eviction on timeout


# --------------------------------------------------------------------------
# traced constants
# --------------------------------------------------------------------------


class Consts(NamedTuple):
    """Numeric constants the compiled step closes over *as traced values*.

    Everything here may vary between runs of the same compiled step —
    that is what makes the batched config sweep one compilation.
    """

    src: jnp.ndarray             # i32 [NF]
    dst: jnp.ndarray             # i32 [NF]
    size: jnp.ndarray            # i32 [NF] flow bytes
    t_start: jnp.ndarray         # i32 [NF]
    dep_par: jnp.ndarray         # i32 [NF, D] parent flow id (NF = unused
                                 #   slot; D = 0 without a dependency table)
    dep_thr: jnp.ndarray         # i32 [NF, D] parent bytes that must have
                                 #   landed before this flow activates
    ret: jnp.ndarray             # i32 scalar ACK/grant return latency (the
                                 #   ack ring layout requires it constant)
    flows_of: jnp.ndarray        # i32 [N, FMAX] per-sender flow table
    slot_of: jnp.ndarray         # i32 [NF] flow's column in flows_of[src]
    flows_by_recv: jnp.ndarray   # i32 [N, FRMAX]
    lat_q: jnp.ndarray           # i32 [NE] post-departure wire latency
    # -- compiled fault schedule (faults.compile_tables; times relative to
    #    fault_start so the legacy knob stays a sweepable scalar) --
    ft_time: jnp.ndarray         # i32 [NQ, max(FK, 1)] transition times
    ft_period: jnp.ndarray       # i32 [NQ, max(FK, 1)] service periods
    fl_start: jnp.ndarray        # i32 [NQ] flap window start
    fl_end: jnp.ndarray          # i32 [NQ] flap window end (INF = open)
    fl_cycle: jnp.ndarray        # i32 [NQ] flap cycle length (0 = none)
    fl_up: jnp.ndarray           # i32 [NQ] healthy ticks per cycle
    fl_period: jnp.ndarray       # i32 [NQ] period while flapped down
    fault_start: jnp.ndarray     # i32 scalar
    goodput_bin: jnp.ndarray     # i32 scalar goodput histogram bin width
    trim_delay: jnp.ndarray      # i32 scalar
    kmin: jnp.ndarray            # f32 scalar RED lower threshold (packets)
    kspan: jnp.ndarray           # f32 scalar RED kmax - kmin
    rto: jnp.ndarray             # f32 [NF]
    credit_window: jnp.ndarray   # f32 scalar (EQDS)
    start_cwnd: jnp.ndarray      # f32 scalar initial cwnd bytes
    cc: CCParams
    lb: reps.LBParams
    # -- per-tick invariants hoisted out of the phase bodies (the phases
    #    would otherwise re-materialize these iotas/gathers every tick) --
    qidx: jnp.ndarray            # i32 [NQ] port iota
    eidx: jnp.ndarray            # i32 [NE] emitter iota
    flow_ids: jnp.ndarray        # i32 [NF] flow iota
    node_ids: jnp.ndarray        # i32 [N] node iota
    # -- table-driven routing (topology.build_topology; fabric.route_switch
    #    gathers through these — tier-generic, no dense tables) --
    nbr_q: jnp.ndarray           # i32 [NQ] switch each port's wire feeds
                                 #   (edge rows clamped to 0; edge_q gates)
    edge_q: jnp.ndarray          # bool [NQ] port delivers to a host NIC
    sw_lo: jnp.ndarray           # i32 [NSW] switch subtree interval [lo, hi)
    sw_hi: jnp.ndarray           # i32 [NSW]
    sw_up_base: jnp.ndarray      # i32 [NSW] first equal-cost up port
    sw_up_cnt: jnp.ndarray       # i32 [NSW] up-port count (0 at top tier)
    sw_salt: jnp.ndarray         # u32 [NSW] per-switch ECMP hash salt
    dn_base: jnp.ndarray         # i32 [NSW] down port = dn_base + d // dn_stride
    dn_stride: jnp.ndarray       # i32 [NSW] nodes covered per down port
    sw_of_q: jnp.ndarray         # i32 [NQ] switch owning each queue
    # -- per-queue routing tables: the switch tables above, pre-gathered
    #    through ``nbr_q`` at derive time so ``fabric.route_from_queue``
    #    (the departures hot path) reads [NQ] vectors directly instead of
    #    issuing seven [NSW] -> [NQ] gathers per tick --
    q_lo: jnp.ndarray            # i32 [NQ] = sw_lo[nbr_q]
    q_hi: jnp.ndarray            # i32 [NQ] = sw_hi[nbr_q]
    q_up_base: jnp.ndarray       # i32 [NQ] = sw_up_base[nbr_q]
    q_up_cnt: jnp.ndarray        # i32 [NQ] = sw_up_cnt[nbr_q]
    q_salt: jnp.ndarray          # u32 [NQ] = sw_salt[nbr_q]
    q_dn_base: jnp.ndarray       # i32 [NQ] = dn_base[nbr_q]
    q_dn_stride: jnp.ndarray     # i32 [NQ] = dn_stride[nbr_q]
    # -- per-flow first-hop tables: a fresh packet's routing decision at
    #    the sender's rack switch is static per flow except for the ECMP
    #    entropy hash, so ``fabric.route_from_sender`` reduces to a select
    #    between a precomputed down queue and a hashed up port — zero
    #    gathers in the sends hot path --
    f_down: jnp.ndarray          # bool [NF] dst inside the sender's rack
    f_dn_q: jnp.ndarray          # i32 [NF] the (static) same-rack edge queue
    f_up_base: jnp.ndarray       # i32 [NF] rack switch's first up port
    f_up_cnt: jnp.ndarray        # i32 [NF] rack switch's up-port count
    f_salt: jnp.ndarray          # u32 [NF] rack switch's ECMP salt
    # -- compact enqueue emitters + per-switch fan-in groups (enqueue
    #    ranking and per-queue accept counts, kernels/enqueue_arb) --
    enq_ids: jnp.ndarray         # i32 [EQ] enqueue-capable emitter ids
    in_tbl: jnp.ndarray          # i32 [NSW, DMAX] compact emitter indices
                                 #   feeding each switch, ascending, pad EQ
    in_pos: jnp.ndarray          # i32 [EQ] compact emitter's flat slot in
                                 #   in_tbl
    lat_core: jnp.ndarray        # i32 scalar switch-facing-port wire latency
    lat_edge: jnp.ndarray        # i32 scalar t0_down wire latency
    lat_send: jnp.ndarray        # i32 scalar sender-NIC wire latency
    # -- next-event horizon invariants (DESIGN.md Sec. 6.3): slot iotas of
    #    the wire and control rings, hoisted for the leap reductions --
    iota_l: jnp.ndarray          # i32 [L] wire-ring slot iota
    iota_r: jnp.ndarray          # i32 [R] control-ring slot iota


def pkt_size(dims: Dims, consts: Consts, flow, seq):
    """True wire size of packet `seq` of `flow` (last packet may be short)."""
    rem = consts.size[jnp.clip(flow, 0, dims.NF - 1)] - seq * dims.mtu
    return jnp.clip(rem, 0, dims.mtu)


# --------------------------------------------------------------------------
# state
# --------------------------------------------------------------------------


class SimState(NamedTuple):
    now: jnp.ndarray                 # i32 scalar
    salt: jnp.ndarray                # i32 scalar — per-run hash decorrelation
    q_fields: jnp.ndarray            # i32 [NQ+1, CAP, 5] flow/seq/ent/ecn/ts
    q_head: jnp.ndarray              # i32 [NQ+1]
    q_size: jnp.ndarray              # i32 [NQ+1]
    infl: jnp.ndarray                # i32 [L, NE, 7] valid/dstq/flow/seq/ent/ecn/ts
    ack_ring: jnp.ndarray            # i32 [R, N, 6] valid/flow/seq/ecn/ent/ts
                                     #   (slot (t+ret)%R written whole per tick:
                                     #   ret is receiver-constant, so the write
                                     #   is a dynamic-update-slice, not scatter)
    trim_ring: jnp.ndarray           # i32 [R, NF+1, 2+WW] cnt/bytes/loss-bitmap
                                     #   (packed: one scatter per tick feeds the
                                     #   delayed trim count, bytes, and per-slot
                                     #   loss words; bytes are exact in i32)
    credit_ring: jnp.ndarray         # f32 [R, NF+1]
    sent: jnp.ndarray                # i32 [3, NF+1, W] component-major sent ring:
                                     #   [0]=state (0=free 1=outstanding 3=lost)
                                     #   [1]=seq  [2]=send tick
    next_seq: jnp.ndarray            # i32 [NF]
    unacked: jnp.ndarray             # f32 [NF] in-flight bytes (phase 3 -> 5)
    done: jnp.ndarray                # bool [NF]
    fct: jnp.ndarray                 # i32 [NF] (-1 = unfinished)
    goodput: jnp.ndarray             # i32 [NF] unique bytes delivered
    bitmap: jnp.ndarray              # i32 [NF+1, MAXW] receiver dedupe
    granted: jnp.ndarray             # f32 [NF] EQDS credit issued
    trim_seen: jnp.ndarray           # f32 [NF+1] trimmed bytes observed by the
                                     #   receiver (row NF is scatter write-off;
                                     #   only maintained for credit-based algos)
    rr_recv: jnp.ndarray             # i32 [N]
    rr_send: jnp.ndarray             # i32 [N]
    pace_accum: jnp.ndarray          # f32 [NF]
    rto_backoff: jnp.ndarray         # i32 [NF] consecutive-timeout count
                                     #   (drives capped exponential RTO
                                     #   backoff; 0 unless Dims enables it)
    cc: CCState
    lb: reps.LBState
    m: Metrics


# --------------------------------------------------------------------------
# derivation
# --------------------------------------------------------------------------


def derive(cfg: SimConfig, wl: Workload):
    """Map (config, workload) -> (Topology, Timing, Dims, Consts)."""
    link, tree = cfg.link, cfg.tree
    topo = build_topology(tree)
    tm = derive_timing(link, tree)

    N, NQ, NE = tree.n_nodes, topo.n_queues, topo.n_emitters
    NF = wl.n_flows
    wl.validate(n_nodes=N)   # reject bad tables before any shape math
    MTU = float(link.mtu_bytes)
    CAP = int(tm.brtt_inter)                      # 1 BDP per port queue
    max_pkts = int(np.ceil(wl.size.max() / MTU))
    # sent-ring slots: 1.5x the max window in packets (seq-range headroom;
    # new sends block on occupied slots, modeling a bounded retx buffer) —
    # but never wider than the workload's own seq space: once W >= max_pkts
    # the slot map seq % W is injective for every flow, so any larger ring
    # is trajectory-identical dead weight, and all the [NF, W] transport
    # passes (ring drain, timeout scans, emission writes) pay for it.
    W = int(2 ** np.ceil(np.log2(max(1.5 * 1.25 * tm.brtt_inter, 32))))
    W = min(W, int(2 ** np.ceil(np.log2(max(max_pkts, 32)))))
    WW = W // 32
    L = tm.hop + 2
    R = int(max(tm.ret_inter, tm.trim_delay) + tm.hop + 4)
    MAXW = (max_pkts + 31) // 32
    P, U, M = tree.racks, tree.uplinks, tree.nodes_per_rack
    QE = NQ - N                                   # edge-port block base

    # ---- per-flow constants ----
    # ACK return delay is *globally constant*: the ack ring is indexed
    # (arrival_tick + ret, receiver) and a receiver delivers one packet per
    # tick, so slot (t + ret) % R belongs exclusively to the deliveries of
    # tick t — which lets `fabric.arrivals` write the whole [N]-row slot as
    # one dynamic-update-slice instead of a scatter.
    # Per-flow base RTT: hop-count-specific forward latency (same rack /
    # cross-rack within a pod, which IS the longest path on two-tier trees
    # / cross-core) plus the constant ACK return delay.
    sr, dr = wl.src // M, wl.dst // M
    Pg = tree.racks_per_pod
    fwd_f = np.where(sr == dr, tm.fwd_intra,
                     np.where(sr // Pg == dr // Pg, tm.fwd_pod,
                              tm.fwd_inter))
    brtt_f = (fwd_f + tm.ret_inter).astype(np.float32)
    ret_f = jnp.asarray(tm.ret_inter, I32)

    bdp = float(tm.brtt_inter * MTU)
    cc_kwargs = dict(cfg.cc_overrides)
    cc_params = make_cc_params(
        mtu=MTU, bdp=bdp, brtt=brtt_f,
        react_every=cfg.react_every,
        gamma=gamma(link, tm),
        use_trimming=cfg.trimming,
        **cc_kwargs,
    )
    lb_params = reps.make_lb_params(
        num_entropies=cfg.num_entropies,
        bdp_pkts=int(tm.brtt_inter),
    )
    rto_mult = cfg.rto_mult or (3.0 if cfg.trimming else 2.0)
    rto_f = jnp.asarray(rto_mult, F32) * cc_params.trtt
    credit_window = jnp.asarray(cfg.credit_window_mult * bdp, F32)

    # ---- per-sender / per-receiver flow matrices ----
    FMAX = max(int(np.max(np.bincount(wl.src, minlength=N))), 1)
    FRMAX = max(int(np.max(np.bincount(wl.dst, minlength=N))), 1)
    flows_of = np.full((N, FMAX), NF, np.int32)
    slot_of = np.zeros(NF, np.int32)               # inverse of flows_of
    cnt = np.zeros(N, np.int64)
    for f in np.argsort(wl.order, kind="stable"):  # per-sender, ordered
        s = wl.src[f]
        flows_of[s, cnt[s]] = f
        slot_of[f] = cnt[s]
        cnt[s] += 1
    flows_by_recv = np.full((N, FRMAX), NF, np.int32)
    cnt = np.zeros(N, np.int64)
    for f in range(NF):
        r = wl.dst[f]
        flows_by_recv[r, cnt[r]] = f
        cnt[r] += 1
    window = int(min(wl.window, FMAX))

    # ---- dependency table (collectives, DESIGN.md Sec. 11) ----
    # Dense [NF, D] parent ids + byte thresholds; the workload's -1 free
    # slots normalize to the NF sentinel (same write-off convention as
    # flows_of).  D == 0 keeps sender.activated on the legacy t_start-only
    # path — structurally the same traced graph as before the table existed.
    D = wl.n_deps
    if D:
        dep_par = np.asarray(wl.dep_par, np.int64).copy()
        dep_par[dep_par < 0] = NF
        dep_thr = np.asarray(wl.dep_thr, np.int64).copy()
        dep_thr[dep_par == NF] = 0          # free slots trivially satisfied
    else:
        dep_par = np.zeros((NF, 0), np.int64)
        dep_thr = np.zeros((NF, 0), np.int64)

    # ---- per-emitter wire latency ----
    # fabric.departures / sender.sends rely on the latency being uniform
    # within each of the three contiguous emitter classes (switch-facing
    # ports at any tier, edge ports, sender NICs) and strictly below the
    # ring length L.
    lat_q = np.zeros(NE, np.int32)
    lat_q[:QE] = link.link_lat_ticks + link.switch_lat_ticks
    lat_q[QE:NQ] = link.link_lat_ticks
    lat_q[NQ:] = 1 + link.link_lat_ticks + link.switch_lat_ticks
    for cls in (lat_q[:QE], lat_q[QE:NQ], lat_q[NQ:]):
        if not (np.all(cls == cls[0]) and 0 < cls[0] < L):
            raise ValueError(
                f"wire latency must be uniform within each emitter class "
                f"(switch-facing/edge/sender) and satisfy 0 < lat < L={L}; "
                f"got {sorted(set(lat_q.tolist()))}")

    # ---- fault schedule compilation (faults.py) ----
    # Legacy static tuples lower to one-event schedules; a FaultSchedule
    # passes through.  compile_tables validates every entry (kind, ranges,
    # signs) with actionable errors naming the offending tuple, and emits
    # the per-port transition tables the fabric evaluates each tick.
    sched = faults_schedule.lower(cfg.faults)
    cf = faults_schedule.compile_tables(sched, topo, cfg.fault_start)
    if cfg.rto_backoff_max < 0:
        raise ValueError(
            f"rto_backoff_max must be >= 0, got {cfg.rto_backoff_max}")
    if cfg.goodput_bin < 0:
        raise ValueError(f"goodput_bin must be >= 0, got {cfg.goodput_bin}")
    goodput_bin = int(cfg.goodput_bin) or 8 * int(tm.brtt_inter)
    if not cfg.kmax_frac > cfg.kmin_frac:
        raise ValueError(
            f"RED thresholds need kmax_frac > kmin_frac, got "
            f"{cfg.kmin_frac} .. {cfg.kmax_frac}")
    kmin = cfg.kmin_frac * CAP
    kmax = cfg.kmax_frac * CAP

    if cfg.superstep < 0:
        raise ValueError(f"superstep must be >= 0, got {cfg.superstep}")
    superstep = int(cfg.superstep) or int(tm.brtt_inter)

    # ---- pre-gathered routing tables (per-tick gather hoisting) ----
    # Per-queue: the seven switch tables route_from_queue needs, indexed
    # through nbr_q once here instead of every tick (edge rows clamp to
    # switch 0 exactly like nbr_q itself; edge_q gates them off).
    # Per-flow: a fresh packet's first hop is decided at the sender's rack
    # switch sw_f = src // M; the subtree test and the down queue are
    # workload constants, only the up-port ECMP hash needs the entropy.
    nbr = np.maximum(np.asarray(topo.nbr_sw[:NQ]), 0)
    sw_f = np.asarray(wl.src, np.int64) // M
    f_lo = np.asarray(topo.sw_lo)[sw_f]
    f_hi = np.asarray(topo.sw_hi)[sw_f]
    f_down = (wl.dst >= f_lo) & (wl.dst < f_hi)
    f_dn_q = (np.asarray(topo.dn_base)[sw_f]
              + np.asarray(wl.dst) // np.asarray(topo.dn_stride)[sw_f])

    # Event-horizon time leaping (DESIGN.md Sec. 6.3) is only exact when an
    # event-free tick is a state no-op.  Rate pacing accrues a budget every
    # tick and PLB rolls its round clock on wall time, so those two
    # configurations run leap-free regardless of the knob.
    paced = cfg.algo in registry.PACED
    leap = bool(cfg.leap) and not paced and cfg.lb != "plb"

    dims = Dims(
        N=N, NQ=NQ, NE=NE, NF=NF, CAP=CAP, W=W, WW=WW, L=L, R=R,
        MAXW=MAXW, FMAX=FMAX, FRMAX=FRMAX, P=P, U=U, M=M, QE=QE,
        tiers=tree.tiers,
        window=window, D=D, mtu=int(MTU), brtt_inter=int(tm.brtt_inter),
        bdp_bytes=bdp, superstep=superstep, leap=leap,
        trimming=cfg.trimming,
        credit_based=cfg.algo in registry.CREDIT_BASED,
        paced=paced,
        lb_mode=reps.LB_NAMES[cfg.lb],
        FK=cf.FK, flapped=cf.flapped,
        rto_backoff_max=int(cfg.rto_backoff_max),
        evict=bool(cfg.evict_on_timeout),
    )
    consts = Consts(
        src=jnp.asarray(wl.src, I32),
        dst=jnp.asarray(wl.dst, I32),
        size=jnp.asarray(wl.size, I32),
        t_start=jnp.asarray(wl.t_start, I32),
        dep_par=jnp.asarray(dep_par, I32),
        dep_thr=jnp.asarray(dep_thr, I32),
        ret=ret_f,
        flows_of=jnp.asarray(flows_of),
        slot_of=jnp.asarray(slot_of),
        flows_by_recv=jnp.asarray(flows_by_recv),
        lat_q=jnp.asarray(lat_q),
        ft_time=jnp.asarray(cf.ft_time),
        ft_period=jnp.asarray(cf.ft_period),
        fl_start=jnp.asarray(cf.fl_start),
        fl_end=jnp.asarray(cf.fl_end),
        fl_cycle=jnp.asarray(cf.fl_cycle),
        fl_up=jnp.asarray(cf.fl_up),
        fl_period=jnp.asarray(cf.fl_period),
        fault_start=jnp.asarray(cfg.fault_start, I32),
        goodput_bin=jnp.asarray(goodput_bin, I32),
        trim_delay=jnp.asarray(tm.trim_delay, I32),
        kmin=jnp.asarray(kmin, F32),
        kspan=jnp.asarray(kmax - kmin, F32),
        rto=rto_f,
        credit_window=credit_window,
        start_cwnd=jnp.asarray(cfg.start_cwnd_mult * bdp, F32),
        cc=cc_params,
        lb=lb_params,
        qidx=jnp.arange(NQ, dtype=I32),
        eidx=jnp.arange(NE, dtype=I32),
        flow_ids=jnp.arange(NF, dtype=I32),
        node_ids=jnp.arange(N, dtype=I32),
        nbr_q=jnp.asarray(np.maximum(topo.nbr_sw[:NQ], 0), I32),
        edge_q=jnp.asarray(topo.nbr_sw[:NQ] < 0),
        sw_lo=jnp.asarray(topo.sw_lo, I32),
        sw_hi=jnp.asarray(topo.sw_hi, I32),
        sw_up_base=jnp.asarray(topo.sw_up_base, I32),
        sw_up_cnt=jnp.asarray(topo.sw_up_cnt, I32),
        sw_salt=jnp.asarray(topo.sw_salt, jnp.uint32),
        dn_base=jnp.asarray(topo.dn_base, I32),
        dn_stride=jnp.asarray(topo.dn_stride, I32),
        sw_of_q=jnp.asarray(topo.sw_of_q, I32),
        q_lo=jnp.asarray(np.asarray(topo.sw_lo)[nbr], I32),
        q_hi=jnp.asarray(np.asarray(topo.sw_hi)[nbr], I32),
        q_up_base=jnp.asarray(np.asarray(topo.sw_up_base)[nbr], I32),
        q_up_cnt=jnp.asarray(np.asarray(topo.sw_up_cnt)[nbr], I32),
        q_salt=jnp.asarray(np.asarray(topo.sw_salt)[nbr], jnp.uint32),
        q_dn_base=jnp.asarray(np.asarray(topo.dn_base)[nbr], I32),
        q_dn_stride=jnp.asarray(np.asarray(topo.dn_stride)[nbr], I32),
        f_down=jnp.asarray(f_down),
        f_dn_q=jnp.asarray(f_dn_q, I32),
        f_up_base=jnp.asarray(np.asarray(topo.sw_up_base)[sw_f], I32),
        f_up_cnt=jnp.asarray(np.asarray(topo.sw_up_cnt)[sw_f], I32),
        f_salt=jnp.asarray(np.asarray(topo.sw_salt)[sw_f], jnp.uint32),
        enq_ids=jnp.asarray(topo.enq_ids, I32),
        in_tbl=jnp.asarray(topo.in_tbl, I32),
        in_pos=jnp.asarray(topo.in_pos, I32),
        lat_core=jnp.asarray(lat_q[0], I32),
        lat_edge=jnp.asarray(lat_q[QE], I32),
        lat_send=jnp.asarray(lat_q[NQ], I32),
        iota_l=jnp.arange(L, dtype=I32),
        iota_r=jnp.arange(R, dtype=I32),
    )
    return topo, tm, dims, consts


# Counted each time ``init_state`` runs (eagerly or as a trace).
# ``tests/test_engine_leap.py`` asserts ``Sim.run_batch`` builds exactly one
# init state and broadcasts it, rather than re-deriving it per seed:
# ``with trace_guard("state.init", expect=1): ...`` (repro.analysis).
_INIT_TRACES = _trace_counter("state.init")

# Sentinel "no event in sight" horizon (i32-safe; run loops clamp it to the
# remaining tick budget before applying a leap).
HORIZON_INF = 1 << 30


def init_state(dims: Dims, consts: Consts) -> SimState:
    """Tick-0 world.  Pure in (dims, consts); safe under jit and vmap."""
    _INIT_TRACES.hit()
    zeros = jnp.zeros
    NF, N, NQ = dims.NF, dims.N, dims.NQ
    cc = init_cc_state(NF, consts.cc, start_cwnd=consts.start_cwnd)
    lb = reps.init_lb_state(NF, consts.lb)
    return SimState(
        now=zeros((), I32),
        salt=zeros((), I32),
        q_fields=zeros((NQ + 1, dims.CAP, 5), I32),
        q_head=zeros((NQ + 1,), I32),
        q_size=zeros((NQ + 1,), I32),
        infl=zeros((dims.L, dims.NE, 7), I32),
        ack_ring=zeros((dims.R, N, 6), I32),
        trim_ring=zeros((dims.R, NF + 1, 2 + dims.WW), I32),
        credit_ring=zeros((dims.R, NF + 1), F32),
        sent=zeros((3, NF + 1, dims.W), I32),
        next_seq=zeros((NF,), I32),
        unacked=zeros((NF,), F32),
        done=zeros((NF,), bool),
        fct=jnp.full((NF,), -1, I32),
        goodput=zeros((NF,), I32),
        bitmap=zeros((NF + 1, dims.MAXW), I32),
        granted=zeros((NF,), F32),
        trim_seen=zeros((NF + 1,), F32),
        rr_recv=zeros((N,), I32),
        rr_send=zeros((N,), I32),
        pace_accum=zeros((NF,), F32),
        rto_backoff=zeros((NF,), I32),
        cc=cc, lb=lb, m=init_metrics(),
    )
