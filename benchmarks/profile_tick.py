"""Per-phase tick profiler: where does a tick's wall time go as N grows?

Times each of the six tick phases (and the composed tick, and the leap
horizon reduction) in isolation under jit on permutation scenarios at
N ∈ {32, 128, 512, 1024}, by running R phase applications inside one
``lax.fori_loop`` (so per-call dispatch amortizes away and XLA cannot
dead-code the phase).  JAX op cost is shape-dependent, not
data-dependent, so timing a self-composed phase on a mid-run state is
representative of the phase inside the real tick.

This is the measurement that ranks phases for kernelization (DESIGN.md
Sec. 6.4) and later audits that the kernel choices still match the
profile.  Two sections land in BENCH_netsim.json:

- ``phase_profile``: one row per (scenario, phase) with us/tick and the
  phase's share of the composed tick.
- ``roofline``: per scenario, the resident SimState footprint, a
  measured STREAM-triad bandwidth, and the implied memory-bound
  ticks/sec ceiling next to the measured composed-tick rate — how far
  the tick is from "every state byte touched twice at stream speed"
  (methodology: DESIGN.md Sec. 6.4).

Usage:
  PYTHONPATH=src python -m benchmarks.profile_tick [--quick]
      [--ns 32,128,512,1024] [--reps N] [--json-path PATH]
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from benchmarks.common import BENCH_JSON, LINK, TREE_FLAT, emit, \
    write_bench_json
from repro.netsim import workloads
from repro.netsim.scenarios import Scenario, scenario
from repro.netsim.state import SimConfig

KiB = 1024


def _perm32():
    wl = workloads.permutation(TREE_FLAT, size_bytes=256 * KiB, seed=7)
    return Scenario(name="perm_32n_flat",
                    cfg=SimConfig(link=LINK, tree=TREE_FLAT), wl=wl,
                    max_ticks=60_000)


# N -> scenario factory; 128/512/1024 are the three-tier ledger scenarios
SCENARIOS = {
    32: _perm32,
    128: lambda: scenario("perm_128n_3t"),
    512: lambda: scenario("perm_512n_3t"),
    1024: lambda: scenario("perm_1024n_3t"),
}


def _phases(sim):
    """The six tick phases with this sim's resolved backends and consts
    bound — read straight off ``sim.phases`` (the exact closures
    ``engine.build`` composes into the step), so the profile can never
    drift from the real tick composition."""
    consts = sim.consts
    return {name: functools.partial(fn, consts) for name, fn in sim.phases}


@functools.partial(jax.jit, static_argnums=(0, 2))
def _loop(fn, st, iters):
    return jax.lax.fori_loop(0, iters, lambda _, s: fn(s), st)


def _time_phase(fn, st, iters, reps):
    """Best-of wall seconds per application of ``fn`` (R applications
    fused in one fori_loop per timed call)."""
    _loop(fn, st, iters).now.block_until_ready()     # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        _loop(fn, st, iters).now.block_until_ready()
        best = min(best, time.time() - t0)
    return best / iters


def _state_bytes(st) -> int:
    return int(sum(jnp.asarray(leaf).nbytes for leaf in jax.tree.leaves(st)))


def stream_gbps(reps: int = 3, mb: int = 256) -> float:
    """Measured STREAM-triad bandwidth (GB/s): a = b + s*c over arrays
    sized far beyond LLC, 3 streams of traffic per element."""
    n = mb * 1024 * 1024 // 4
    b = jnp.ones((n,), jnp.float32)
    c = jnp.ones((n,), jnp.float32)
    triad = jax.jit(lambda b, c: b + 1.5 * c)
    triad(b, c).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        triad(b, c).block_until_ready()
        best = min(best, time.time() - t0)
    return 3 * n * 4 / best / 1e9


def profile_scenario(n: int, reps: int):
    """Profile one scenario: per-phase rows + a roofline row."""
    sc = SCENARIOS[n]()
    sim = sc.build()
    # a mid-run state (rings populated, flows active); content does not
    # change op cost, but it keeps the profile honest if that ever changes
    st = sim.init()
    for _ in range(16):
        st = sim.step(st)
    st.now.block_until_ready()

    iters = 100 if n <= 128 else 25
    rows, total_us = [], 0.0
    walls = {label: _time_phase(fn, st, iters, reps)
             for label, fn in _phases(sim).items()}
    tick_wall = _time_phase(sim.step, st, iters, reps)
    hor_wall = _time_phase(
        lambda s: s._replace(now=s.now + 0 * sim.horizon(s)), st, iters, reps)
    for label, wall in list(walls.items()) + [("horizon", hor_wall),
                                              ("full_tick", tick_wall)]:
        us = wall * 1e6
        share = wall / tick_wall
        emit(f"phase_{sc.name}_{label}", wall,
             f"us_per_tick={us:.1f};share_of_tick={share:.2f}")
        rows.append(dict(name=f"{sc.name}/{label}", scenario=sc.name,
                         n=n, phase=label, us_per_tick=round(us, 2),
                         share_of_tick=round(share, 3)))
        if label not in ("horizon", "full_tick"):
            total_us += us

    sb = _state_bytes(st)
    bw = stream_gbps()
    # memory-bound ceiling: every resident state byte read + written once
    # per tick at stream speed (touch factor 2)
    ceil_tps = bw * 1e9 / (2.0 * sb)
    meas_tps = 1.0 / tick_wall
    roof = dict(name=f"roofline/{sc.name}", scenario=sc.name, n=n,
                state_bytes=sb, stream_gbps=round(bw, 2),
                memory_bound_ticks_per_sec=round(ceil_tps, 1),
                measured_ticks_per_sec=round(meas_tps, 1),
                roofline_fraction=round(meas_tps / ceil_tps, 4),
                phase_sum_us=round(total_us, 1))
    emit(f"roofline_{sc.name}", tick_wall,
         f"state_mb={sb/1e6:.1f};ceiling_tps={ceil_tps:.0f};"
         f"measured_tps={meas_tps:.0f};frac={meas_tps/ceil_tps:.3f}")
    return rows, roof


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="N in {32,128} only (CI smoke)")
    p.add_argument("--ns", default=None,
                   help="comma-separated N list, e.g. '512,1024'")
    p.add_argument("--reps", type=int, default=3)
    p.add_argument("--json-path", default=BENCH_JSON, metavar="PATH")
    args = p.parse_args(argv)
    if args.ns:
        ns = [int(x) for x in args.ns.split(",") if x]
    else:
        ns = [32, 128] if args.quick else [32, 128, 512, 1024]

    t0 = time.time()
    print("name,us_per_call,derived")
    phase_rows, roof_rows = [], []
    for n in ns:
        rows, roof = profile_scenario(n, args.reps)
        phase_rows.extend(rows)
        roof_rows.append(roof)
    meta = dict(jax=jax.__version__, device=str(jax.devices()[0].platform))
    write_bench_json("phase_profile", phase_rows, path=args.json_path,
                     meta=meta)
    path = write_bench_json("roofline", roof_rows, path=args.json_path,
                            meta=meta)
    print(f"\n# total wall: {time.time()-t0:.1f}s -> {path}")


if __name__ == "__main__":
    main()
