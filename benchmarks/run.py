"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The netsim figures always
run; the roofline table is appended when the dry-run sweeps' JSON outputs
exist (see repro.launch.dryrun).

Usage:
  PYTHONPATH=src python -m benchmarks.run [fig2 fig6 ...]
"""

from __future__ import annotations

import os
import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import fig_benchmarks as F

    wanted = set(sys.argv[1:])

    def selected(fn):
        return not wanted or any(w in fn.__name__ for w in wanted)

    print("name,us_per_call,derived")
    rows = []
    for fn in F.ALL_FIGS:
        if not selected(fn):
            continue
        try:
            rows.extend(fn())
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__},0,ERROR:{type(e).__name__}:{e}")

    # roofline table if the sweep artifacts exist
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if (not wanted or "roofline" in " ".join(wanted)) and \
            os.path.exists(os.path.join(here, "roofline_results.json")):
        from benchmarks import roofline
        print()
        roofline.main()

    print(f"\n# total wall: {time.time()-t0:.1f}s; {len(rows)} rows")


if __name__ == "__main__":
    main()
