"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The netsim figures always
run (each through the experiment API — ``common.run_scenario`` returns a
typed ``api.RunResult``); the roofline table is appended when the
dry-run sweeps' JSON outputs exist (see repro.launch.dryrun).  With
``--json`` the rows are also recorded into the machine-readable
``BENCH_netsim.json`` ledger (section ``figs``) via
``benchmarks.common.write_bench_json``.

``--studies`` additionally runs the fused tuning-grid studies
(``benchmarks.sweep``: {scenario x algo x GRID x seeds}, one compile per
grid) and, with ``--json``, records their ``StudyResult`` rows into the
``studies`` ledger section — compare PR-over-PR via
``benchmarks.check_regression --section studies --metric completion``.

``--quick`` is plumbed through to every netsim figure (sizes and tick
budgets scaled down for smoke runs); quick rows land in the separate
ledger section ``figs_quick`` so they never overwrite the full-size
figures.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--json] [--json-path PATH]
      [--quick] [--studies] [fig2 fig6 ...]
"""

from __future__ import annotations

import argparse
import inspect
import os
import sys
import time
import traceback


def _row_dicts(rows, errors):
    out = []
    for r in rows:
        name, us, derived = r.split(",", 2)
        out.append(dict(name=name, us_per_call=float(us), derived=derived))
    out.extend(dict(name=name, error=err) for name, err in errors)
    return out


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("figs", nargs="*", help="substring filters (fig2 fig6 ...)")
    p.add_argument("--json", action="store_true",
                   help="also record rows into BENCH_netsim.json")
    p.add_argument("--json-path", default=None, metavar="PATH",
                   help="ledger path (implies --json)")
    p.add_argument("--quick", action="store_true",
                   help="scaled-down smoke run (rows go to section "
                        "'figs_quick', never the full-size 'figs')")
    p.add_argument("--studies", action="store_true",
                   help="also run the fused tuning-grid studies "
                        "(benchmarks.sweep) and record their StudyResult "
                        "rows (section 'studies')")
    args = p.parse_args(argv)

    t0 = time.time()
    from benchmarks import fig_benchmarks as F

    wanted = set(args.figs)

    def selected(fn):
        return not wanted or any(w in fn.__name__ for w in wanted)

    print("name,us_per_call,derived")
    rows, errors = [], []
    for fn in F.ALL_FIGS:
        if not selected(fn):
            continue
        try:
            kw = ({"quick": True} if args.quick
                  and "quick" in inspect.signature(fn).parameters else {})
            rows.extend(fn(**kw))
        except Exception as e:  # noqa: BLE001
            # keep the CSV row shape but never swallow the diagnosis
            traceback.print_exc(file=sys.stderr)
            errors.append((fn.__name__, f"{type(e).__name__}:{e}"))
            print(f"{fn.__name__},0,ERROR:{type(e).__name__}:{e}")

    if args.json or args.json_path:
        from benchmarks.common import write_bench_json
        write_bench_json("figs_quick" if args.quick else "figs",
                         _row_dicts(rows, errors), path=args.json_path)

    if args.studies:
        from benchmarks import sweep as S
        sweep_argv = []
        if args.json or args.json_path:
            sweep_argv.append("--json")
        if args.json_path:
            sweep_argv.extend(["--json-path", args.json_path])
        if args.quick:
            # scaled-down grid; rows go to section 'studies_quick' so a
            # smoke run never touches the reviewed 'studies' baseline
            sweep_argv.append("--quick")
        print()
        S.main(sweep_argv)

    # roofline table if the sweep artifacts exist
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if (not wanted or "roofline" in " ".join(wanted)) and \
            os.path.exists(os.path.join(here, "roofline_results.json")):
        from benchmarks import roofline
        print()
        roofline.main()

    print(f"\n# total wall: {time.time()-t0:.1f}s; {len(rows)} rows")


if __name__ == "__main__":
    main()
