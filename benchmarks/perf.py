"""Superstep execution-engine throughput benchmark (ticks/second).

Measures the aggregate run loop on the standard scenarios (incast,
permutation, windowed alltoall) across CC backends and superstep sizes,
against an *ungated* K=1 while-loop reference — the pre-superstep engine
loop whose all-done exit reduction runs every tick.  Variants are measured
interleaved (round-robin over reps, best-of) so machine-load drift does
not bias one variant.

The sparse/large-message scenarios (``sparse_heavy``/``sparse_large``,
DESIGN.md Sec. 6.3) are additionally measured with event-horizon time
leaping on vs off: the trajectory is bit-for-bit identical (asserted in
tests/test_engine_leap.py), so the ticks/sec ratio isolates the leap.

Prints the usual ``name,us_per_call,derived`` CSV rows and always records
a machine-readable ``perf`` section into ``BENCH_netsim.json`` (see
``benchmarks.common.write_bench_json``) so ticks/sec is tracked
PR-over-PR.

Usage:
  PYTHONPATH=src python -m benchmarks.perf [--quick] [--json-path PATH]
      [--reps N] [--backends jnp,pallas]
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from benchmarks.common import BENCH_JSON, emit, write_bench_json
from repro.netsim.scenarios import scenario


@functools.partial(jax.jit, static_argnums=(0, 2))
def _run_k1_ungated(step, state0, max_ticks):
    """Reference loop: the pre-superstep engine hot loop (one tick per
    while_loop iteration, exit reduction evaluated every tick)."""
    def cond(st):
        return (st.now < max_ticks) & ~jnp.all(st.done)

    return jax.lax.while_loop(cond, step, state0)


def _legacy_baseline(cfg, wl, max_ticks):
    """The full pre-PR engine: legacy tick op structure (benchmarks.legacy)
    under the ungated K=1 while loop."""
    from benchmarks.legacy import build_legacy
    sim = build_legacy(cfg, wl)
    return lambda: _run_k1_ungated(sim.step, sim.init(), max_ticks)


def scenarios(quick: bool):
    """(registry scenario name, backends) per standard dense scenario —
    the names double as ledger row keys (``repro.netsim.scenarios``).

    A ``pallas`` row runs *all* the registered kernels on that backend
    (cc_update + the fused enqueue-rank/arbitration + the packed ring
    drain) in interpret mode on CPU (orders of magnitude slower per
    tick), so it only gets the smallest scenario of each mode;
    compiled-TPU runs lift that restriction.
    """
    if quick:
        return [("tiny_incast3", ("jnp", "pallas")),
                ("tiny_perm4", ("jnp",))]
    return [("incast8_32n", ("jnp", "pallas")),
            ("perm64", ("jnp",)),
            ("alltoall16_w4", ("jnp",))]


def leap_scenarios(quick: bool):
    """Registry names of the sparse/large-message scenarios measured
    leap-on vs leap-off — sized so the fabric idles for most of the
    simulated span (heavy-tailed sizes with spread-out arrivals; few
    large staggered transfers)."""
    if quick:
        return ["tiny_sparse"]
    return ["sparse_heavy_32n", "sparse_large_32n"]


def tier3_scenarios(quick: bool):
    """(registry scenario name, backends) per three-tier (core-plane)
    scenario: the paper-scale fabrics.  Big per-tick working sets
    (512-1024 nodes, 1.8k-3.6k emitters), so they run the production
    superstep only (plus the legacy k1 baseline) rather than the whole
    superstep ladder.  The pallas kernel backends (interpret mode on
    CPU) run only on the tiny 3-tier fabric, same policy as the dense
    list."""
    if quick:
        return [("tiny_3t", ("jnp", "pallas")),
                ("perm_512n_3t_degraded", ("jnp",))]
    return [("perm_512n_3t", ("jnp",)),
            ("perm_1024n_3t", ("jnp",)),
            ("incast_256x1_3t", ("jnp",)),
            ("alltoall_3t", ("jnp",)),
            ("perm_512n_3t_degraded", ("jnp",)),
            ("tiny_3t", ("jnp", "pallas"))]


def superstep_sizes(brtt: int, quick: bool):
    ks = [1, brtt] if quick else [1, 8, brtt, 2 * brtt]
    return sorted(set(ks))


def _measure(variants, reps):
    """Warm every variant (compile + first run), then time them interleaved
    (round-robin over reps, best-of) so machine-load drift does not bias
    one variant.  Returns ({label: best wall}, {label: simulated ticks})."""
    walls, ticks = {}, {}
    for label, fn in variants.items():
        st = fn()
        st.now.block_until_ready()
        ticks[label] = int(st.now)
        walls[label] = float("inf")
    for _ in range(reps):
        for label, fn in variants.items():
            t0 = time.time()
            fn().now.block_until_ready()
            walls[label] = min(walls[label], time.time() - t0)
    return walls, ticks


def bench_scenario(name, backend, reps, quick, ksizes=None):
    """Measure the ungated reference and every superstep size, interleaved.
    Returns one row dict per variant.  The k-variants run the *production
    default* engine config (time leaping included — a no-op jump on these
    dense scenarios beyond the per-superstep horizon cost); each row
    records its ``leap`` flag so ledger comparisons are labeled.
    ``ksizes`` overrides the measured superstep ladder: a list of sizes,
    or ``"production"`` for just the auto size (one base RTT — the
    three-tier rows measure only that).

    A ``pallas`` row runs every registered kernel on that backend —
    cc_update *and* the fabric enqueue-rank/arbitration and transport
    ring-drain kernels — so the label means "the pallas hot loop", not
    one kernel in isolation."""
    sc = scenario(name, cc_backend=backend, fabric_backend=backend,
                  transport_backend=backend)
    max_ticks = sc.max_ticks
    base_sim = sc.build()
    # baseline: the pre-PR engine — legacy tick op structure under the
    # ungated one-tick-per-iteration while loop (see benchmarks/legacy.py)
    variants = {"k1_ungated": _legacy_baseline(sc.cfg, sc.wl, max_ticks)}
    sims = {}
    if ksizes is None:
        ksizes = superstep_sizes(base_sim.dims.brtt_inter, quick)
    elif ksizes == "production":
        ksizes = [base_sim.dims.brtt_inter]
    for k in ksizes:
        sim = sc.with_(superstep=k).build()
        sims[f"k{k}"] = sim
        variants[f"k{k}"] = (lambda s=sim: s.run(max_ticks))

    walls, ticks = _measure(variants, reps)
    base_tps = ticks["k1_ungated"] / walls["k1_ungated"]
    rows = []
    for label in variants:
        tps = ticks[label] / walls[label]
        speedup = tps / base_tps
        k = 0 if label == "k1_ungated" else int(label[1:])
        emit(f"perf_{name}_{backend}_{label}", walls[label],
             f"ticks={ticks[label]};ticks_per_sec={tps:.0f};"
             f"speedup_vs_k1_ungated={speedup:.2f}")
        rows.append(dict(
            name=f"{name}/{backend}/{label}", scenario=name, backend=backend,
            superstep=k,
            leap=bool(sims[label].dims.leap) if label in sims else False,
            ticks=ticks[label], wall_s=round(walls[label], 6),
            ticks_per_sec=round(tps, 1),
            speedup_vs_k1_ungated=round(speedup, 3)))
    # per-scenario best-k record: which fused superstep size wins, and —
    # loudly — whether fusion *lost* to the ungated k=1 reference (the
    # regression mode this ledger exists to catch; a fused k>1 loop
    # re-running a too-expensive tick body can sit below the legacy
    # baseline, as perm_512n_3t did before the large-N scatter work)
    fused = {lbl: ticks[lbl] / walls[lbl] for lbl in sims
             if int(lbl[1:]) > 1}
    if fused:
        best_lbl = max(fused, key=fused.get)
        best_tps = fused[best_lbl]
        regression = bool(best_tps < base_tps)
        emit(f"perf_{name}_{backend}_best_k", walls[best_lbl],
             f"best_k={best_lbl[1:]};ticks_per_sec={best_tps:.0f};"
             f"fusion_regression={regression}")
        if regression:
            print(f"# !! FUSION REGRESSION {name}/{backend}: best fused "
                  f"{best_lbl} = {best_tps:.0f} ticks/s < k1_ungated = "
                  f"{base_tps:.0f} ticks/s", flush=True)
        rows.append(dict(
            name=f"{name}/{backend}/best_k", scenario=name, backend=backend,
            kind="best_k", best_k=int(best_lbl[1:]),
            ticks_per_sec=round(best_tps, 1),
            speedup_vs_k1_ungated=round(best_tps / base_tps, 3),
            fusion_regression=regression))
    return rows


def bench_leap_scenario(name, reps):
    """Measure leap-on vs leap-off (superstep auto, jnp backend) on one
    sparse scenario, interleaved best-of.  Returns one row per variant."""
    sc = scenario(name)
    max_ticks = sc.max_ticks
    variants, sims = {}, {}
    for label, leap in (("leap_off", False), ("leap_on", True)):
        sim = sc.with_(leap=leap).build()
        sims[label] = sim
        variants[label] = (lambda s=sim: s.run(max_ticks))

    walls, ticks = _measure(variants, reps)
    base_tps = ticks["leap_off"] / walls["leap_off"]
    rows = []
    for label in variants:
        tps = ticks[label] / walls[label]
        emit(f"perf_{name}_jnp_{label}", walls[label],
             f"ticks={ticks[label]};ticks_per_sec={tps:.0f};"
             f"speedup_vs_leap_off={tps / base_tps:.2f}")
        rows.append(dict(
            name=f"{name}/jnp/{label}", scenario=name, backend="jnp",
            superstep=sims[label].dims.superstep,
            leap=bool(sims[label].dims.leap),
            ticks=ticks[label], wall_s=round(walls[label], 6),
            ticks_per_sec=round(tps, 1),
            speedup_vs_leap_off=round(tps / base_tps, 3)))
    return rows


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="tiny topology smoke run (CI)")
    p.add_argument("--json-path", default=BENCH_JSON, metavar="PATH",
                   help="BENCH_netsim.json path (always written)")
    p.add_argument("--reps", type=int, default=None,
                   help="timing repetitions per variant (best-of)")
    p.add_argument("--backends", default=None,
                   help="comma-separated override, e.g. 'jnp'")
    p.add_argument("--only", default=None, metavar="NAMES",
                   help="comma-separated scenario-name filter (applies to "
                        "the dense, leap, and three-tier lists)")
    args = p.parse_args(argv)
    reps = args.reps or (2 if args.quick else 4)
    only = set(args.only.split(",")) if args.only else None

    def picked(name):
        return only is None or name in only

    t0 = time.time()
    print("name,us_per_call,derived")
    rows = []
    # three-tier rows run FIRST: the large-N numbers are the ledger's
    # headline and in-process memory pressure from the earlier dense /
    # interpret-mode pallas suites suppresses later timings by ~10-12%
    # (allocator fragmentation + compiled-workspace residue), which is
    # measurement pollution, not engine speed.  The small dense/leap
    # scenarios are far less sensitive to heap state.
    for name, backends in tier3_scenarios(args.quick):
        if not picked(name):
            continue
        if args.backends:
            backends = [b for b in args.backends.split(",") if b]
        for backend in backends:
            rows.extend(bench_scenario(name, backend, min(reps, 2),
                                       args.quick, ksizes="production"))
    for name, backends in scenarios(args.quick):
        if not picked(name):
            continue
        if args.backends:
            backends = [b for b in args.backends.split(",") if b]
        for backend in backends:
            rows.extend(bench_scenario(name, backend, reps, args.quick))
    for name in leap_scenarios(args.quick):
        if picked(name):
            rows.extend(bench_leap_scenario(name, min(reps, 2)))
    path = write_bench_json(
        "perf", rows, path=args.json_path,
        meta=dict(quick=bool(args.quick), reps=reps, jax=jax.__version__,
                  device=str(jax.devices()[0].platform)))
    print(f"\n# total wall: {time.time()-t0:.1f}s; {len(rows)} rows -> {path}")


if __name__ == "__main__":
    main()
