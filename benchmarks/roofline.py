"""Roofline aggregation: three terms per (arch x shape x mesh) cell.

Inputs (produced by the dry-run sweeps):
  dryrun_results.json   — full-depth compiles: memory analysis, raw
                          (scan-body-once) cost numbers — the pass/fail +
                          fits-in-HBM evidence.
  roofline_results.json — depth-differenced, unrolled lowering: exact
                          per-step per-device FLOPs / bytes / collective
                          bytes (see repro.launch.dryrun.roofline_cell).

Hardware model (TPU v5e-class, task spec):
  peak      197 TFLOP/s bf16 per chip
  HBM bw    819 GB/s per chip
  ICI       ~50 GB/s per link

Terms (seconds per step, per the task formulas — cost_analysis numbers are
per-device after SPMD partitioning, so chips cancels):
  compute    = flops_per_device / 197e12
  memory     = bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = non-embedding active
params; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute, causal-
mask waste and head overhead.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(path):
    with open(path) as f:
        return json.load(f)


_NACT_CACHE = {}


def n_active(arch: str) -> int:
    """Recompute active (non-MoE-scaled) params from the config — the
    stored value in older sweeps predates the MoE leaf-matching fix."""
    if arch not in _NACT_CACHE:
        from repro.configs import get_config
        _NACT_CACHE[arch] = get_config(arch).active_param_count()
    return _NACT_CACHE[arch]


def analyze(roof: dict, dry: dict | None = None) -> dict:
    chips = roof["chips"]
    fl = roof["flops_per_device"]
    by = roof["bytes_per_device"]
    coll = sum(roof["collectives_per_device"].values())
    t_comp = fl / PEAK_FLOPS
    t_mem = by / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = (6 if roof["kind"] == "train" else 2) * n_active(roof["arch"]) * roof["tokens"]
    hlo_global = fl * chips
    out = dict(
        arch=roof["arch"], shape=roof["shape"], mesh=roof["mesh"],
        chips=chips,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        dominant=dominant,
        bound_frac=terms[dominant] / max(sum(terms.values()), 1e-30),
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=mf / max(hlo_global, 1e-30),
        step_time_est=max(terms.values()),
        mfu_est=mf / chips / max(terms.values()) / PEAK_FLOPS,
        collectives=roof["collectives_per_device"],
    )
    if dry:
        out["temp_bytes_full"] = dry.get("temp_size_in_bytes")
        out["state_bytes_per_device"] = dry.get("state_bytes_per_device")
    return out


def suggestion(row: dict) -> str:
    d = row["dominant"]
    c = row["collectives"]
    if d == "collective":
        big = max((k for k in c), key=lambda k: c[k])
        return (f"dominated by {big} ({c[big]/2**30:.2f} GiB/dev/step): "
                "overlap with compute or reshard (reduce weight re-gathers)")
    if d == "memory":
        return ("HBM-bound: raise arithmetic intensity (fuse, larger "
                "per-device batch, weight-stationary layout)")
    if row["useful_ratio"] < 0.5:
        return ("compute-bound but <50% useful flops: cut remat recompute "
                "and causal-mask waste (skip masked KV tiles)")
    return "compute-bound near useful peak: good placement"


def table(rows, keys=("arch", "shape", "mesh")) -> str:
    hdr = ["arch", "shape", "mesh", "t_comp(ms)", "t_mem(ms)", "t_coll(ms)",
           "bound", "useful", "MFU_est"]
    lines = ["| " + " | ".join(hdr) + " |",
             "|" + "|".join("---" for _ in hdr) + "|"]
    for r in rows:
        lines.append("| " + " | ".join([
            r["arch"], r["shape"], r["mesh"],
            f"{r['t_compute']*1e3:.2f}", f"{r['t_memory']*1e3:.2f}",
            f"{r['t_collective']*1e3:.2f}", r["dominant"],
            f"{r['useful_ratio']:.2f}", f"{r['mfu_est']:.3f}"]) + " |")
    return "\n".join(lines)


def main():
    roof = load(os.path.join(HERE, "roofline_results.json"))
    try:
        dry = {(r["arch"], r["shape"], r["mesh"]): r
               for r in load(os.path.join(HERE, "dryrun_results.json"))}
    except FileNotFoundError:
        dry = {}
    rows = []
    for r in roof:
        if not r.get("ok"):
            print(f"# SKIP (failed): {r.get('arch')} {r.get('shape')}")
            continue
        row = analyze(r, dry.get((r["arch"], r["shape"], r["mesh"])))
        rows.append(row)
    print(table(rows))
    print()
    for r in rows:
        print(f"- {r['arch']} x {r['shape']}: {suggestion(r)}")


if __name__ == "__main__":
    main()
