"""Collectives benchmark: the dependency-driven collective scenarios
(ring/tree allreduce, all-gather, pipeline — DESIGN.md Sec. 11) run
across congestion-control algorithms, reporting collective completion
time (CCT) next to the flow-level metrics.

CCT is the metric training traffic actually experiences: the ticks from
a collective's earliest ``t_start`` to its *last* flow's delivery — a
single straggler chunk stalls the whole operation, which per-flow FCT
percentiles hide.  Row names are ``<scenario>/<algo>``; rows land in
ledger section ``collectives`` and compare PR-over-PR via::

  python -m benchmarks.check_regression --fresh fresh.json \
      --ledger BENCH_netsim.json --section collectives \
      --metric cct --direction down --require tiny_allreduce_ring

``--quick`` runs only the tiny scenarios on smartt for the CI
collectives job — same names and tick budgets as the full table, so the
quick rows compare directly against the committed ledger.

Usage:
  PYTHONPATH=src python -m benchmarks.collectives [--quick] [--json-path PATH]
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import BENCH_JSON, emit, write_bench_json
from repro.netsim import api, scenarios

TINY = ("tiny_allreduce_ring", "tiny_allgather", "tiny_pipeline")
FULL = ("allreduce_ring_128n_3t", "allreduce_tree_128n_3t",
        "allgather_64n_3t", "pipeline_32n")
ALGOS = ("smartt", "swift", "mprdma")


def variants(quick: bool):
    """(scenario name, algo) pairs — one ledger row each."""
    if quick:
        return [(name, ALGOS[0]) for name in TINY]
    return [(name, algo) for name in TINY + FULL for algo in ALGOS]


def run_variant(name: str, algo: str) -> dict:
    label = f"{name}/{algo}"
    sc = scenarios.scenario(name).with_(name=label, algo=algo)
    t0 = time.time()
    r = api.run(sc)
    row = r.row()
    emit(label, time.time() - t0,
         f"done={r.n_done}/{r.n_flows} cct={row.get('cct', -1)} "
         f"completion={r.completion} trims={r.trims}")
    return row


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="tiny scenarios on smartt only (CI smoke)")
    p.add_argument("--json-path", default=BENCH_JSON, metavar="PATH",
                   help="ledger path (default: repo BENCH_netsim.json)")
    args = p.parse_args(argv)

    print("name,us_per_call,derived")
    rows = [run_variant(name, algo) for name, algo in variants(args.quick)]

    path = write_bench_json(
        "collectives", rows, path=args.json_path,
        meta=dict(quick=bool(args.quick)))
    print(f"wrote {len(rows)} rows -> {path} section=collectives",
          file=sys.stderr)


if __name__ == "__main__":
    main()
