"""Frozen reference implementation of the pre-superstep engine hot path.

``benchmarks/perf.py`` reports engine throughput as a speedup over "the
K=1 ungated loop" — the engine as it stood before the superstep PR: one
tick per ``while_loop`` iteration, and a scatter-heavy tick (stable-argsort
enqueue ranking, per-emitter ACK scatter with a write-off target, five
separate ACK-drain scatters, three separate trim-ledger scatters, three
separate sent-ring component scatters, scatter-built eligibility/emission
masks).  This module reconstructs that op structure against the current
state containers so the baseline stays measurable after the engine moved
on.  It is benchmark-only code: nothing in the simulator imports it, and
it intentionally does NOT track future engine changes.

The reconstruction produces the same simulated trajectory as the
production step — same fct/goodput/cwnd/tick count (the argsort ranks
equal the production ranks; everything else is op structure, not
semantics) — so ticks/sec comparisons are apples to apples.  One state
leaf intentionally diverges for sender-based algorithms: the seed engine
maintained the EQDS-only ``trim_seen`` ledger unconditionally, so this
baseline does too, while the production step gates it on
``Dims.credit_based``; that cost difference is part of what the speedup
measures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import registry, reps
from repro.core.types import CCEvent
from repro.netsim import engine, fabric, faults, metrics, sender
from repro.netsim.metrics import HIST_BINS
from repro.netsim.state import pkt_size

I32 = jnp.int32
F32 = jnp.float32


def _departures(dims, consts, st):
    """Seed-style wire placement: one scatter over all ports with a
    dropped write-off slot for idle ports."""
    t = st.now
    m = st.m
    NQ, CAP, L = dims.NQ, dims.CAP, dims.L
    qidx = consts.qidx
    # the fault model moved to compiled schedule tables (netsim/faults);
    # the shared evaluation replaces the seed's static service_period /
    # dead vectors bit-for-bit, and the baseline keeps its seed-style op
    # structure everywhere else
    if dims.FK or dims.flapped:
        per = faults.port_period(dims, consts, t)
        svc = jnp.where(per > 1, (t % jnp.maximum(per, 1)) == 0, True)
    else:
        svc = True
    active = (st.q_size[:NQ] > 0) & svc
    head = st.q_head[:NQ]
    hf = st.q_fields[qidx, head]
    d_flow, d_seq, d_ent, d_ecn, d_ts = (hf[:, i] for i in range(5))
    from repro.netsim import hashing
    qsz = st.q_size[:NQ].astype(F32)
    pmark = jnp.clip((qsz - consts.kmin) / consts.kspan, 0.0, 1.0)
    mark = hashing.uniform01(t * jnp.int32(131071) + qidx,
                             jnp.int32(0xECD) + st.salt) < pmark
    d_ecn = d_ecn | (mark & active).astype(I32)
    if dims.FK or dims.flapped:
        black = (per == 0)[qidx] & active
    else:
        black = jnp.zeros((NQ,), bool)
    emit = active & ~black
    next_q = fabric.route_from_queue(dims, consts, d_flow, d_ent)
    q_head = st.q_head.at[:NQ].set(jnp.where(active, (head + 1) % CAP, head))
    q_size = st.q_size.at[:NQ].add(-active.astype(I32))
    B = dims.QE
    lat = jnp.where(qidx < B, consts.lat_core, consts.lat_edge)
    slot = jnp.where(emit, (t + lat) % L, L)          # L = dropped
    payload = jnp.stack(
        [emit.astype(I32), next_q, d_flow, d_seq, d_ent, d_ecn, d_ts], axis=1)
    infl = st.infl.at[slot, qidx].set(payload, mode="drop")
    m = m._replace(n_black=m.n_black + jnp.sum(black.astype(I32)))
    return st._replace(q_head=q_head, q_size=q_size, infl=infl, m=m)


def _arrivals(dims, consts, st):
    """Seed-style arrivals: full-emitter delivery path, scattered ACK ring
    write, argsort enqueue ranking, three separate trim-ledger scatters."""
    t = st.now
    m = st.m
    NF, NQ, NE, N = dims.NF, dims.NQ, dims.NE, dims.N
    CAP, L, R = dims.CAP, dims.L, dims.R

    arr = st.infl[t % L]
    infl = st.infl.at[t % L].set(0)
    a_valid = arr[:, 0] == 1
    a_dstq, a_flow, a_seq, a_ent, a_ecn, a_ts = (arr[:, i] for i in range(1, 7))
    deliver = a_valid & (a_dstq < 0)
    enq = a_valid & (a_dstq >= 0)

    node = jnp.where(deliver, -a_dstq - 1, 0)
    dflow = jnp.where(deliver, a_flow, NF)
    word, bit = a_seq // 32, a_seq % 32
    old = st.bitmap[dflow, word]
    isnew = deliver & (((old >> bit) & 1) == 0)
    bitmap = st.bitmap.at[dflow, word].add(
        jnp.where(isnew, (1 << bit).astype(I32), 0))
    psz = pkt_size(dims, consts, a_flow, a_seq)
    goodput = st.goodput.at[jnp.where(isnew, a_flow, 0)].add(
        jnp.where(isnew, psz, 0))
    newly_done = (goodput >= consts.size) & ~st.done
    done = st.done | newly_done
    fct = jnp.where(newly_done, t + consts.ret - consts.t_start, st.fct)
    anode = jnp.where(deliver, node, N)               # N = dropped
    aslot = jnp.where(deliver, (t + consts.ret) % R, 0)
    ack_payload = jnp.stack(
        [deliver.astype(I32), a_flow, a_seq, a_ecn, a_ent, a_ts], axis=1)
    ack_ring = st.ack_ring.at[aslot, anode].set(ack_payload, mode="drop")
    m = m._replace(
        delivered_pkts=m.delivered_pkts + jnp.sum(deliver.astype(I32)),
        delivered_bytes=m.delivered_bytes
        + jnp.sum(jnp.where(isnew, psz, 0)).astype(F32),
    )

    # enqueues: stable argsort ranking (the pre-PR scheme)
    q_head, q_size = st.q_head, st.q_size
    edst = jnp.where(enq, a_dstq, NQ)
    order = jnp.argsort(edst)
    ds = edst[order]
    eflow, eseq, eent, eecn, ets = (
        x[order] for x in (a_flow, a_seq, a_ent, a_ecn, a_ts))
    first = jnp.searchsorted(ds, ds, side="left")
    rank = jnp.arange(NE, dtype=first.dtype) - first
    space = CAP - q_size[ds]
    acc = (ds < NQ) & (rank < space)
    pos = (q_head[ds] + q_size[ds] + rank.astype(I32)) % CAP
    row = jnp.where(acc, ds, NQ)
    posw = jnp.where(acc, pos, 0)
    q_fields = st.q_fields.at[row, posw].set(
        jnp.stack([eflow, eseq, eent, eecn, ets], axis=1))
    q_size = q_size + jax.ops.segment_sum(acc.astype(I32), ds,
                                          num_segments=NQ + 1)
    rej = (ds < NQ) & ~acc
    rflow = jnp.where(rej, eflow, NF)
    rbytes = jnp.where(rej, pkt_size(dims, consts, eflow, eseq), 0)
    trim_seen = st.trim_seen.at[rflow].add(rbytes.astype(F32))
    if dims.trimming:
        W, WW = dims.W, dims.WW
        tslot = jnp.where(rej, (t + consts.trim_delay) % R, 0)
        trim_ring = st.trim_ring.at[tslot, rflow, 0].add(rej.astype(I32))
        trim_ring = trim_ring.at[tslot, rflow, 1].add(rbytes)
        wslot = (eseq % W) // 32
        wbit = (eseq % W) % 32
        trim_ring = trim_ring.at[tslot, rflow, 2 + wslot].add(
            jnp.where(rej, (1 << wbit).astype(I32), 0))
        m = m._replace(n_trim=m.n_trim + jnp.sum(rej.astype(I32)))
    else:
        trim_ring = st.trim_ring
        m = m._replace(n_drop=m.n_drop + jnp.sum(rej.astype(I32)))
    return st._replace(
        infl=infl, bitmap=bitmap, goodput=goodput, done=done, fct=fct,
        ack_ring=ack_ring, q_fields=q_fields, q_size=q_size,
        trim_seen=trim_seen, trim_ring=trim_ring, m=m)


def _control(dims, consts, cc_update, st):
    """Seed-style control: five separate ACK-drain scatters, scattered
    sent-slot free, two separate loss slice-writes, histogram scatter."""
    t = st.now
    m = st.m
    NF, N, R, W = dims.NF, dims.N, dims.R, dims.W
    MTU = float(dims.mtu)
    flow_ids = consts.flow_ids

    acks = st.ack_ring[t % R]
    ack_ring = st.ack_ring.at[t % R].set(0)
    v = acks[:, 0] == 1
    idxf = jnp.where(v, acks[:, 1], NF)

    def scat(vals, fill=0):
        return jnp.full((NF + 1,), fill, vals.dtype).at[idxf].set(vals)[:NF]

    has_ack = jnp.zeros((NF + 1,), bool).at[idxf].set(v)[:NF]
    ack_seq = scat(acks[:, 2])
    ack_ecn = jnp.zeros((NF + 1,), bool).at[idxf].set(acks[:, 3] == 1)[:NF]
    ack_ent = scat(acks[:, 4])
    ack_ts = scat(acks[:, 5])
    rtt = jnp.where(has_ack, (t - ack_ts).astype(F32), 0.0)
    ack_bytes = jnp.where(
        has_ack, pkt_size(dims, consts, flow_ids, ack_seq).astype(F32), 0.0)

    tr = st.trim_ring[t % R][:NF]
    trims, tbytes, lbits = tr[:, 0], tr[:, 1].astype(F32), tr[:, 2:]
    cred = st.credit_ring[t % R][:NF]
    trim_ring = st.trim_ring.at[t % R].set(0)
    credit_ring = st.credit_ring.at[t % R].set(0.0)

    aslot2 = ack_seq % W
    cur = st.sent[0, flow_ids, aslot2]
    cur_seq = st.sent[1, flow_ids, aslot2]
    match = has_ack & (cur != 0) & (cur_seq == ack_seq)
    sent = st.sent.at[0, flow_ids, aslot2].set(jnp.where(match, 0, cur))

    wbits = jnp.arange(W, dtype=I32)
    bitsel = (lbits[:, wbits // 32] >> (wbits % 32)) & 1
    lost_mask = (bitsel == 1) & (sent[0, :NF] == 1)
    sent = sent.at[0, :NF].set(jnp.where(lost_mask, 3, sent[0, :NF]))

    started_flows = (t >= consts.t_start) & ~st.done
    to_mask = (sent[0, :NF] == 1) & \
        ((t - sent[2, :NF]).astype(F32) > consts.rto[:, None]) & \
        started_flows[:, None]
    sp_word = sent[1, :NF] // 32
    sp_bit = sent[1, :NF] % 32
    already = ((st.bitmap[:NF][jnp.arange(NF)[:, None], sp_word]
                >> sp_bit) & 1) == 1
    m = m._replace(spurious_retx=m.spurious_retx
                   + jnp.sum((to_mask & already).astype(I32)))
    sent = sent.at[0, :NF].set(jnp.where(to_mask, 3, sent[0, :NF]))
    n_to = jnp.sum(to_mask.astype(I32), axis=1)
    to_bytes = n_to.astype(F32) * MTU
    m = m._replace(n_to=m.n_to + jnp.sum(n_to))
    unacked = jnp.sum((sent[0, :NF] == 1).astype(I32),
                      axis=1).astype(F32) * MTU

    ev = CCEvent(
        has_ack=has_ack, ack_bytes=ack_bytes, ecn=ack_ecn, rtt=rtt,
        ack_entropy=ack_ent, n_trims=trims, trim_bytes=tbytes,
        n_timeouts=n_to, to_bytes=to_bytes, unacked=unacked,
        credit_grant=cred)
    cc = cc_update(consts.cc, st.cc, ev, t)
    lb = reps.on_ack(dims.lb_mode, consts.lb, st.lb, has_ack, ack_ecn,
                     ack_ent, flow_ids, t)
    bins = jnp.clip((rtt * (8.0 / dims.brtt_inter)).astype(I32),
                    0, HIST_BINS - 1)
    m = m._replace(
        rtt_hist=m.rtt_hist.at[jnp.where(has_ack, bins, 0)].add(
            has_ack.astype(I32)),
        n_ack=m.n_ack + jnp.sum(has_ack.astype(I32)))
    return st._replace(
        ack_ring=ack_ring, trim_ring=trim_ring, credit_ring=credit_ring,
        sent=sent, unacked=unacked, cc=cc, lb=lb, m=m)


def _sends(dims, consts, st):
    """Seed-style sends: scatter-built eligibility and emission masks,
    three separate sent-ring component scatters, scattered wire write."""
    t = st.now
    m = st.m
    NF, N, NQ, L, W = dims.NF, dims.N, dims.NQ, dims.L, dims.W
    FMAX, window = dims.FMAX, dims.window
    mtu_i = dims.mtu
    flow_ids = consts.flow_ids
    cc = st.cc

    pace = st.pace_accum
    if dims.paced:
        pace = jnp.minimum(pace + cc.pacing_rate, 4.0 * float(mtu_i))

    done_p = jnp.pad(st.done, (0, 1), constant_values=True)
    unfin = (~done_p[consts.flows_of]) & (consts.flows_of < NF)
    prior_unfin = jnp.cumsum(unfin, axis=1) - unfin.astype(I32)
    win_elig = jnp.full((NF + 1,), False).at[consts.flows_of.reshape(-1)].set(
        (prior_unfin < window).reshape(-1))[:NF]

    started = (t >= consts.t_start) & ~st.done & win_elig
    is_retx = st.sent[0, :NF] == 3
    has_retx = jnp.any(is_retx, axis=1)
    retx_slot = jnp.argmax(is_retx, axis=1)
    retx_seq = st.sent[1, flow_ids, retx_slot]
    new_seq = st.next_seq
    new_slot = new_seq % W
    new_ok = (new_seq * mtu_i < consts.size) & \
        (st.sent[0, flow_ids, new_slot] == 0)
    seq_emit = jnp.where(has_retx, retx_seq, new_seq)
    nsize = pkt_size(dims, consts, flow_ids, seq_emit).astype(F32)
    win_ok = st.unacked + nsize <= cc.cwnd
    credit_ok = True
    if dims.credit_based:
        credit_ok = (cc.credits >= nsize) | (cc.spec_budget >= nsize)
    pace_ok = (pace >= nsize) if dims.paced else True
    elig = started & (has_retx | new_ok) & win_ok & credit_ok & pace_ok & \
        (nsize > 0)

    E = jnp.pad(elig, (0, 1))[consts.flows_of]
    keys = (jnp.arange(FMAX, dtype=I32)[None, :] - st.rr_send[:, None]) % FMAX
    keys = jnp.where(E, keys, FMAX + 1)
    sel = jnp.argmin(keys, axis=1)
    has_s = jnp.any(E, axis=1)
    sflow = jnp.where(has_s, consts.flows_of[consts.node_ids, sel], NF)
    rr_send = jnp.where(has_s, (sel.astype(I32) + 1) % FMAX, st.rr_send)

    emit_mask = jnp.zeros((NF + 1,), bool).at[sflow].set(has_s)[:NF]
    lb, entropy = reps.on_send(dims.lb_mode, consts.lb, st.lb, emit_mask,
                               seq_emit, flow_ids, t)
    first_q = fabric.route_from_sender(dims, consts, flow_ids, entropy)

    send_slot = jnp.where(has_s, (t + consts.lat_send) % L, L)
    sf = jnp.clip(sflow, 0, NF - 1)
    spay = jnp.stack([
        has_s.astype(I32), first_q[sf], sflow, seq_emit[sf], entropy[sf],
        jnp.zeros((N,), I32), jnp.full((N,), 1, I32) * t], axis=1)
    infl = st.infl.at[send_slot, NQ + consts.node_ids].set(spay, mode="drop")

    eslot = seq_emit % W
    eflow2 = jnp.where(emit_mask, flow_ids, NF)
    sent = st.sent.at[0, eflow2, eslot].set(
        jnp.where(emit_mask, 1, st.sent[0, eflow2, eslot]))
    sent = sent.at[1, eflow2, eslot].set(
        jnp.where(emit_mask, seq_emit, sent[1, eflow2, eslot]))
    sent = sent.at[2, eflow2, eslot].set(
        jnp.where(emit_mask, t, sent[2, eflow2, eslot]))
    is_new_send = emit_mask & ~has_retx
    next_seq = st.next_seq + is_new_send.astype(I32)
    m = m._replace(n_retx=m.n_retx
                   + jnp.sum((emit_mask & has_retx).astype(I32)))

    spend = jnp.where(emit_mask, nsize, 0.0)
    if dims.credit_based:
        use_credit = cc.credits >= nsize
        cc = cc._replace(
            credits=cc.credits - spend * use_credit,
            spec_budget=cc.spec_budget - spend * (~use_credit))
    if dims.paced:
        pace = pace - spend
    return st._replace(
        infl=infl, sent=sent, next_seq=next_seq, rr_send=rr_send,
        pace_accum=pace, cc=cc, lb=lb, m=m)


def build_legacy(cfg, wl):
    """An engine.Sim whose step uses the pre-PR op structure (run it with
    perf._run_k1_ungated for the full legacy baseline)."""
    import dataclasses
    sim = engine.build(cfg, wl)
    cc_update = registry.get(cfg.algo, cfg.cc_backend)
    dims, consts = sim.dims, sim.consts

    def step(st):
        st = _departures(dims, consts, st)
        st = _arrivals(dims, consts, st)
        st = _control(dims, consts, cc_update, st)
        st = sender.grants(dims, consts, st)
        st = _sends(dims, consts, st)
        st = metrics.account(dims, consts, st)
        return st._replace(now=st.now + 1)

    return dataclasses.replace(sim, step=step)
