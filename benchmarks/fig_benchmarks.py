"""One benchmark per paper figure/table (Sec. 4 of SMaRTT-REPS).

Each ``figN_*`` function reproduces the *claim* of the corresponding paper
figure at CPU scale and returns CSV rows; EXPERIMENTS.md Sec.
Paper-validation records the comparison against the paper's own numbers.
Runs go through the experiment API (``common.run_scenario`` -> typed
``api.RunResult``: ``.completion``, ``.jain``, ``.trims``, ...).
"""

from __future__ import annotations

from benchmarks.common import (TREE_2TO1, TREE_4TO1, TREE_8TO1, TREE_FLAT,
                               emit, run_scenario)
from repro.netsim import workloads

KiB = 1024
MiB = 1024 * 1024


def _sz(size_bytes: int, quick: bool) -> int:
    """Quick-mode message scaling (smoke runs; ledger rows come from the
    full-size run -- benchmarks/run.py routes quick rows to a separate
    section)."""
    return max(16 * KiB, size_bytes // 8) if quick else size_bytes


def _mt(max_ticks: int, quick: bool) -> int:
    return max(4000, max_ticks // 8) if quick else max_ticks


def fig2_signals(quick=False):
    """Fig. 2/3a: ECN reacts faster, delay is fairer, SMaRTT gets both.

    Reports both the FCT outcome and the Fig. 2 quantity itself: the tick
    at which the mean congestion window first converges to within 1.5x of
    the fair share (cwnd trace via the engine's trace mode)."""
    import time as _t

    import numpy as np

    from benchmarks.common import LINK, TREE_FLAT
    from repro.netsim.engine import SimConfig, build

    rows = []
    wl = workloads.incast(TREE_FLAT, degree=8,
                          size_bytes=_sz(512 * KiB, quick), seed=0)
    fair = 26 * 4096 / 8 * 1.25          # BDP share of the bottleneck
    for algo in ("ecn_only", "delay_only", "smartt"):
        s = run_scenario(TREE_FLAT, wl, algo=algo,
                         max_ticks=_mt(60000, quick))
        sim = build(SimConfig(link=LINK, tree=TREE_FLAT, algo=algo, lb="reps"), wl)
        t0 = _t.time()
        _, ys = sim.run_trace(128 if quick else 512, trace_flows=8)
        mean_cwnd = np.asarray(ys["cwnd"]).mean(axis=1)
        conv = np.argmax(mean_cwnd <= 1.5 * fair)
        if mean_cwnd.min() > 1.5 * fair:
            conv = -1
        rows.append(emit(f"fig2_incast8to1_{algo}",
                         s.wall_s + (_t.time() - t0),
                         f"completion={s.completion};jain={s.jain:.3f};"
                         f"trims={s.trims};cwnd_conv_tick={conv}"))
    return rows


def fig3b_granularity(quick=False):
    """Fig. 3b: reacting every N ACKs (N<=50) stays within ~5% of per-packet."""
    rows = []
    wl = workloads.incast(TREE_FLAT, degree=8,
                          size_bytes=_sz(512 * KiB, quick), seed=0)
    base = None
    for n in (1, 8, 50):
        s = run_scenario(TREE_FLAT, wl, algo="smartt", react_every=n,
                         max_ticks=_mt(60000, quick))
        base = base or s.completion
        rows.append(emit(f"fig3b_react_every_{n}", s.wall_s,
                         f"completion={s.completion};"
                         f"vs_perpacket={s.completion/base:.3f}"))
    return rows


def fig5b_wtd(quick=False):
    """Fig. 5b: Wait-to-Decrease cuts FCT on a non-oversubscribed
    permutation (transient ECMP imbalance left to REPS, not the window)."""
    rows = []
    wl = workloads.permutation(TREE_FLAT, size_bytes=_sz(1 * MiB, quick),
                               seed=2)
    for name, ovr in (("wtd_on", ()), ("wtd_off", (("wtd_thresh", 0.0),))):
        s = run_scenario(TREE_FLAT, wl, algo="smartt", cc_overrides=ovr,
                         max_ticks=_mt(60000, quick))
        rows.append(emit(f"fig5b_{name}", s.wall_s,
                         f"completion={s.completion};jain={s.jain:.3f}"))
    return rows


def fig6_reps(quick=False):
    """Fig. 6: REPS vs oblivious spray vs per-flow ECMP vs PLB."""
    rows = []
    wl = workloads.permutation(TREE_4TO1, size_bytes=_sz(1 * MiB, quick),
                               seed=3)
    for lb in ("reps", "spray", "plb", "ecmp"):
        s = run_scenario(TREE_4TO1, wl, algo="smartt", lb=lb,
                         max_ticks=_mt(60000, quick))
        rows.append(emit(f"fig6_lb_{lb}", s.wall_s,
                         f"completion={s.completion};jain={s.jain:.3f};"
                         f"trims={s.trims}"))
    return rows


def fig7_faults(quick=False):
    """Fig. 7: asymmetric (half-rate) link and link failure — REPS routes
    around; oblivious spray keeps hitting the bad path."""
    rows = []
    tree = TREE_FLAT
    wl = workloads.permutation(tree, size_bytes=_sz(1 * MiB, quick), seed=4)
    for lb in ("reps", "spray"):
        s = run_scenario(tree, wl, algo="smartt", lb=lb,
                         faults=((0, 3, 2),), fault_start=0,
                         max_ticks=_mt(60000, quick))
        rows.append(emit(f"fig7a_degraded_{lb}", s.wall_s,
                         f"completion={s.completion};trims={s.trims}"))
    for lb in ("reps", "spray"):
        s = run_scenario(tree, wl, algo="smartt", lb=lb,
                         faults=((0, 3, 0),), fault_start=200,
                         max_ticks=_mt(60000, quick))
        rows.append(emit(f"fig7c_linkdown_{lb}", s.wall_s,
                         f"completion={s.completion};"
                         f"blackholed={s.blackholed}"))
    return rows


def fig9_trimming(quick=False):
    """Fig. 8/9: losing trimming costs ~a base RTT or two, not more."""
    rows = []
    brtt = 26
    cases = [
        ("incast16_512K", TREE_FLAT,
         workloads.incast(TREE_FLAT, degree=16,
                          size_bytes=_sz(512 * KiB, quick), seed=5)),
        ("perm_4to1_1M", TREE_4TO1,
         workloads.permutation(TREE_4TO1, size_bytes=_sz(1 * MiB, quick),
                               seed=5)),
    ]
    for name, tree, wl in cases:
        base = run_scenario(tree, wl, algo="smartt", trimming=True,
                            max_ticks=_mt(60000, quick))
        noto = run_scenario(tree, wl, algo="smartt", trimming=False,
                            max_ticks=_mt(60000, quick))
        delta = (noto.completion - base.completion) / brtt
        rows.append(emit(f"fig9_{name}", base.wall_s + noto.wall_s,
                         f"trim={base.completion};timeout={noto.completion};"
                         f"delta_brtt={delta:.2f};"
                         f"spurious={noto.spurious_frac:.4f}"))
    return rows


def fig10_incast(quick=False):
    """Fig. 10: incast across degrees/sizes — EQDS near-perfect, SMaRTT
    within a few %, MPRDMA less fair, BBR slow for mid sizes."""
    rows = []
    for degree, size in ((8, 256 * KiB), (24, 512 * KiB)):
        size = _sz(size, quick)
        wl = workloads.incast(TREE_FLAT, degree=degree, size_bytes=size, seed=6)
        ideal = degree * (size // 4096) + 26
        for algo in ("smartt", "swift", "mprdma", "bbr", "eqds"):
            s = run_scenario(TREE_FLAT, wl, algo=algo,
                             max_ticks=_mt(60000, quick))
            rows.append(emit(
                f"fig10_incast{degree}_{size//KiB}K_{algo}", s.wall_s,
                f"completion={s.completion};vs_ideal="
                f"{s.completion/ideal:.3f};jain={s.jain:.3f}"))
    return rows


def fig11_permutation(quick=False):
    """Fig. 1/11: permutations under oversubscription — SMaRTT fastest &
    fair; EQDS wastes bandwidth on trims; one-big-flow favors FastIncrease."""
    rows = []
    for name, tree in (("8to1", TREE_8TO1), ("4to1", TREE_4TO1),
                       ("2to1", TREE_2TO1)):
        wl = workloads.permutation(tree, size_bytes=_sz(512 * KiB, quick),
                                   seed=7)
        for algo in ("smartt", "swift", "mprdma", "bbr", "eqds"):
            s = run_scenario(tree, wl, algo=algo,
                             max_ticks=_mt(120000, quick))
            rows.append(emit(
                f"fig11_perm_{name}_{algo}", s.wall_s,
                f"completion={s.completion};jain={s.jain:.3f};"
                f"trims={s.trims}"))
    # Fig 11c: multiple concurrent permutations
    wl = workloads.permutation(TREE_4TO1, size_bytes=_sz(512 * KiB, quick),
                               seed=8, n_perms=2)
    for algo in ("smartt", "eqds"):
        s = run_scenario(TREE_4TO1, wl, algo=algo,
                         max_ticks=_mt(120000, quick))
        rows.append(emit(f"fig11c_multiperm_{algo}", s.wall_s,
                         f"completion={s.completion};trims={s.trims}"))
    # Fig 11d: one bigger flow — FastIncrease reclaims bandwidth
    wl = workloads.permutation(TREE_4TO1, size_bytes=_sz(512 * KiB, quick),
                               seed=9, big_flow=(0, _sz(1 * MiB, quick)))
    for algo in ("smartt", "swift"):
        s = run_scenario(TREE_4TO1, wl, algo=algo,
                         max_ticks=_mt(120000, quick))
        rows.append(emit(f"fig11d_bigflow_{algo}", s.wall_s,
                         f"completion={s.completion}"))
    return rows


def fig12_alltoall(quick=False):
    """Fig. 12: windowed alltoall (MoE traffic) — sender-based CC wins as
    parallel connections grow."""
    rows = []
    tree = TREE_4TO1
    wl = workloads.alltoall(tree, size_bytes=_sz(64 * KiB, quick), window=4,
                            nodes=16)
    for algo in ("smartt", "swift", "eqds"):
        s = run_scenario(tree, wl, algo=algo, max_ticks=_mt(200000, quick))
        rows.append(emit(f"fig12_alltoall_w4_{algo}", s.wall_s,
                         f"completion={s.completion};trims={s.trims};"
                         f"done={s.n_done}"))
    return rows


def fig13_eqds(quick=False):
    """Fig. 13 / Sec. 5.1: EQDS augmented with SMaRTT fixes fabric
    congestion that vanilla EQDS cannot manage."""
    rows = []
    wl = workloads.permutation(TREE_8TO1, size_bytes=_sz(512 * KiB, quick),
                               seed=10)
    for algo in ("eqds", "eqds_smartt", "smartt"):
        s = run_scenario(TREE_8TO1, wl, algo=algo,
                         max_ticks=_mt(120000, quick))
        rows.append(emit(f"fig13_{algo}", s.wall_s,
                         f"completion={s.completion};trims={s.trims};"
                         f"jain={s.jain:.3f}"))
    return rows


ALL_FIGS = (fig2_signals, fig3b_granularity, fig5b_wtd, fig6_reps,
            fig7_faults, fig9_trimming, fig10_incast, fig11_permutation,
            fig12_alltoall, fig13_eqds)
