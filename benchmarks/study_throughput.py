"""Fleet-scale Study throughput: lanes/sec vs forced host-device count,
plus cold/warm result-cache wall time (DESIGN.md Sec. 7).

Device count is fixed at process start (XLA reads
``--xla_force_host_platform_device_count`` before the first jax import),
so every measurement runs in a *worker subprocess* launched with its own
``XLA_FLAGS``; the parent only orchestrates and writes the ledger.

Two row families land in ``BENCH_netsim.json`` under
``sections.study_throughput``:

- ``<scenario>/d<D>``: one Study (base point x S seeds) sharded over D
  forced host devices — steady-state (post-compile) wall, lanes/sec, and
  the full final-state pytree digest.  The parent *hard-fails* unless
  every D produces the same digest as D=1: bit-identical sharding is an
  acceptance property, not a perf number.
- ``<scenario>/cache/{cold,warm}``: the same Study run against a fresh
  content-addressed cache (cold: every lane computed + written back)
  and then re-run (warm: every lane a hit, zero recomputed).  The warm
  row records ``speedup_vs_cold``; the acceptance floor is 10x.

Usage:
  PYTHONPATH=src python -m benchmarks.study_throughput            # full
  PYTHONPATH=src python -m benchmarks.study_throughput --quick    # CI
      [--scenario NAME] [--seeds N] [--devices 1,2,4,8]
      [--json-path PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_MARK = "STUDY_THROUGHPUT_RESULT "


# --------------------------------------------------------------------------
# worker side (runs with XLA_FLAGS already set by the parent)
# --------------------------------------------------------------------------


def _worker_shard(scenario: str, n_seeds: int) -> dict:
    import jax

    from repro.netsim import api, cache, shard

    n_dev = jax.device_count()
    st = api.study(scenario, seeds=tuple(range(n_seeds)))
    mesh = shard.lane_mesh() if n_dev > 1 else None
    first = st.run(mesh=mesh)           # compile + run
    steady = st.run(mesh=mesh)          # reuses the jit cache
    return dict(
        devices=n_dev, lanes=st.n_lanes,
        wall_first_s=round(first.wall_s, 4),
        wall_s=round(steady.wall_s, 4),
        lanes_per_sec=round(st.n_lanes / steady.wall_s, 3),
        digest=cache.state_digest(steady.states),
    )


def _worker_cache(scenario: str, n_seeds: int) -> dict:
    from repro.netsim import api, cache

    st = api.study(scenario, seeds=tuple(range(n_seeds)))
    root = tempfile.mkdtemp(prefix="netsim_cache_bench_")
    try:
        rc = cache.ResultCache(root)
        cold = st.run(cache=rc)
        warm = st.run(cache=rc)
        return dict(
            lanes=st.n_lanes,
            cold_wall_s=round(cold.wall_s, 4),
            warm_wall_s=round(warm.wall_s, 4),
            cold_hits=cold.cache_hits, cold_misses=cold.cache_misses,
            warm_hits=warm.cache_hits, warm_misses=warm.cache_misses,
            speedup=round(cold.wall_s / max(warm.wall_s, 1e-9), 2),
            cold_digest=cache.state_digest(cold.states),
            warm_digest=cache.state_digest(warm.states),
        )
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _run_worker(mode: str, scenario: str, n_seeds: int,
                devices: int = 1) -> dict:
    """Launch one measurement subprocess with its own device count and
    parse its ``STUDY_THROUGHPUT_RESULT`` line."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.study_throughput", "--worker",
           mode, "--scenario", scenario, "--seeds", str(n_seeds)]
    proc = subprocess.run(cmd, cwd=REPO_ROOT, env=env, text=True,
                          capture_output=True, timeout=3600)
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(
        f"worker ({mode}, d={devices}) produced no result line\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")


# --------------------------------------------------------------------------
# parent side
# --------------------------------------------------------------------------


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: tiny scenario, D in {1,2}")
    p.add_argument("--scenario", default=None)
    p.add_argument("--seeds", type=int, default=None)
    p.add_argument("--devices", default=None,
                   help="comma-separated forced host-device counts")
    p.add_argument("--json-path", default=None)
    p.add_argument("--worker", default=None, choices=("shard", "cache"),
                   help=argparse.SUPPRESS)
    args = p.parse_args(argv)

    scenario = args.scenario or ("tiny_3t" if args.quick else "perm_512n_3t")
    n_seeds = args.seeds or (3 if args.quick else 8)

    if args.worker:
        fn = _worker_shard if args.worker == "shard" else _worker_cache
        print(_MARK + json.dumps(fn(scenario, n_seeds)))
        return 0

    from benchmarks.common import emit, write_bench_json

    devices = ([int(d) for d in args.devices.split(",")] if args.devices
               else ([1, 2] if args.quick else [1, 2, 4, 8]))
    rows = []
    t0 = time.time()

    base_digest = None
    for d in devices:
        r = _run_worker("shard", scenario, n_seeds, devices=d)
        name = f"{scenario}/d{d}"
        rows.append(dict(name=name, scenario=scenario, devices=r["devices"],
                         lanes=r["lanes"], wall_s=r["wall_s"],
                         wall_first_s=r["wall_first_s"],
                         lanes_per_sec=r["lanes_per_sec"],
                         digest=r["digest"]))
        emit(name, r["wall_s"],
             f"{r['lanes_per_sec']:.2f} lanes/s on {r['devices']} dev")
        if base_digest is None:
            base_digest = r["digest"]
        elif r["digest"] != base_digest:
            print(f"::error title=shard parity::{name} final-state digest "
                  f"{r['digest'][:12]} != d{devices[0]} "
                  f"{base_digest[:12]} — sharded run is NOT bit-identical")
            raise SystemExit(1)
    print(f"# shard parity: {len(devices)} device counts, one digest "
          f"{base_digest[:12]}…")

    c = _run_worker("cache", scenario, n_seeds, devices=1)
    if c["cold_digest"] != c["warm_digest"] or \
            c["cold_digest"] != base_digest:
        print("::error title=cache parity::cold/warm digests diverge from "
              "the uncached run")
        raise SystemExit(1)
    if c["warm_misses"] != 0:
        print(f"::error title=cache resume::warm run recomputed "
              f"{c['warm_misses']} lane(s); expected 0")
        raise SystemExit(1)
    rows.append(dict(name=f"{scenario}/cache/cold", scenario=scenario,
                     lanes=c["lanes"], wall_s=c["cold_wall_s"],
                     lanes_per_sec=round(c["lanes"] / c["cold_wall_s"], 3),
                     cache_hits=c["cold_hits"],
                     cache_misses=c["cold_misses"]))
    rows.append(dict(name=f"{scenario}/cache/warm", scenario=scenario,
                     lanes=c["lanes"], wall_s=c["warm_wall_s"],
                     lanes_per_sec=round(c["lanes"] / c["warm_wall_s"], 3),
                     cache_hits=c["warm_hits"],
                     cache_misses=c["warm_misses"],
                     speedup_vs_cold=c["speedup"]))
    emit(f"{scenario}/cache/cold", c["cold_wall_s"],
         f"{c['cold_misses']} lanes computed")
    emit(f"{scenario}/cache/warm", c["warm_wall_s"],
         f"{c['warm_hits']} hits, {c['speedup']}x vs cold")
    if c["speedup"] < 10.0:
        print(f"::warning title=cache speedup::warm cache only "
              f"{c['speedup']}x faster than cold (acceptance floor: 10x)")

    path = write_bench_json("study_throughput", rows, path=args.json_path,
                            meta=dict(scenario=scenario, seeds=n_seeds,
                                      note="workers forced device counts "
                                           "via XLA_FLAGS"))
    print(f"# wrote {len(rows)} rows to {path} in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
