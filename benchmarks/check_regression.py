"""Compare a freshly measured perf section against the committed
``BENCH_netsim.json`` ledger and *warn* on ticks/sec regressions.

CI's bench smoke job runs ``benchmarks.perf --quick`` into a scratch path
and then::

  python -m benchmarks.check_regression --fresh fresh.json \
      --ledger BENCH_netsim.json [--threshold 0.30] [--section perf]

Rows are matched by ``name``; only rows carrying ``ticks_per_sec`` in both
documents are compared.  A fresh row more than ``threshold`` below the
ledger prints a GitHub ``::warning::`` annotation (and a plain line for
local runs).  Exit code stays 0 — machine-speed drift on shared CI runners
makes a hard gate flakier than it is useful; the ledger itself is the
reviewed artifact.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str, section: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("sections", {}).get(section, {}).get("rows", [])
    return {r["name"]: r for r in rows
            if isinstance(r, dict) and "name" in r
            and isinstance(r.get("ticks_per_sec"), (int, float))}


def compare(fresh: dict, ledger: dict, threshold: float):
    """Yields (name, fresh_tps, ledger_tps, ratio) for regressed rows."""
    for name, row in sorted(fresh.items()):
        base = ledger.get(name)
        if base is None:
            continue
        f_tps, l_tps = row["ticks_per_sec"], base["ticks_per_sec"]
        if l_tps > 0 and f_tps < (1.0 - threshold) * l_tps:
            yield name, f_tps, l_tps, f_tps / l_tps


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fresh", required=True, help="freshly measured ledger")
    p.add_argument("--ledger", required=True, help="committed ledger")
    p.add_argument("--section", default="perf")
    p.add_argument("--threshold", type=float, default=0.30,
                   help="warn when fresh ticks/sec drops more than this "
                        "fraction below the ledger (default 0.30)")
    args = p.parse_args(argv)

    fresh = load_rows(args.fresh, args.section)
    ledger = load_rows(args.ledger, args.section)
    common = sorted(set(fresh) & set(ledger))
    print(f"# comparing {len(common)} row(s) "
          f"({len(fresh)} fresh, {len(ledger)} in ledger), "
          f"threshold {args.threshold:.0%}")
    for name in common:
        print(f"#   {name}: {fresh[name]['ticks_per_sec']:.0f} vs "
              f"{ledger[name]['ticks_per_sec']:.0f} ticks/sec")

    regressions = list(compare(fresh, ledger, args.threshold))
    for name, f_tps, l_tps, ratio in regressions:
        msg = (f"perf regression {name}: {f_tps:.0f} ticks/sec vs "
               f"{l_tps:.0f} in the ledger ({ratio:.2f}x)")
        print(f"::warning title=bench regression::{msg}")
        print(msg, file=sys.stderr)
    if not regressions:
        print("# no regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
