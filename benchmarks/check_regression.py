"""Compare a freshly measured benchmark section against the committed
``BENCH_netsim.json`` ledger and *warn* on metric regressions.

CI's bench smoke job runs ``benchmarks.perf --quick`` into a scratch path
and then::

  python -m benchmarks.check_regression --fresh fresh.json \
      --ledger BENCH_netsim.json [--threshold 0.30] [--section perf]
      [--metric ticks_per_sec] [--direction up]

Rows are matched by ``name``; only rows carrying ``--metric`` as a number
in both documents are compared.  The default reads the engine-throughput
rows (``perf`` / ``ticks_per_sec``, higher is better); the experiment
API's ``StudyResult`` rows (section ``studies`` — ``benchmarks.sweep
--json`` / ``benchmarks.run --studies``) compare the same way, e.g.::

  python -m benchmarks.check_regression --fresh fresh.json \
      --ledger BENCH_netsim.json --section studies \
      --metric completion --direction down

A fresh row more than ``threshold`` worse than the ledger (below it for
``--direction up`` metrics like ticks/sec, above it for ``--direction
down`` metrics like completion ticks) prints a GitHub ``::warning::``
annotation (and a plain line for local runs).  Exit code stays 0 —
machine-speed drift on shared CI runners makes a hard gate flakier than
it is useful; the ledger itself is the reviewed artifact.

The fleet-scale Study rows (section ``study_throughput`` —
``benchmarks.study_throughput``; lanes/sec per forced host-device count
plus cold/warm cache wall time) compare with::

  python -m benchmarks.check_regression --fresh fresh.json \
      --ledger BENCH_netsim.json --section study_throughput \
      --metric lanes_per_sec

``--require`` takes comma-separated row-name prefixes that must match at
least one *compared* row (present in both documents) — CI passes the
three-tier and pallas-backend families here (and the ``d<N>``/``cache``
study-throughput families in the multidevice job), so a refactor that
silently drops those rows from the quick bench warns instead of
shrinking coverage unnoticed.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str, section: str, metric: str = "ticks_per_sec") -> dict:
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("sections", {}).get(section, {}).get("rows", [])
    return {r["name"]: r for r in rows
            if isinstance(r, dict) and "name" in r
            and isinstance(r.get(metric), (int, float))
            and not isinstance(r.get(metric), bool)}


def compare(fresh: dict, ledger: dict, threshold: float,
            metric: str = "ticks_per_sec", direction: str = "up"):
    """Yields (name, fresh_value, ledger_value, ratio) for regressed rows.
    ``direction`` is which way the metric is *good*: ``up`` warns when the
    fresh value drops below ``(1 - threshold) * ledger``; ``down`` warns
    when it rises above ``(1 + threshold) * ledger``."""
    for name, row in sorted(fresh.items()):
        base = ledger.get(name)
        if base is None:
            continue
        f_v, l_v = row[metric], base[metric]
        if l_v <= 0:
            continue
        if direction == "up":
            bad = f_v < (1.0 - threshold) * l_v
        else:
            # a negative fresh value is the unfinished sentinel (e.g.
            # completion=-1: the run no longer finishes) — the worst
            # possible regression, never a pass
            bad = f_v > (1.0 + threshold) * l_v or f_v < 0
        if bad:
            yield name, f_v, l_v, f_v / l_v


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fresh", required=True, help="freshly measured ledger")
    p.add_argument("--ledger", required=True, help="committed ledger")
    p.add_argument("--section", default="perf")
    p.add_argument("--metric", default="ticks_per_sec",
                   help="numeric row field to compare (default "
                        "ticks_per_sec; StudyResult rows also carry "
                        "completion, fct_p99, slowdown_p99, trims, ...)")
    p.add_argument("--direction", choices=("up", "down"), default="up",
                   help="which way the metric is good (default up: warn "
                        "on drops; use down for completion/FCT metrics)")
    p.add_argument("--threshold", type=float, default=0.30,
                   help="warn when the fresh metric is more than this "
                        "fraction worse than the ledger (default 0.30)")
    p.add_argument("--require", default=None, metavar="PREFIXES",
                   help="comma-separated row-name prefixes that must each "
                        "match a compared row (e.g. 'tiny_3t/pallas,"
                        "tiny_3t/jnp') — warns on missing coverage")
    args = p.parse_args(argv)

    fresh = load_rows(args.fresh, args.section, args.metric)
    ledger = load_rows(args.ledger, args.section, args.metric)
    common = sorted(set(fresh) & set(ledger))
    print(f"# comparing {len(common)} row(s) "
          f"({len(fresh)} fresh, {len(ledger)} in ledger), "
          f"section {args.section!r} metric {args.metric!r} "
          f"threshold {args.threshold:.0%}")
    for name in common:
        print(f"#   {name}: {fresh[name][args.metric]:g} vs "
              f"{ledger[name][args.metric]:g} {args.metric}")
    for prefix in (args.require.split(",") if args.require else []):
        prefix = prefix.strip()
        if prefix and not any(n.startswith(prefix) for n in common):
            msg = (f"required bench row family {prefix!r} matched no "
                   f"compared row — coverage shrank")
            print(f"::warning title=bench coverage::{msg}")
            print(msg, file=sys.stderr)

    regressions = list(compare(fresh, ledger, args.threshold,
                               args.metric, args.direction))
    for name, f_v, l_v, ratio in regressions:
        msg = (f"bench regression {name}: {f_v:g} {args.metric} vs "
               f"{l_v:g} in the ledger ({ratio:.2f}x)")
        print(f"::warning title=bench regression::{msg}")
        print(msg, file=sys.stderr)
    if not regressions:
        print("# no regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
