"""Failover benchmark: the registered dynamic-fault scenarios
(``corefail_128n_3t`` / ``flap_128n_3t`` / ``switchkill_128n_3t``) run
across congestion-control backends, each with and without the
failure-recovery transport knobs (capped exponential RTO backoff +
REPS timeout entropy eviction, ISSUE 8).

This is the paper's Fig. 7 degraded-fabric comparison re-shaped around
*dynamic* schedules: the fault fails mid-flight and (except the flap)
repairs before the budget, so the rows carry the recovery metrics —
``fault_ticks``, ``delivered_fault_frac``, ``ttr_max``, ``dip_depth``,
``dip_ticks`` — next to completion.  Row names are
``<scenario>[+recovery]/<algo>``; rows land in ledger section
``failover`` and compare PR-over-PR via::

  python -m benchmarks.check_regression --fresh fresh.json \
      --ledger BENCH_netsim.json --section failover \
      --metric completion --direction down --require corefail_128n_3t

``--quick`` runs only the corefail scenario on smartt (both recovery
variants) for the CI chaos job — a same-named subset of the full table
(same scenarios, same tick budgets), so the quick rows compare directly
against the committed ledger.

Usage:
  PYTHONPATH=src python -m benchmarks.failover [--quick] [--json-path PATH]
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import BENCH_JSON, emit, write_bench_json
from repro.netsim import api, scenarios

SCENARIOS = ("corefail_128n_3t", "flap_128n_3t", "switchkill_128n_3t")
ALGOS = ("smartt", "swift", "mprdma")

# the recovery configuration under test: retry up to 4x the base RTO and
# evict the cached REPS entropy on every timeout (see DESIGN.md Sec. 9)
RECOVERY = dict(rto_backoff_max=2, evict_on_timeout=True)


def variants(quick: bool):
    """(scenario name, algo, recovery?) triples — one ledger row each."""
    names = SCENARIOS[:1] if quick else SCENARIOS
    algos = ALGOS[:1] if quick else ALGOS
    return [(name, algo, rec)
            for name in names for algo in algos for rec in (False, True)]


def run_variant(name: str, algo: str, recovery: bool) -> dict:
    label = f"{name}+recovery/{algo}" if recovery else f"{name}/{algo}"
    over = dict(name=label, algo=algo)
    if recovery:
        over.update(RECOVERY)
    sc = scenarios.scenario(name).with_(**over)
    t0 = time.time()
    r = api.run(sc)
    row = r.row()
    emit(label, time.time() - t0,
         f"done={r.n_done}/{r.n_flows} completion={r.completion} "
         f"black={r.blackholed} ttr={row.get('ttr_max', -1)}")
    return row


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="corefail/smartt only (CI smoke)")
    p.add_argument("--json-path", default=BENCH_JSON, metavar="PATH",
                   help="ledger path (default: repo BENCH_netsim.json)")
    args = p.parse_args(argv)

    print("name,us_per_call,derived")
    rows = [run_variant(name, algo, rec)
            for name, algo, rec in variants(args.quick)]

    path = write_bench_json(
        "failover", rows, path=args.json_path,
        meta=dict(quick=bool(args.quick)))
    print(f"wrote {len(rows)} rows -> {path} section=failover",
          file=sys.stderr)


if __name__ == "__main__":
    main()
