"""Study-grid benchmark: paper-style tuning sweeps x seed batches for one
compile per (scenario, algorithm).

Reproduces the Fig. 4-7-shaped studies through the experiment API
(DESIGN.md Sec. 7): an incast and a core-crossing permutation scenario,
each evaluated across {smartt, swift, mprdma, eqds} over an 8-point grid
of (start_cwnd_mult x react_every) plus RED threshold variants, crossed
with decorrelation seeds — every {point x seed} lane of a grid rides one
compiled step (``api.study``), the kind of many-config evaluation loop
that UEC-style tuning studies and spraying/congested-path analyses need.

Prints ``name,us_per_call,derived`` CSV rows (one per lane, plus a
per-grid compile/wall summary).  With ``--json`` the typed
``StudyResult.rows()`` land in the ``studies`` section of
``BENCH_netsim.json`` (compare PR-over-PR via
``benchmarks.check_regression --section studies --metric completion``).

Usage:
  PYTHONPATH=src python -m benchmarks.sweep [--seeds N] [--quick] [--json]
      [--json-path PATH] [incast perm ...]
"""

from __future__ import annotations

import argparse
import time

from repro.analysis import trace_guard
from repro.netsim import api
from repro.netsim.scenarios import scenario

SCENARIOS = ("incast8_16n", "perm_16n")
ALGOS = ("smartt", "swift", "mprdma", "eqds")
MAX_TICKS = 60000

# 8-point grid: initial window x reaction granularity, plus RED variants
GRID = (
    [{"start_cwnd_mult": a, "react_every": r}
     for a in (0.5, 1.0, 1.25) for r in (1, 4)]
    + [{"kmin_frac": 0.1, "kmax_frac": 0.4},
       {"kmin_frac": 0.3, "kmax_frac": 0.9}]
)


def run_study(sc_name: str, algo: str, seeds, grid=GRID,
              max_ticks=MAX_TICKS) -> tuple:
    """One fused {grid x seeds} study; returns (ledger rows, csv rows)."""
    sc = scenario(sc_name, algo=algo, max_ticks=max_ticks)
    t0 = time.time()
    st = api.study(sc, points=grid, seeds=seeds)
    with trace_guard("engine.step") as g:
        res = st.run()
    build_wall = time.time() - t0
    compiles = g.count
    csv = []
    for r in res:
        csv.append(f"study_{sc_name}_{algo}[{r.point_tag}]s{r.seed},"
                   f"{build_wall / len(res) * 1e6:.0f},"
                   f"completion={r.completion};jain={r.jain:.3f};"
                   f"slowdown_p99={r.slowdown_p99:.2f};trims={r.trims};"
                   f"done={r.n_done}")
    csv.append(f"study_{sc_name}_{algo}_total,{build_wall * 1e6:.0f},"
               f"lanes={len(res)};points={st.n_points};seeds={st.n_seeds};"
               f"step_compiles={compiles};run_wall_s={res.wall_s:.2f}")
    rows = res.rows()
    for row in rows:
        row["wall_s"] = round(res.wall_s / len(rows), 6)
    return rows, csv


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("filters", nargs="*", help="substring filters "
                   "(incast perm smartt ...)")
    p.add_argument("--seeds", type=int, default=2,
                   help="decorrelation seeds per grid point (default 2)")
    p.add_argument("--quick", action="store_true",
                   help="smoke run: one scenario x {smartt,eqds} over a "
                        "4-point grid, 1 seed, scaled ticks; rows go to "
                        "section 'studies_quick', never 'studies'")
    p.add_argument("--json", action="store_true",
                   help="record StudyResult rows into BENCH_netsim.json "
                        "(section 'studies')")
    p.add_argument("--json-path", default=None, metavar="PATH",
                   help="ledger path (implies --json)")
    args = p.parse_args(argv)
    if args.quick:
        scenarios_, algos = ("incast8_16n",), ("smartt", "eqds")
        grid, seeds, max_ticks = GRID[:4], (0,), MAX_TICKS // 4
    else:
        scenarios_, algos = SCENARIOS, ALGOS
        grid, seeds, max_ticks = GRID, tuple(range(args.seeds)), MAX_TICKS

    print("name,us_per_call,derived")
    ledger_rows = []
    for sc_name in scenarios_:
        for algo in algos:
            tag = f"{sc_name}_{algo}"
            if args.filters and not any(w in tag for w in args.filters):
                continue
            rows, csv = run_study(sc_name, algo, seeds, grid, max_ticks)
            ledger_rows.extend(rows)
            for line in csv:
                print(line)

    if args.json or args.json_path:
        from benchmarks.common import write_bench_json
        path = write_bench_json(
            "studies_quick" if args.quick else "studies", ledger_rows,
            path=args.json_path,
            meta=dict(grid=len(grid), seeds=len(seeds)))
        print(f"# {len(ledger_rows)} study rows -> {path}")


if __name__ == "__main__":
    main()
