"""Batched parameter-sweep benchmark: paper-style tuning grids for one
compile per (workload, algorithm).

Reproduces the Fig. 4-7-shaped studies as a grid sweep: an incast and a
core-crossing permutation, each evaluated across {smartt, swift, mprdma,
eqds} over an 8-point grid of (start_cwnd_mult x react_every) plus RED
threshold variants — the kind of many-config evaluation loop that UEC-style
tuning studies and spraying/congested-path analyses need.

Prints ``name,us_per_call,derived`` CSV rows (one per grid point, plus a
per-grid compile/wall summary).

Usage:
  PYTHONPATH=src python -m benchmarks.sweep [incast perm ...]
"""

from __future__ import annotations

import sys
import time

from repro.netsim import engine, workloads
from repro.netsim.metrics import jain_fairness
from repro.netsim.state import SimConfig
from repro.netsim.sweep import build_sweep
from repro.netsim.units import FatTreeConfig, LinkConfig

TREE = FatTreeConfig(racks=2, nodes_per_rack=8, uplinks=2)   # 16 nodes, 4:1
ALGOS = ("smartt", "swift", "mprdma", "eqds")
MAX_TICKS = 60000

# 8-point grid: initial window x reaction granularity, plus RED variants
GRID = (
    [{"start_cwnd_mult": a, "react_every": r}
     for a in (0.5, 1.0, 1.25) for r in (1, 4)]
    + [{"kmin_frac": 0.1, "kmax_frac": 0.4},
       {"kmin_frac": 0.3, "kmax_frac": 0.9}]
)


def _workloads():
    return (
        ("incast", workloads.incast(TREE, degree=8, size_bytes=64 * 4096,
                                    seed=3)),
        ("perm", workloads.permutation(TREE, size_bytes=64 * 4096, seed=3)),
    )


def main() -> None:
    wanted = set(sys.argv[1:])
    print("name,us_per_call,derived")
    for wl_name, wl in _workloads():
        if wanted and not any(w in wl_name for w in wanted):
            continue
        for algo in ALGOS:
            cfg = SimConfig(link=LinkConfig(), tree=TREE, algo=algo, lb="reps")
            t0 = time.time()
            sw = build_sweep(cfg, wl, GRID)
            c0 = engine.STEP_TRACE_COUNT[0]
            states = sw.run(max_ticks=MAX_TICKS)
            states.now.block_until_ready()
            wall = time.time() - t0
            compiles = engine.STEP_TRACE_COUNT[0] - c0
            rows = sw.summaries(states)
            for pt, r in zip(GRID, rows):
                tag = "+".join(f"{k}={v:g}" for k, v in pt.items())
                done = r["fct_ticks"] > 0
                jain = jain_fairness(r["fct_ticks"][done]) if done.any() else 0.0
                print(f"sweep_{wl_name}_{algo}[{tag}],"
                      f"{wall / len(GRID) * 1e6:.0f},"
                      f"fct_max={r['fct_max']};jain={jain:.3f};"
                      f"trims={r['trims']};done={r['n_done']}")
            print(f"sweep_{wl_name}_{algo}_total,{wall*1e6:.0f},"
                  f"points={len(GRID)};step_compiles={compiles}")


if __name__ == "__main__":
    main()
