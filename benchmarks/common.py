"""Shared benchmark scaffolding for the paper-figure reproductions.

Scenarios are scaled to CPU (64-128 nodes, 100 Gb/s ticks, 256 KiB - 2 MiB
flows) from the paper's 1024-node 800 Gb/s setup; the *relative* behavior
between algorithms is the reproduction target (see EXPERIMENTS.md).
Every row prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import json
import os
import platform
import time

from repro.netsim import api
from repro.netsim.scenarios import (LINK,  # noqa: F401 (re-export)
                                    TREE_2TO1, TREE_4TO1, TREE_8TO1,
                                    TREE_FLAT, TREE_TINY, Scenario)
from repro.netsim.state import SimConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_netsim.json")


def run_scenario(tree, wl, *, algo="smartt", lb="reps", max_ticks=60000,
                 seed=0, **cfg_kw) -> api.RunResult:
    """Run one ad-hoc (tree, workload) setup through the experiment API
    (DESIGN.md Sec. 7) -> typed :class:`api.RunResult` (completion, jain,
    slowdowns, counters, wall_s)."""
    sc = Scenario(name=wl.name,
                  cfg=SimConfig(link=LINK, tree=tree, algo=algo, lb=lb,
                                **cfg_kw),
                  wl=wl, max_ticks=max_ticks)
    return api.run(sc, seed=seed)


def emit(name: str, wall_s: float, derived) -> str:
    row = f"{name},{wall_s*1e6:.0f},{derived}"
    print(row)
    return row


def write_bench_json(section: str, rows, path: str | None = None,
                     meta: dict | None = None) -> str:
    """Merge ``rows`` (a list of dicts keyed by ``name``) into the
    machine-readable benchmark ledger ``BENCH_netsim.json`` under
    ``sections[section]``.  Other sections are preserved, and within the
    section new rows replace same-named rows while the rest survive — so
    the trajectory accumulates PR-over-PR and a filtered run (e.g.
    ``benchmarks.run --json fig2``) never drops previously recorded
    figures."""
    path = path or BENCH_JSON
    doc = {"schema": 1, "sections": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            if isinstance(old, dict) and old.get("schema") == 1 \
                    and isinstance(old.get("sections"), dict):
                doc = old
        except (json.JSONDecodeError, OSError):
            pass                      # unreadable ledger: start fresh
    rows = list(rows)
    prev = doc["sections"].get(section, {})
    if isinstance(prev, dict) and isinstance(prev.get("rows"), list):
        fresh = {r.get("name") for r in rows if isinstance(r, dict)}
        rows = [r for r in prev["rows"]
                if isinstance(r, dict) and r.get("name") not in fresh] + rows
    sec = {
        "generated_unix": int(time.time()),
        "platform": platform.platform(),
        "rows": rows,
    }
    if meta:
        sec.update(meta)
    doc["sections"][section] = sec
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def ideal_ticks(n_pkts_through_bottleneck: int, brtt: int = 26) -> int:
    return n_pkts_through_bottleneck + brtt
