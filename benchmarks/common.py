"""Shared benchmark scaffolding for the paper-figure reproductions.

Scenarios are scaled to CPU (64-128 nodes, 100 Gb/s ticks, 256 KiB - 2 MiB
flows) from the paper's 1024-node 800 Gb/s setup; the *relative* behavior
between algorithms is the reproduction target (see EXPERIMENTS.md).
Every row prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import time

import numpy as np

from repro.netsim.engine import SimConfig, build, jain_fairness, summarize
from repro.netsim.units import FatTreeConfig, LinkConfig

LINK = LinkConfig()

# standard scaled topologies
TREE_8TO1 = FatTreeConfig(racks=8, nodes_per_rack=16, uplinks=2)     # 128 nodes
TREE_4TO1 = FatTreeConfig(racks=4, nodes_per_rack=16, uplinks=4)     # 64 nodes
TREE_2TO1 = FatTreeConfig(racks=4, nodes_per_rack=16, uplinks=8)     # 64 nodes
TREE_FLAT = FatTreeConfig(racks=4, nodes_per_rack=8, uplinks=8)      # 32 nodes, 1:1


def run_scenario(tree, wl, *, algo="smartt", lb="reps", max_ticks=60000,
                 **cfg_kw):
    cfg = SimConfig(link=LINK, tree=tree, algo=algo, lb=lb, **cfg_kw)
    sim = build(cfg, wl)
    t0 = time.time()
    st = sim.run(max_ticks=max_ticks)
    st.now.block_until_ready()
    wall = time.time() - t0
    s = summarize(sim, st)
    done_mask = np.asarray(st.done)
    fd = s["fct_ticks"][done_mask]
    s["jain"] = jain_fairness(fd) if done_mask.any() else 0.0
    s["wall_s"] = wall
    s["completion"] = int(fd.max()) if done_mask.any() else -1
    return s


def emit(name: str, wall_s: float, derived) -> str:
    row = f"{name},{wall_s*1e6:.0f},{derived}"
    print(row)
    return row


def ideal_ticks(n_pkts_through_bottleneck: int, brtt: int = 26) -> int:
    return n_pkts_through_bottleneck + brtt
